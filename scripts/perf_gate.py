#!/usr/bin/env python3
"""Self-calibrating perf gate for the CI `perf` job (stdlib only).

Works against the trajectory file `BENCH_perf_hotpath.json` at the repo
root and the single-entry JSON the bench writes via `--json-out`.

Subcommands
-----------
floor
    Print (stdout, one number) the blocking suite-throughput floor:
    0.5 x the median `suite_throughput_task_runs_per_s` of the last N
    trajectory entries from the same runner. With fewer than MIN_ENTRIES
    same-runner entries the conservative bootstrap fallback is used.
    The basis for the chosen floor is printed to stderr so the CI job
    log always shows where the number came from.

check-allocs
    Compare the new entry's `allocs_per_task_run` against the most
    recent trajectory entry that carries one (trajectory entries are
    only appended on main-branch pushes, so that is "last main"). Fails
    (exit 1) on a regression of more than REGRESS_FRAC; prints a skip
    notice and exits 0 when either side has no allocation count yet.

append
    Stamp `date` and `runner` onto the new entry and append it to the
    trajectory file (newest last), preserving the file's 2-space-indent
    formatting. The CI job commits the result on main pushes.
"""

import argparse
import datetime
import json
import statistics
import sys

# Bootstrap floor (task-runs/s) until the trajectory has enough entries
# to calibrate from — the pre-calibration hard-coded CI value.
FALLBACK_FLOOR = 10.0
# Same-runner entries needed before the calibrated floor takes over.
MIN_ENTRIES = 3
# The floor is this fraction of the median recent throughput: low enough
# that runner noise does not trip it, high enough that a real hot-path
# regression (2x+) does.
FLOOR_FRAC = 0.5
# Window of most-recent same-runner entries the median is taken over.
WINDOW = 10
# Allowed allocs_per_task_run growth vs the last main entry.
REGRESS_FRAC = 0.25


def load_json(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def runner_entries(trajectory, runner):
    """Trajectory entries from `runner`, oldest first (file order)."""
    return [e for e in trajectory.get("entries", []) if e.get("runner") == runner]


def cmd_floor(args):
    trajectory = load_json(args.trajectory)
    entries = runner_entries(trajectory, args.runner)
    samples = [
        e["suite_throughput_task_runs_per_s"]
        for e in entries
        if isinstance(e.get("suite_throughput_task_runs_per_s"), (int, float))
    ][-WINDOW:]
    if len(samples) < MIN_ENTRIES:
        print(
            f"floor basis: {len(samples)} same-runner entries for "
            f"{args.runner!r} (< {MIN_ENTRIES}); using bootstrap fallback "
            f"{FALLBACK_FLOOR}",
            file=sys.stderr,
        )
        print(FALLBACK_FLOOR)
        return 0
    med = statistics.median(samples)
    floor = FLOOR_FRAC * med
    print(
        f"floor basis: median of last {len(samples)} {args.runner!r} "
        f"entries = {med:.1f} task-runs/s; floor = {FLOOR_FRAC} x median "
        f"= {floor:.1f}",
        file=sys.stderr,
    )
    print(f"{floor:.1f}")
    return 0


def cmd_check_allocs(args):
    entry = load_json(args.entry)
    new = entry.get("allocs_per_task_run")
    if not isinstance(new, (int, float)):
        print(
            "alloc gate: SKIPPED — new entry has no allocs_per_task_run "
            "(bench not built with --features alloc-count)"
        )
        return 0
    trajectory = load_json(args.trajectory)
    baselines = [
        e["allocs_per_task_run"]
        for e in trajectory.get("entries", [])
        if isinstance(e.get("allocs_per_task_run"), (int, float))
    ]
    if not baselines:
        print(
            "alloc gate: SKIPPED — trajectory has no entry with an "
            "allocation count yet (empty trajectory bootstrap)"
        )
        return 0
    base = baselines[-1]
    limit = base * (1.0 + REGRESS_FRAC)
    if new > limit:
        print(
            f"alloc gate: FAIL — {new:.0f} allocs/task-run vs last main "
            f"entry {base:.0f} (> +{REGRESS_FRAC:.0%} limit {limit:.0f})"
        )
        return 1
    print(
        f"alloc gate: ok — {new:.0f} allocs/task-run vs last main entry "
        f"{base:.0f} (limit {limit:.0f})"
    )
    return 0


def cmd_append(args):
    entry = load_json(args.entry)
    stamped = {"date": args.date, "runner": args.runner}
    if args.floor_basis:
        stamped["floor_basis"] = args.floor_basis
    stamped.update(entry)
    trajectory = load_json(args.trajectory)
    trajectory.setdefault("entries", []).append(stamped)
    with open(args.trajectory, "w", encoding="utf-8") as f:
        json.dump(trajectory, f, indent=2)
        f.write("\n")
    print(f"appended entry dated {args.date} ({args.runner}) to {args.trajectory}")
    return 0


def main(argv):
    parser = argparse.ArgumentParser(description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_floor = sub.add_parser("floor", help="print the calibrated throughput floor")
    p_floor.add_argument("--trajectory", required=True)
    p_floor.add_argument("--runner", required=True)
    p_floor.set_defaults(run=cmd_floor)

    p_check = sub.add_parser("check-allocs", help="gate allocs_per_task_run")
    p_check.add_argument("--entry", required=True)
    p_check.add_argument("--trajectory", required=True)
    p_check.set_defaults(run=cmd_check_allocs)

    p_append = sub.add_parser("append", help="stamp + append an entry")
    p_append.add_argument("--entry", required=True)
    p_append.add_argument("--trajectory", required=True)
    p_append.add_argument("--runner", required=True)
    p_append.add_argument(
        "--date",
        default=datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d"),
    )
    p_append.add_argument(
        "--floor-basis",
        default="",
        help="how this run's throughput floor was derived (from `floor` stderr)",
    )
    p_append.set_defaults(run=cmd_append)

    args = parser.parse_args(argv)
    return args.run(args)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
