#!/usr/bin/env python3
"""Unit tests for perf_gate.py (stdlib unittest; run by the CI python job).

The focus is the bootstrap behavior a brand-new (or wiped) trajectory
file must get right: `floor` falls back to the conservative hard-coded
floor and says so, and `check-allocs` skips — never fails — while either
side of the comparison has no allocation count yet.
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import perf_gate  # noqa: E402


class PerfGateCase(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def write(self, name, obj):
        path = os.path.join(self.dir.name, name)
        with open(path, "w", encoding="utf-8") as f:
            json.dump(obj, f)
        return path

    def run_main(self, argv):
        """Run perf_gate.main capturing (exit code, stdout, stderr)."""
        out, err = io.StringIO(), io.StringIO()
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            code = perf_gate.main(argv)
        return code, out.getvalue(), err.getvalue()

    def test_floor_empty_trajectory_bootstraps_to_fallback(self):
        traj = self.write("traj.json", {"entries": []})
        code, out, err = self.run_main(
            ["floor", "--trajectory", traj, "--runner", "ci-x64"]
        )
        self.assertEqual(code, 0)
        self.assertEqual(float(out.strip()), perf_gate.FALLBACK_FLOOR)
        self.assertIn("bootstrap fallback", err)

    def test_floor_ignores_other_runners_below_min_entries(self):
        # 5 entries from a different runner must not calibrate this one.
        traj = self.write(
            "traj.json",
            {
                "entries": [
                    {"runner": "other", "suite_throughput_task_runs_per_s": 500.0}
                    for _ in range(5)
                ]
            },
        )
        code, out, _ = self.run_main(
            ["floor", "--trajectory", traj, "--runner", "ci-x64"]
        )
        self.assertEqual(code, 0)
        self.assertEqual(float(out.strip()), perf_gate.FALLBACK_FLOOR)

    def test_floor_calibrates_from_same_runner_median(self):
        traj = self.write(
            "traj.json",
            {
                "entries": [
                    {"runner": "ci-x64", "suite_throughput_task_runs_per_s": v}
                    for v in (80.0, 100.0, 120.0)
                ]
            },
        )
        code, out, err = self.run_main(
            ["floor", "--trajectory", traj, "--runner", "ci-x64"]
        )
        self.assertEqual(code, 0)
        self.assertAlmostEqual(
            float(out.strip()), perf_gate.FLOOR_FRAC * 100.0, places=1
        )
        self.assertIn("median", err)

    def test_check_allocs_skips_on_empty_trajectory(self):
        # The empty-trajectory bootstrap: a fresh entry WITH a count, a
        # trajectory with none — must skip with the bootstrap notice, not
        # fail or crash.
        entry = self.write("entry.json", {"allocs_per_task_run": 1234.0})
        traj = self.write("traj.json", {"entries": []})
        code, out, _ = self.run_main(
            ["check-allocs", "--entry", entry, "--trajectory", traj]
        )
        self.assertEqual(code, 0)
        self.assertIn("SKIPPED", out)
        self.assertIn("empty trajectory bootstrap", out)

    def test_check_allocs_skips_when_entry_has_no_count(self):
        entry = self.write("entry.json", {"suite_throughput_task_runs_per_s": 50.0})
        traj = self.write(
            "traj.json",
            {"entries": [{"runner": "ci-x64", "allocs_per_task_run": 1000.0}]},
        )
        code, out, _ = self.run_main(
            ["check-allocs", "--entry", entry, "--trajectory", traj]
        )
        self.assertEqual(code, 0)
        self.assertIn("SKIPPED", out)

    def test_check_allocs_gates_a_real_regression(self):
        traj = self.write(
            "traj.json",
            {"entries": [{"runner": "ci-x64", "allocs_per_task_run": 1000.0}]},
        )
        ok = self.write("ok.json", {"allocs_per_task_run": 1100.0})
        code, out, _ = self.run_main(
            ["check-allocs", "--entry", ok, "--trajectory", traj]
        )
        self.assertEqual(code, 0)
        self.assertIn("ok", out)
        bad = self.write("bad.json", {"allocs_per_task_run": 1500.0})
        code, out, _ = self.run_main(
            ["check-allocs", "--entry", bad, "--trajectory", traj]
        )
        self.assertEqual(code, 1)
        self.assertIn("FAIL", out)

    def test_append_stamps_and_preserves_entries(self):
        entry = self.write("entry.json", {"suite_throughput_task_runs_per_s": 42.0})
        traj = self.write("traj.json", {"entries": []})
        code, out, _ = self.run_main(
            [
                "append",
                "--entry", entry,
                "--trajectory", traj,
                "--runner", "ci-x64",
                "--date", "2026-08-08",
            ]
        )
        self.assertEqual(code, 0)
        self.assertIn("appended", out)
        with open(traj, encoding="utf-8") as f:
            data = json.load(f)
        self.assertEqual(len(data["entries"]), 1)
        e = data["entries"][0]
        self.assertEqual(e["runner"], "ci-x64")
        self.assertEqual(e["date"], "2026-08-08")
        self.assertEqual(e["suite_throughput_task_runs_per_s"], 42.0)


if __name__ == "__main__":
    unittest.main()
