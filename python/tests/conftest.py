"""Make `compile` importable whether pytest runs from repo root or python/.

Also degrade gracefully on partial environments: the kernel sweep tests need
`hypothesis`, and everything here needs `jax`; skip collection of what the
environment cannot support instead of erroring out.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore.append("test_kernel.py")
if importlib.util.find_spec("jax") is None:
    for name in ("test_kernel.py", "test_model_aot.py"):
        if name not in collect_ignore:
            collect_ignore.append(name)
