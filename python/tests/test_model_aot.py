"""L2/AOT tests: task registry integrity + HLO-text lowering invariants."""

import json
import os
import tempfile

import pytest

from compile import aot, model


class TestRegistry:
    def test_every_task_has_ref_variant(self):
        for task, entry in model.TASKS.items():
            assert "ref" in entry["variants"], task

    def test_every_task_has_nonref_variant(self):
        for task, entry in model.TASKS.items():
            assert len(entry["variants"]) >= 2, task

    def test_input_specs_are_static(self):
        for task, entry in model.TASKS.items():
            for spec in entry["inputs"]:
                assert all(isinstance(d, int) and d > 0 for d in spec.shape), task

    @pytest.mark.parametrize("task", list(model.TASKS))
    def test_variants_lower(self, task):
        # Lower the cheapest variant per task end-to-end (ref is pure jnp).
        lowered = model.lower_variant(task, "ref")
        assert lowered is not None


class TestHloText:
    def test_hlo_text_shape(self):
        lowered = model.lower_variant("softmax", "ref")
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ROOT" in text
        # return_tuple=True: the entry computation root must be a tuple so the
        # rust side's to_tuple1() unwrap works.
        assert "tuple(" in text

    def test_artifacts_exist_and_match_manifest(self):
        # `make artifacts` must have run before the test suite (Makefile
        # ordering); validate the manifest against the files on disk.
        art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        man_path = os.path.join(art, "manifest.json")
        if not os.path.exists(man_path):
            pytest.skip("artifacts not built")
        with open(man_path) as f:
            manifest = json.load(f)
        assert set(manifest["tasks"]) == set(model.TASKS)
        for task, entry in manifest["tasks"].items():
            assert set(entry["variants"]) == set(model.TASKS[task]["variants"])
            for v, meta in entry["variants"].items():
                path = os.path.join(art, meta["file"])
                assert os.path.exists(path), path
                assert os.path.getsize(path) > 100, path

    def test_aot_main_subset(self):
        # Drive the CLI path on the smallest task into a temp dir.
        import sys
        from unittest import mock

        with tempfile.TemporaryDirectory() as td:
            argv = ["aot", "--out-dir", td, "--tasks", "softmax"]
            with mock.patch.object(sys, "argv", argv):
                aot.main()
            with open(os.path.join(td, "manifest.json")) as f:
                manifest = json.load(f)
            assert list(manifest["tasks"]) == ["softmax"]
            assert os.path.exists(os.path.join(td, "softmax__ref.hlo.txt"))
