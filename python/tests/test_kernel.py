"""Kernel-vs-ref correctness: the CORE build-time signal for L1.

Every Pallas schedule point must be numerically equivalent to its pure-jnp
oracle; hypothesis sweeps shapes (and tile parameters where legal) so the
BlockSpec index maps are exercised off the happy path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_epilogue as fe
from compile.kernels import layernorm as ln
from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import softmax as sm

SETTINGS = dict(max_examples=12, deadline=None)


def _randn(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


# ---------------------------------------------------------------- matmul


class TestMatmul:
    def test_tiled_matches_ref(self, rng):
        x, w = _randn(rng, 128, 256), _randn(rng, 256, 192)
        np.testing.assert_allclose(
            mm.matmul_tiled(x, w, bm=64, bn=64, bk=64),
            ref.matmul_ref(x, w),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_naive_matches_ref(self, rng):
        x, w = _randn(rng, 64, 96), _randn(rng, 96, 128)
        np.testing.assert_allclose(
            mm.matmul_naive(x, w, bm=8, bn=64),
            ref.matmul_ref(x, w),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_tiled_rejects_nondividing_tiles(self, rng):
        x, w = _randn(rng, 100, 64), _randn(rng, 64, 64)
        with pytest.raises(AssertionError):
            mm.matmul_tiled(x, w, bm=64, bn=64, bk=64)

    @settings(**SETTINGS)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 4),
        ni=st.integers(1, 4),
        bm=st.sampled_from([16, 32]),
        bk=st.sampled_from([16, 32]),
        bn=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tiled_shape_sweep(self, mi, ki, ni, bm, bk, bn, seed):
        r = np.random.default_rng(seed)
        m, k, n = mi * bm, ki * bk, ni * bn
        x, w = _randn(r, m, k), _randn(r, k, n)
        np.testing.assert_allclose(
            mm.matmul_tiled(x, w, bm=bm, bn=bn, bk=bk),
            ref.matmul_ref(x, w),
            rtol=1e-3,
            atol=1e-3,
        )

    def test_vmem_footprint_formula(self):
        # 2*(bm*bk + bk*bn)*4 + bm*bn*4, f32
        assert mm.vmem_footprint_bytes(128, 128, 128) == (
            2 * (128 * 128 + 128 * 128) * 4 + 128 * 128 * 4
        )


# --------------------------------------------------------- fused epilogue


class TestFusedEpilogue:
    @pytest.mark.parametrize("variant", ["fused_naive", "tiled", "tiled_fused"])
    def test_variants_match_ref(self, rng, variant):
        x, w, b = _randn(rng, 128, 256), _randn(rng, 256, 256), _randn(rng, 256)
        np.testing.assert_allclose(
            fe.fused_epilogue(x, w, b, variant=variant),
            ref.fused_epilogue_ref(x, w, b),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_output_shape_is_column(self, rng):
        x, w, b = _randn(rng, 64, 128), _randn(rng, 128, 128), _randn(rng, 128)
        out = fe.fused_epilogue(x, w, b, variant="tiled_fused")
        assert out.shape == (64, 1)

    def test_clamp_saturation(self, rng):
        # Inputs large enough that clamp is active on every element; the
        # logsumexp then reduces a constant row: z = cmax + log(N).
        x = jnp.full((16, 32), 100.0, dtype=jnp.float32)
        w = jnp.full((32, 32), 1.0, dtype=jnp.float32)
        b = jnp.zeros((32,), dtype=jnp.float32)
        out = fe.fused_epilogue(x, w, b, variant="tiled_fused", bm=16, bn=32, bk=32, br=16)
        z = 10.0 + np.log(32.0)
        expected = z * (z * np.tanh(np.log1p(np.exp(z))))
        np.testing.assert_allclose(out, np.full((16, 1), expected), rtol=1e-5)

    def test_unknown_variant_raises(self, rng):
        x, w, b = _randn(rng, 16, 16), _randn(rng, 16, 16), _randn(rng, 16)
        with pytest.raises(ValueError):
            fe.fused_epilogue(x, w, b, variant="nope")

    @settings(**SETTINGS)
    @given(
        bi=st.integers(1, 3),
        scale=st.floats(0.1, 2.0),
        cmax=st.floats(1.0, 20.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_param_sweep(self, bi, scale, cmax, seed):
        r = np.random.default_rng(seed)
        batch = 64 * bi
        x, w, b = _randn(r, batch, 128), _randn(r, 128, 128), _randn(r, 128)
        got = fe.fused_epilogue(
            x, w, b, variant="tiled_fused", scale=scale, clamp_min=-cmax, clamp_max=cmax
        )
        want = ref.fused_epilogue_ref(
            x, w, b, scale=scale, clamp_min=-cmax, clamp_max=cmax
        )
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


# ----------------------------------------------------- softmax / layernorm


class TestRowKernels:
    @settings(**SETTINGS)
    @given(
        ri=st.integers(1, 4),
        cols=st.sampled_from([8, 64, 200, 512]),
        br=st.sampled_from([16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_softmax_sweep(self, ri, cols, br, seed):
        r = np.random.default_rng(seed)
        x = _randn(r, ri * br, cols)
        np.testing.assert_allclose(
            sm.softmax_rows(x, br=br), ref.softmax_ref(x), rtol=1e-5, atol=1e-6
        )

    def test_softmax_rows_sum_to_one(self, rng):
        x = _randn(rng, 64, 100)
        out = np.asarray(sm.softmax_rows(x))
        np.testing.assert_allclose(out.sum(axis=-1), np.ones(64), rtol=1e-5)

    def test_softmax_stable_large_inputs(self, rng):
        x = _randn(rng, 64, 64) * 1e4
        out = np.asarray(sm.softmax_rows(x))
        assert np.isfinite(out).all()

    @settings(**SETTINGS)
    @given(
        ri=st.integers(1, 4),
        cols=st.sampled_from([16, 128, 300]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_layernorm_sweep(self, ri, cols, seed):
        r = np.random.default_rng(seed)
        x = _randn(r, ri * 32, cols)
        g, b = _randn(r, cols), _randn(r, cols)
        np.testing.assert_allclose(
            ln.layernorm_rows(x, g, b, br=32),
            ref.layernorm_ref(x, g, b),
            rtol=1e-3,
            atol=1e-3,
        )

    def test_layernorm_normalizes(self, rng):
        x = _randn(rng, 32, 256) * 5.0 + 3.0
        g, b = jnp.ones((256,)), jnp.zeros((256,))
        out = np.asarray(ln.layernorm_rows(x, g, b, br=32))
        np.testing.assert_allclose(out.mean(axis=-1), np.zeros(32), atol=1e-4)
        np.testing.assert_allclose(out.std(axis=-1), np.ones(32), rtol=1e-2)


# ------------------------------------------------------------- attention


class TestAttention:
    @settings(**SETTINGS)
    @given(
        si=st.integers(1, 4),
        d=st.sampled_from([16, 32, 64]),
        br=st.sampled_from([16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_attention_sweep(self, si, d, br, seed):
        from compile.kernels import attention as attn

        r = np.random.default_rng(seed)
        s = si * 64
        q, k, v = _randn(r, s, d), _randn(r, s, d), _randn(r, s, d)
        np.testing.assert_allclose(
            attn.attention(q, k, v, br=br),
            ref.attention_ref(q, k, v),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_attention_rows_are_convex_combinations(self, rng):
        from compile.kernels import attention as attn

        # With V = identity-ish rows, outputs are convex combinations:
        # bounded by V's min/max per column.
        q, k = _randn(rng, 64, 32), _randn(rng, 64, 32)
        v = _randn(rng, 64, 32)
        out = np.asarray(attn.attention(q, k, v))
        assert out.min() >= np.asarray(v).min() - 1e-5
        assert out.max() <= np.asarray(v).max() + 1e-5

    def test_attention_block_must_divide(self, rng):
        from compile.kernels import attention as attn

        q, k, v = _randn(rng, 100, 32), _randn(rng, 100, 32), _randn(rng, 100, 32)
        with pytest.raises(AssertionError):
            attn.attention(q, k, v, br=64)
