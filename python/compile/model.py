"""L2: the artifact-backed KernelBenchSim tasks as JAX compute graphs.

Each task is a named registry entry with:
  * ``inputs``   — list of example-arg specs,
  * ``variants`` — mapping variant name -> jax callable (calls kernels.*),
    always including ``"ref"`` (the pure-jnp oracle / Torch-Eager stand-in).

``aot.py`` lowers every (task, variant) pair to HLO text; the rust runtime
loads them, verifies each variant against ``ref`` on seeded inputs, and times
them. Python never runs after `make artifacts`.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import attention as attn
from .kernels import fused_epilogue as fe
from .kernels import layernorm as ln
from .kernels import matmul as mm
from .kernels import ref
from .kernels import softmax as sm

F32 = jnp.float32

# Problem sizes are scaled from the paper's A100 shapes (1024x8192x8192) to
# CPU-tractable ones; the schedule-space structure (dominant GEMM, fusable
# epilogue, row reductions) is preserved. DESIGN.md §Substitutions.
MATMUL_M, MATMUL_K, MATMUL_N = 256, 512, 512
EPI_B, EPI_K, EPI_N = 256, 512, 512
SM_ROWS, SM_COLS = 512, 512
ATTN_S, ATTN_D = 256, 64
LN_ROWS, LN_COLS = 512, 512


def _spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


TASKS = {
    "matmul": {
        "inputs": [_spec(MATMUL_M, MATMUL_K), _spec(MATMUL_K, MATMUL_N)],
        "variants": {
            "ref": ref.matmul_ref,
            "naive": mm.matmul_naive,
            "tiled_64": functools.partial(mm.matmul_tiled, bm=64, bn=64, bk=64),
            "tiled_128": functools.partial(mm.matmul_tiled, bm=128, bn=128, bk=128),
        },
    },
    "fused_epilogue": {
        "inputs": [_spec(EPI_B, EPI_K), _spec(EPI_K, EPI_N), _spec(EPI_N)],
        "variants": {
            "ref": ref.fused_epilogue_ref,
            "fused_naive": functools.partial(fe.fused_epilogue, variant="fused_naive"),
            "tiled": functools.partial(fe.fused_epilogue, variant="tiled"),
            "tiled_fused": functools.partial(fe.fused_epilogue, variant="tiled_fused"),
        },
    },
    "attention": {
        "inputs": [
            _spec(ATTN_S, ATTN_D),
            _spec(ATTN_S, ATTN_D),
            _spec(ATTN_S, ATTN_D),
        ],
        "variants": {
            "ref": ref.attention_ref,
            "rowblock": attn.attention,
        },
    },
    "softmax": {
        "inputs": [_spec(SM_ROWS, SM_COLS)],
        "variants": {
            "ref": ref.softmax_ref,
            "rowblock": sm.softmax_rows,
        },
    },
    "layernorm": {
        "inputs": [_spec(LN_ROWS, LN_COLS), _spec(LN_COLS), _spec(LN_COLS)],
        "variants": {
            "ref": ref.layernorm_ref,
            "rowblock": ln.layernorm_rows,
        },
    },
}


def lower_variant(task: str, variant: str):
    """jit + lower one (task, variant) against its example-arg specs."""
    entry = TASKS[task]
    fn = entry["variants"][variant]

    # Wrap so the output is always a 1-tuple (the rust side unwraps to_tuple1).
    def wrapped(*args):
        return (fn(*args),)

    return jax.jit(wrapped).lower(*entry["inputs"])
