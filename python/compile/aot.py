"""AOT-lower every (task, variant) to HLO *text* + write a manifest.

HLO text (NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published ``xla``
0.1.6 rust crate links) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tasks", nargs="*", default=None, help="subset of task names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"tasks": {}}
    names = args.tasks or list(model.TASKS)
    for task in names:
        entry = model.TASKS[task]
        inputs = [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in entry["inputs"]
        ]
        variants = {}
        for variant in entry["variants"]:
            lowered = model.lower_variant(task, variant)
            text = to_hlo_text(lowered)
            fname = f"{task}__{variant}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            variants[variant] = {"file": fname, "hlo_chars": len(text)}
            print(f"  {task}/{variant}: {len(text)} chars -> {fname}")
        manifest["tasks"][task] = {"inputs": inputs, "variants": variants}

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest for {len(names)} tasks to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
