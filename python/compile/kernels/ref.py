"""Pure-jnp reference oracles (the correctness ground truth for every kernel).

These functions define the semantics that every Pallas variant must match
(pytest asserts allclose at build time; the rust Verifier re-checks the AOT
artifacts against the reference artifact at run time).
"""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul: (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def mish(x: jax.Array) -> jax.Array:
    """Mish activation: x * tanh(softplus(x))."""
    return x * jnp.tanh(jax.nn.softplus(x))


def fused_epilogue_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    scale: float = 0.5,
    clamp_min: float = -10.0,
    clamp_max: float = 10.0,
) -> jax.Array:
    """The KernelSkill Appendix-D task (KernelBench L2 style).

    linear -> scale -> residual double -> clamp -> logsumexp(dim=1) -> x*mish(x)

    x: (B, K) activations, w: (K, N) weight (already transposed from the
    nn.Linear (N, K) layout), b: (N,) bias. Returns (B, 1).
    """
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b
    y = y * scale
    y = y + y
    y = jnp.clip(y, clamp_min, clamp_max)
    z = jax.scipy.special.logsumexp(y, axis=1, keepdims=True)
    return z * mish(z)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Single-head scaled-dot-product attention oracle: (S,d) x3 -> (S,d)."""
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.asarray(d, dtype=jnp.float32)
    )
    return jnp.matmul(
        jax.nn.softmax(scores, axis=-1), v, preferred_element_type=jnp.float32
    )


def softmax_ref(x: jax.Array) -> jax.Array:
    """Numerically-stable row softmax over the last dim."""
    return jax.nn.softmax(x, axis=-1)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Row LayerNorm over the last dim."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta
