"""L1: Pallas kernels for the paper's compute hot-spots (interpret=True).

Modules:
  * ``matmul``         — naive vs VMEM-tiled GEMM schedules
  * ``fused_epilogue`` — the Appendix-D task at three schedule points
  * ``attention``      — row-blocked flash-style attention
  * ``softmax``        — row-blocked softmax
  * ``layernorm``      — row-blocked LayerNorm
  * ``ref``            — pure-jnp oracles
"""

from . import attention, fused_epilogue, layernorm, matmul, ref, softmax  # noqa: F401
