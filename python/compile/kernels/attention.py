"""L1 Pallas attention kernel: softmax(Q K^T / sqrt(d)) V, row-blocked.

The flash-attention insight on TPU terms: keep a (br, S) score strip and the
full K/V panels resident in VMEM per grid step — one HBM round-trip for Q and
O instead of materializing the (S, S) score matrix in HBM. This is the
`FuseEpilogueReduction` + `WarpReduceShuffle` method pair applied to the
attention sub-graph (the L3 transformer tasks' hot pattern).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[...]  # (br, d)
    k = k_ref[...]  # (S, d)
    v = v_ref[...]  # (S, d)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, br: int = 64) -> jax.Array:
    """Single-head attention, row-blocked over queries.

    q, k, v: (S, d) f32. Returns (S, d).
    """
    s, d = q.shape
    rb = min(br, s)
    assert s % rb == 0, f"row block {rb} must divide sequence {s}"
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        lambda qr, kr, vr, orf: _attn_kernel(qr, kr, vr, orf, scale=scale),
        grid=(s // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(q, k, v)
