"""L1 Pallas kernels for the Appendix-D fused-epilogue task.

The task (KernelBench Level-2 style):

    linear -> *scale -> +residual(double) -> clamp -> logsumexp(dim=1) -> x*mish(x)

Three schedule points, matching the optimization trajectory the paper
describes in its motivating example (§3):

  * ``fused_naive``  — what the memory-free optimizer produced: GEMM + scale +
    double + clamp fused into ONE kernel, but the GEMM itself is the naive
    no-reuse schedule; logsumexp/mish left unfused. (The 0.032x kernel.)
  * ``tiled``        — what KernelSkill's long-term memory recommends first:
    fix the dominant GEMM bottleneck with VMEM tiling; epilogue stays unfused.
  * ``tiled_fused``  — the coupled follow-up: tiled GEMM, then the whole
    elementwise + row-reduction epilogue fused into a single row-blocked
    kernel (one HBM round-trip for the activation matrix).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm


def _fit_tile(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is <= pref (schedule legality helper)."""
    t = min(pref, dim)
    while dim % t:
        t -= 1
    return t


def _mish(x):
    return x * jnp.tanh(jax.nn.softplus(x))


def _epilogue_elementwise(y, b, scale, clamp_min, clamp_max):
    y = (y + b) * scale
    y = y + y
    return jnp.clip(y, clamp_min, clamp_max)


def _fused_naive_kernel(x_ref, w_ref, b_ref, o_ref, *, scale, clamp_min, clamp_max):
    """Naive GEMM fused with bias/scale/double/clamp — the paper's bad kernel."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _epilogue_elementwise(acc, b_ref[...], scale, clamp_min, clamp_max)


def _rowblock_lse_mish_kernel(y_ref, o_ref):
    """Row-blocked logsumexp + x*mish(x): one pass over a (br, N) strip."""
    y = y_ref[...]
    m = jnp.max(y, axis=1, keepdims=True)
    z = m + jnp.log(jnp.sum(jnp.exp(y - m), axis=1, keepdims=True))
    o_ref[...] = z * _mish(z)


def fused_epilogue(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    variant: str = "tiled_fused",
    scale: float = 0.5,
    clamp_min: float = -10.0,
    clamp_max: float = 10.0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    br: int = 64,
) -> jax.Array:
    """Dispatch over the three schedule points. Shapes: x (B,K), w (K,N), b (N,)."""
    batch, _ = x.shape
    _, n = w.shape
    b2 = jnp.broadcast_to(b, (1, n))

    if variant == "fused_naive":
        # One kernel: naive GEMM (+epilogue elementwise); tiny output blocks,
        # full-K strips re-streamed per block. logsumexp/mish left in jnp.
        gm, gn = 8, min(128, n)
        y = pl.pallas_call(
            lambda xr, wr, br_, or_: _fused_naive_kernel(
                xr, wr, br_, or_, scale=scale, clamp_min=clamp_min, clamp_max=clamp_max
            ),
            grid=(batch // gm, n // gn),
            in_specs=[
                pl.BlockSpec((gm, x.shape[1]), lambda i, j: (i, 0)),
                pl.BlockSpec((x.shape[1], gn), lambda i, j: (0, j)),
                pl.BlockSpec((1, gn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((gm, gn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
            interpret=True,
        )(x, w, b2)
        z = jax.scipy.special.logsumexp(y, axis=1, keepdims=True)
        return z * _mish(z)

    if variant in ("tiled", "tiled_fused"):
        y = mm.matmul_tiled(
            x,
            w,
            bm=_fit_tile(batch, bm),
            bn=_fit_tile(n, bn),
            bk=_fit_tile(x.shape[1], bk),
        )
        if variant == "tiled":
            y = _epilogue_elementwise(y, b2, scale, clamp_min, clamp_max)
            z = jax.scipy.special.logsumexp(y, axis=1, keepdims=True)
            return z * _mish(z)
        # tiled_fused: elementwise epilogue + row reduction in ONE row-blocked
        # pallas kernel (a single HBM round-trip over the (B, N) activation).
        rb = _fit_tile(batch, br)

        def _kernel(y_ref, b_ref, o_ref):
            yy = _epilogue_elementwise(
                y_ref[...], b_ref[...], scale, clamp_min, clamp_max
            )
            m = jnp.max(yy, axis=1, keepdims=True)
            z = m + jnp.log(jnp.sum(jnp.exp(yy - m), axis=1, keepdims=True))
            o_ref[...] = z * _mish(z)

        return pl.pallas_call(
            _kernel,
            grid=(batch // rb,),
            in_specs=[
                pl.BlockSpec((rb, n), lambda i: (i, 0)),
                pl.BlockSpec((1, n), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((batch, 1), jnp.float32),
            interpret=True,
        )(y, b2)

    raise ValueError(f"unknown variant {variant!r}")
