"""L1 Pallas row-softmax kernel (KernelBench Level-1 style reduction op)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_rows(x: jax.Array, *, br: int = 64) -> jax.Array:
    """Numerically-stable softmax over the last dim, row-blocked.

    Each grid step owns a (br, N) strip in VMEM: one load, one store —
    the single-pass schedule the long-term memory's 'reduction fusion'
    method prescribes for memory-bound row reductions.
    """
    rows, cols = x.shape
    rb = min(br, rows)
    assert rows % rb == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
