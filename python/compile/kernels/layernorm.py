"""L1 Pallas LayerNorm kernel (KernelBench Level-1 style normalization op)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


def layernorm_rows(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5, br: int = 64
) -> jax.Array:
    """Row LayerNorm, row-blocked: mean/var/normalize in one VMEM pass."""
    rows, cols = x.shape
    rb = min(br, rows)
    assert rows % rb == 0
    g2 = jnp.broadcast_to(gamma, (1, cols))
    b2 = jnp.broadcast_to(beta, (1, cols))
    return pl.pallas_call(
        lambda xr, gr, br_, or_: _layernorm_kernel(xr, gr, br_, or_, eps=eps),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x, g2, b2)
