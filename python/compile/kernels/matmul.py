"""L1 Pallas matmul kernels — the GEMM hot-spot at several schedule points.

These are the concrete realizations of the schedule space the rust Kernel IR
(`kir::schedule`) explores: the *naive* variant is the motivating-example
failure mode (tiny blocks, full-K dot per block, no reuse across the grid),
and the *tiled* variant is the MXU/VMEM-blocked schedule KernelSkill's
long-term memory recommends for a compute-bound GEMM.

TPU adaptation (DESIGN.md §Hardware-Adaptation): CUDA shared-memory tiling
becomes VMEM blocking via BlockSpec; tensor-core WMMA becomes an MXU dot with
`preferred_element_type=f32`. All kernels lower with interpret=True so the
resulting HLO runs on any PJRT backend (the rust CPU client included).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel_accum(x_ref, w_ref, o_ref):
    """Grid-(i, j, k) block matmul with accumulation along the k axis.

    The k grid dimension is innermost, so o_ref for a fixed (i, j) block is
    revisited across k steps — zero-init on the first step, accumulate after.
    This is the double-buffered HBM<->VMEM pipeline expressed as a BlockSpec
    schedule (the Pallas grid machinery overlaps the copies).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_tiled(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """VMEM-blocked matmul: (M, K) @ (K, N) -> (M, N) with (bm, bn, bk) tiles.

    Block shapes must divide the problem shape (the rust legality checker
    enforces the same precondition before proposing this schedule).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tile ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel_accum,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _matmul_kernel_naive(x_ref, w_ref, o_ref):
    """One tiny output block; the full K strip is re-read for every block."""
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def matmul_naive(x: jax.Array, w: jax.Array, *, bm: int = 8, bn: int = 128) -> jax.Array:
    """The motivating-example GEMM: no K blocking, no reuse across blocks.

    Every (bm, bn) output block re-streams its full (bm, K) x-strip and
    (K, bn) w-strip from HBM — the 'naive global-memory dot-product loop'
    of the paper's Appendix D failure case, expressed as a BlockSpec.
    """
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel_naive,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@functools.lru_cache(maxsize=None)
def vmem_footprint_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Estimated per-step VMEM residency of the tiled schedule (both live
    input blocks double-buffered + the output accumulator block).

    Mirrors rust `device::costmodel::vmem_footprint` — kept here so pytest
    can assert the two implementations agree on the artifact variants.
    """
    x_blk = bm * bk * itemsize
    w_blk = bk * bn * itemsize
    o_blk = bm * bn * itemsize
    return 2 * (x_blk + w_blk) + o_blk
