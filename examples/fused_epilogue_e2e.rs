//! E5 — the end-to-end driver on a REAL workload (DESIGN.md §End-to-end).
//!
//! This is the motivating example (§3 / Appendix D) run through the full
//! stack with the real artifact path in the loop:
//!
//!   * the three Pallas schedule points of the task (naive-GEMM fusion /
//!     tiled GEMM / tiled GEMM + fused epilogue) are loaded from
//!     `artifacts/` and executed via PJRT — the Verifier check is REAL
//!     numerics against the reference artifact, and latencies are REAL
//!     wall-clock measurements of the compiled HLO;
//!   * the KernelSkill loop then replays the same optimization story on the
//!     paper-scale task (1024x8192x8192), showing the decision policy
//!     targets the GEMM before fusion — the opposite of the memory-free
//!     optimizer's 0.032x failure;
//!   * the device model reports the A100-projected latency of each stage.
//!
//! Record of a run lives in EXPERIMENTS.md §E5.

use kernelskill::baselines;
use kernelskill::bench_suite::{self, eager};
use kernelskill::coordinator::{self, Branch, LoopConfig};
use kernelskill::device::costmodel;
use kernelskill::device::machine::DeviceSpec;
use kernelskill::kir::schedule::Schedule;
use kernelskill::kir::transforms::{self, MethodId};
use kernelskill::runtime::{verify_variant, Registry, Runtime};

fn main() -> kernelskill::util::error::Result<()> {
    println!("== stage 1: real artifacts (CPU PJRT; numerics + measured latency) ==");
    let reg = Registry::load("artifacts")?;
    let mut rt = Runtime::new("artifacts")?;
    let mut measured = Vec::new();
    for variant in ["ref", "fused_naive", "tiled", "tiled_fused"] {
        let rep = verify_variant(&mut rt, &reg, "fused_epilogue", variant, 7, 1e-3, true)?;
        println!(
            "  {:<14} verified={} max_abs_err={:.2e} measured={:.3} ms",
            variant,
            rep.passed,
            rep.max_abs_err,
            rep.latency_s.unwrap_or(0.0) * 1e3
        );
        assert!(rep.passed);
        measured.push((variant, rep.latency_s.unwrap_or(0.0)));
    }
    println!(
        "  (CPU latencies validate the AOT bridge; the performance *landscape*\n   below is the device model — DESIGN.md §Substitutions)\n"
    );

    println!("== stage 2: A100-projected landscape of the same schedule points ==");
    let dev = DeviceSpec::a100_like();
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks.iter().find(|t| t.id.contains("fused_epilogue")).unwrap();
    let stages: [(&str, &[MethodId]); 4] = [
        ("naive seed (per-op)", &[]),
        (
            "fused_naive (the 0.032x kernel: fusion, naive GEMM)",
            &[MethodId::FuseElementwise],
        ),
        ("tiled GEMM first (KernelSkill's move)", &[MethodId::TileSmem]),
        (
            "tiled+MXU+fused epilogue",
            &[
                MethodId::TileSmem,
                MethodId::UseTensorCore,
                MethodId::VectorizeLoads,
                MethodId::DoubleBuffer,
                MethodId::PadScratch,
                MethodId::FuseEpilogueReduction,
                MethodId::WarpReduceShuffle,
            ],
        ),
    ];
    for (name, methods) in stages {
        let mut sched = Schedule::per_op_naive(&task.graph);
        for &m in methods {
            if transforms::applicable(m, &task.graph, &sched).is_ok() {
                transforms::apply(m, &task.graph, &mut sched);
            }
        }
        let sp = eager::speedup(task, &sched, &dev);
        let cost = costmodel::price(&task.graph, &sched, &dev);
        println!(
            "  {:<52} {:>8.3}x vs eager  ({:.0} us, {} kernels)",
            name,
            sp,
            cost.total_s * 1e6,
            sched.num_kernels()
        );
    }
    println!();

    println!("== stage 3: the closed loop end-to-end ==");
    let result = coordinator::run_task(task, &baselines::kernelskill(), &LoopConfig::default());
    let first_opt = result.rounds.iter().find_map(|r| match r.branch {
        Branch::Optimize(m) => Some(m),
        _ => None,
    });
    println!(
        "  first optimization move: {:?} (the paper's point: GEMM before fusion)",
        first_opt.map(|m| m.name())
    );
    println!(
        "  seed {:.3?}x -> best {:.3}x in {} rounds ({} repairs)",
        result.seed_speedup, result.best_speedup, result.rounds_used, result.repair_attempts
    );
    assert_eq!(first_opt, Some(MethodId::TileSmem));

    // TPU estimate for §Perf (interpret=True gives no real TPU timing).
    let (vmem, mxu) = costmodel::tpu_perf_estimate(&task.graph, &result.best_sched);
    println!(
        "  TPU projection of the winning schedule: VMEM footprint {} KiB, MXU util {:.1}%",
        vmem / 1024,
        mxu * 100.0
    );
    Ok(())
}
