//! Repair-storm study (Figure 2's mechanism): inject a nightmare
//! translation and watch the Diagnoser with vs without short-term repair
//! memory — the memory-backed chain converges (no revisits of known-failing
//! fixes), the memory-less one oscillates.
//!
//! Usage: cargo run --release --example repair_storm [n_trials]

use kernelskill::agents::policy::PolicyProfile;
use kernelskill::agents::{diagnoser, repairer, KernelState};
use kernelskill::device::faults::{Fault, FaultKind};
use kernelskill::kir::graph::KernelGraph;
use kernelskill::kir::op::OpKind;
use kernelskill::kir::schedule::Schedule;
use kernelskill::kir::transforms::MethodId;
use kernelskill::memory::short_term::{RepairAttempt, RepairMemory};
use kernelskill::util::rng::Rng;
use kernelskill::util::stats;

fn storm(seed: u64, with_memory: bool, budget: u32) -> (bool, u32) {
    let mut rng = Rng::new(seed);
    let mut g = KernelGraph::new();
    g.push(OpKind::MatMul, 512, 512, 512, vec![]);
    let mut state = KernelState::new(Schedule::per_op_naive(&g), 0);
    // Three hard translation faults (a broken whole-model translation).
    for i in 0..3u8 {
        let n = 4 + (i % 3);
        state.faults.push(Fault {
            kind: if i == 0 {
                FaultKind::CompileSyntax
            } else {
                FaultKind::WrongNumerics
            },
            injected_by: MethodId::LaunchTune,
            signature: format!("translation defect #{i}"),
            true_fix: rng.range(0, n as u64) as u8,
            n_candidate_fixes: n,
            hard: true,
        });
    }
    let policy = PolicyProfile::chatgpt51();
    let mut mem = RepairMemory::new();
    let mut version = 1;
    for round in 1..=budget {
        let Some(fault) = state.faults.first().cloned() else {
            return (true, round - 1);
        };
        if with_memory {
            mem.open_chain(state.version);
        }
        let plan = diagnoser::diagnose(&fault, with_memory.then_some(&mem), &policy, &mut rng);
        version += 1;
        let mut p = policy.clone();
        if with_memory {
            p.repair_skill = (p.repair_skill + 0.25).min(1.0);
        }
        let result = repairer::execute(&state, &plan, &p, version, &mut rng);
        mem.record(RepairAttempt {
            error_signature: plan.error_signature,
            fix_idx: plan.fix_idx,
            fixed: result.fixed,
            kernel_version: version,
            round,
        });
        state = result.state;
        if state.is_clean() {
            return (true, round);
        }
    }
    (false, budget)
}

fn main() {
    let trials: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let budget = 15;
    for with_memory in [true, false] {
        let mut rounds = Vec::new();
        let mut fixed = 0u64;
        for t in 0..trials {
            let (ok, r) = storm(1000 + t, with_memory, budget);
            if ok {
                fixed += 1;
                rounds.push(r as f64);
            }
        }
        println!(
            "{:<22} fixed {:>4}/{} within {budget} rounds; mean rounds-to-fix {:.2}",
            if with_memory {
                "WITH repair memory"
            } else {
                "WITHOUT repair memory"
            },
            fixed,
            trials,
            stats::mean(&rounds),
        );
    }
    println!("\n(the gap above is Table 2's success-rate mechanism: 100% vs 94-98%)");
}
