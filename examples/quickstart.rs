//! Quickstart: the whole system in ~60 lines.
//!
//! 1. Verify the AOT Pallas artifacts through the real PJRT runtime
//!    (python authored them at build time; rust executes them here).
//! 2. Run KernelSkill's closed loop on the paper's Appendix-D task and
//!    print the audited trajectory.
//!
//! Run with: cargo run --release --example quickstart

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, Branch, LoopConfig};
use kernelskill::runtime::{self, Registry, Runtime};

fn main() -> kernelskill::util::error::Result<()> {
    // ---- 1. real AOT path: load + verify every Pallas variant ----------
    let reg = Registry::load("artifacts")?;
    let mut rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let reports = runtime::verify_all(&mut rt, &reg, 7, 1e-3)?;
    for r in &reports {
        println!(
            "  {:<16} {:<14} max_abs_err={:.2e} {}",
            r.task,
            r.variant,
            r.max_abs_err,
            if r.passed { "ok" } else { "FAIL" }
        );
    }
    assert!(reports.iter().all(|r| r.passed), "artifact verification failed");
    println!("all {} Pallas variants match their pure-jnp references\n", reports.len());

    // ---- 2. the multi-agent loop on the motivating example -------------
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks
        .iter()
        .find(|t| t.id.contains("fused_epilogue"))
        .expect("appendix-D task");
    println!(
        "optimizing {} ({} ops, dominant GEMM share {:.1}%)",
        task.id,
        task.graph.len(),
        task.graph.dominant_flop_fraction() * 100.0
    );
    let result = coordinator::run_task(task, &baselines::kernelskill(), &LoopConfig::default());
    for rec in &result.rounds {
        let what = match &rec.branch {
            Branch::Optimize(m) => format!("optimize[{}]", m.name()),
            Branch::Repair(f) => format!("repair[fix {f}]"),
            Branch::Revert => "revert".into(),
            Branch::Converged => "converged".into(),
        };
        println!(
            "  round {:>2}: {:<28} {}",
            rec.round,
            what,
            rec.speedup
                .map(|s| format!("{s:.3}x vs eager"))
                .unwrap_or_else(|| "broken (repair queued)".into())
        );
    }
    println!(
        "\nseed {:.3?}x -> best {:.3}x over Torch Eager ({} base promotions)",
        result.seed_speedup, result.best_speedup, result.promotions
    );
    Ok(())
}
