//! Suite sweep: run the full 250-task KernelBenchSim suite for a chosen
//! strategy across several seeds and report per-level metrics plus the
//! speedup distribution (the data behind Tables 1-3).
//!
//! Usage: cargo run --release --example suite_sweep [strategy] [n_seeds]

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, LoopConfig};
use kernelskill::harness::metrics;
use kernelskill::util::{pool, stats};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("KernelSkill");
    let n_seeds: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);

    let strategy = baselines::table1_roster()
        .into_iter()
        .chain(baselines::table2_roster())
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .unwrap_or_else(|| {
            eprintln!("unknown strategy {name}; using KernelSkill");
            baselines::kernelskill()
        });

    let tasks = bench_suite::full_suite(42);
    let seeds: Vec<u64> = (0..n_seeds).collect();
    println!(
        "running {} over {} tasks x {} seeds on {} workers...",
        strategy.name,
        tasks.len(),
        seeds.len(),
        pool::default_workers()
    );
    let suite = coordinator::run_suite(
        &tasks,
        &strategy,
        &LoopConfig::default(),
        &seeds,
        pool::default_workers(),
    );

    let split = metrics::by_level(&suite.results);
    for (i, lv) in split.iter().enumerate() {
        let c = metrics::cell(lv, strategy.rounds);
        let speeds: Vec<f64> = lv.iter().map(|r| r.best_speedup).collect();
        println!(
            "L{}: n={:<4} success={:.2} mean={:.2}x median={:.2}x p90={:.2}x max={:.2}x fast1={:.2}",
            i + 1,
            c.n,
            c.success,
            c.speedup,
            stats::median(&speeds),
            stats::percentile(&speeds, 90.0),
            speeds.iter().fold(0.0f64, |a, &b| a.max(b)),
            c.fast1,
        );
    }

    // Top wins + misses for inspection.
    let mut all: Vec<&coordinator::TaskResult> = suite.results.iter().collect();
    all.sort_by(|a, b| b.best_speedup.partial_cmp(&a.best_speedup).unwrap());
    println!("\ntop 5 wins:");
    for r in all.iter().take(5) {
        println!("  {:<28} {:.2}x", r.task_id, r.best_speedup);
    }
    println!("bottom 5 (incl. failures):");
    for r in all.iter().rev().take(5) {
        println!(
            "  {:<28} {:.2}x success={}",
            r.task_id, r.best_speedup, r.success
        );
    }
}
