//! E2 — regenerate Table 2 (memory ablations: Success / Fast1 / Speedup).
//! `cargo bench --bench table2_ablation`.

use kernelskill::harness::bench::time_once;
use kernelskill::harness::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let ((rendered, rows), timing) =
        time_once("table2(ablations)", || experiments::table2(&cfg).expect("table2 run failed"));
    println!("Table 2 — Ablation results (paper Table 2)");
    println!("{rendered}");
    println!("[{}]", timing.report());

    let get = |name: &str| rows.iter().find(|r| r.method == name).unwrap();
    let full = get("KernelSkill");
    let wo_mem = get("w/o memory");
    let wo_lt = get("w/o Long_term memory");
    for lvl in 0..3 {
        assert!(
            full.cells[lvl].speedup > wo_mem.cells[lvl].speedup,
            "memory must help speedup on L{}",
            lvl + 1
        );
        assert!(
            full.cells[lvl].speedup > wo_lt.cells[lvl].speedup,
            "long-term memory must drive speedup on L{}",
            lvl + 1
        );
    }
    // The long-term memory is the speedup driver (paper §5.5): removing it
    // costs much more speedup than removing the short-term memory.
    let wo_st = get("w/o Short_term memory");
    assert!(
        wo_st.cells[0].speedup > wo_lt.cells[0].speedup,
        "LT memory drives L1 speedup"
    );
    println!("shape checks passed: both memories matter; LT memory drives speedup");
}
