//! E3 — regenerate Table 3 (Fast_1: fraction of tasks at least as fast as
//! the Torch baseline). `cargo bench --bench table3_fast1`.

use kernelskill::harness::bench::time_once;
use kernelskill::harness::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let ((rendered, rows), timing) =
        time_once("table3(fast1)", || experiments::table3(&cfg).expect("table3 run failed"));
    println!("Table 3 — Fast_1 (paper Table 3)");
    println!("{rendered}");
    println!("[{}]", timing.report());

    let get = |name: &str| rows.iter().find(|r| r.method == name).unwrap();
    let ks = get("KernelSkill");
    // L2 Fast1 ~1.00 in the paper: fusion always clears parity.
    assert!(ks.cells[1].fast1 > 0.9, "KernelSkill L2 fast1 ~1.0");
    // L1/L3 keep structural misses (library-parity tasks below 1.0x).
    assert!(ks.cells[0].fast1 < 1.0 && ks.cells[2].fast1 < 1.0);
    println!("shape checks passed: L2 fast1 ~1.0 with structural L1/L3 misses");
}
