//! E7 — design-choice ablations the paper's §5.3 configuration implies:
//!   * rt/at base-promotion threshold sweep (why 0.3/0.3),
//!   * seed-count sweep (why 3 Generator samples),
//!   * round-budget sweep (why 15 rounds suffice),
//!   * device-preset robustness (A100-like vs TPU-like ordering),
//!   * fast_p sweep (KernelBench's general metric).
//! `cargo bench --bench ablation_sweeps`.

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, LoopConfig};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::bench::time_once;
use kernelskill::harness::metrics;
use kernelskill::util::pool;

fn mean_speedup(results: &[coordinator::TaskResult]) -> f64 {
    results.iter().map(|r| r.best_speedup).sum::<f64>() / results.len() as f64
}

fn main() {
    let workers = pool::default_workers();
    let tasks: Vec<_> = bench_suite::full_suite(42)
        .into_iter()
        .filter(|t| t.level == 2 || t.level == 1)
        .collect();
    let slice: Vec<_> = tasks.iter().cloned().step_by(2).collect(); // 100 tasks

    let (_, timing) = time_once("ablation sweeps (total)", || {
        // ---- rt/at promotion-threshold sweep ----------------------------
        println!("rt/at promotion-threshold sweep (KernelSkill, 100-task slice):");
        for (rt, at) in [(0.0, 0.0), (0.1, 0.1), (0.3, 0.3), (0.6, 0.6), (1.0, 1.0)] {
            let cfg = LoopConfig {
                rt,
                at,
                ..LoopConfig::default()
            };
            let suite =
                coordinator::run_suite(&slice, &baselines::kernelskill(), &cfg, &[0], workers);
            let promos: f64 = suite.results.iter().map(|r| r.promotions as f64).sum::<f64>()
                / suite.results.len() as f64;
            println!(
                "  rt={rt:.1} at={at:.1}: speedup={:.2}x promotions/task={:.1}",
                mean_speedup(&suite.results),
                promos
            );
        }
        println!("  (0.3/0.3 — the paper's setting — keeps speedup near the unthresholded\n   maximum while cutting base churn; large thresholds starve the base)\n");

        // ---- seed-count sweep -------------------------------------------
        println!("Generator seed-count sweep (KernelSkill, 100-task slice):");
        for n_seeds in [1usize, 2, 3, 5] {
            let mut strat = baselines::kernelskill();
            strat.n_seeds = n_seeds;
            let suite =
                coordinator::run_suite(&slice, &strat, &LoopConfig::default(), &[0], workers);
            let succ = suite.results.iter().filter(|r| r.success).count() as f64
                / suite.results.len() as f64;
            println!(
                "  seeds={n_seeds}: success={succ:.2} speedup={:.2}x",
                mean_speedup(&suite.results)
            );
        }
        println!();

        // ---- round-budget sweep ------------------------------------------
        println!("Round-budget sweep (KernelSkill, 100-task slice):");
        for rounds in [5u32, 10, 15, 20, 30] {
            let mut strat = baselines::kernelskill();
            strat.rounds = rounds;
            let suite =
                coordinator::run_suite(&slice, &strat, &LoopConfig::default(), &[0], workers);
            println!(
                "  rounds={rounds:>2}: speedup={:.2}x (per-round {:.3})",
                mean_speedup(&suite.results),
                mean_speedup(&suite.results) / rounds as f64
            );
        }
        println!("  (diminishing returns past ~15 rounds — the paper's budget)\n");

        // ---- device-preset robustness ------------------------------------
        println!("Device-preset robustness (A100-like vs TPU-like, L2 slice):");
        let l2: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(50).collect();
        for dev in [DeviceSpec::a100_like(), DeviceSpec::tpu_like()] {
            let cfg = LoopConfig {
                dev: dev.clone(),
                ..LoopConfig::default()
            };
            let ks = coordinator::run_suite(&l2, &baselines::kernelskill(), &cfg, &[0], workers);
            let nm = coordinator::run_suite(&l2, &baselines::wo_memory(), &cfg, &[0], workers);
            println!(
                "  {:<10}: KernelSkill {:.2}x vs w/o memory {:.2}x (ordering preserved: {})",
                dev.name,
                mean_speedup(&ks.results),
                mean_speedup(&nm.results),
                mean_speedup(&ks.results) > mean_speedup(&nm.results)
            );
        }
        println!();

        // ---- fast_p sweep --------------------------------------------------
        println!("fast_p sweep (KernelSkill, full suite):");
        let full = bench_suite::full_suite(42);
        let suite = coordinator::run_suite(
            &full,
            &baselines::kernelskill(),
            &LoopConfig::default(),
            &[0],
            workers,
        );
        let split = metrics::by_level(&suite.results);
        for p in [0.5, 1.0, 2.0, 5.0, 10.0] {
            println!(
                "  p={p:>4}: L1 {:.2}  L2 {:.2}  L3 {:.2}",
                metrics::fast_p(&split[0], p),
                metrics::fast_p(&split[1], p),
                metrics::fast_p(&split[2], p)
            );
        }
        println!();

        // ---- persistent-memory transfer sweep ----------------------------
        // Learn skills on Level 1, then warm-start Levels 2-3 from the
        // persisted store — the orchestration-v2 cross-task transfer path.
        println!("Persistent-memory transfer (skills learned on L1, applied to L2/L3):");
        let mem = std::env::temp_dir().join(format!("ks-ablation-mem-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&mem);
        let warm_cfg = LoopConfig {
            memory_dir: Some(mem.clone()),
            ..LoopConfig::default()
        };
        let l1: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(50).collect();
        coordinator::run_suite(&l1, &baselines::kernelskill(), &warm_cfg, &[0], workers);
        for level in [2u8, 3] {
            let lv: Vec<_> = bench_suite::level_suite(42, level).into_iter().take(25).collect();
            let cold = coordinator::run_suite(
                &lv,
                &baselines::kernelskill(),
                &LoopConfig::default(),
                &[0],
                workers,
            );
            let warm =
                coordinator::run_suite(&lv, &baselines::kernelskill(), &warm_cfg, &[0], workers);
            println!(
                "  L{level}: cold {:.2}x vs warm {:.2}x",
                mean_speedup(&cold.results),
                mean_speedup(&warm.results)
            );
        }
        let _ = std::fs::remove_dir_all(&mem);
    });
    println!("\n[{}]", timing.report());
}
