//! E4 — Figures 2-3: short-term-memory trajectories (repair chains, base
//! promotions) plus chain statistics with/without repair memory.
//! `cargo bench --bench fig_trajectory`.

use kernelskill::harness::bench::time_once;
use kernelskill::harness::experiments::{self, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (rendered, timing) = time_once("trajectory figures", || {
        experiments::trajectory_figures(&cfg)
    });
    println!("Figures 2-3 — short-term memory trajectories");
    println!("{rendered}");
    println!("[{}]", timing.report());
    assert!(rendered.contains("KernelSkill trajectory"));
}
