//! E1 — regenerate Table 1 (Success + Speedup, 7 methods x Levels 1-3) and
//! the §5.4 per-round-efficiency comparison. `cargo bench --bench table1`.

use kernelskill::harness::bench::time_once;
use kernelskill::harness::experiments::{self, ExpConfig};
use kernelskill::harness::tables;

fn main() {
    let mut cfg = ExpConfig::default();
    if let Ok(seeds) = std::env::var("KS_SEEDS") {
        let n: u64 = seeds.parse().unwrap_or(1);
        cfg.run_seeds = (0..n).collect();
    }
    // Orchestration v2: stream every finished cell to a checkpoint dir and
    // resume a killed bench (KS_RUN_DIR + KS_RESUME=1); warm-start and
    // persist the long-term skill store with KS_MEMORY_DIR.
    if let Ok(dir) = std::env::var("KS_RUN_DIR") {
        cfg.run_dir = Some(dir.into());
        cfg.resume = std::env::var("KS_RESUME").map(|v| v == "1").unwrap_or(false);
    }
    if let Ok(dir) = std::env::var("KS_MEMORY_DIR") {
        cfg.memory_dir = Some(dir.into());
    }
    let ((rendered, rows), timing) =
        time_once("table1(full suite)", || experiments::table1(&cfg).expect("table1 run failed"));
    println!("Table 1 — Success and Speedup vs Torch Eager (paper Table 1)");
    println!("{rendered}");
    println!("Per-round refinement efficiency (§5.4; speedup / budget rounds)");
    println!("{}", tables::per_round(&rows));
    println!("[{}]", timing.report());
    // Shape assertions: the paper's ordering claims.
    let get = |name: &str| rows.iter().find(|r| r.method == name).unwrap();
    let ks = get("KernelSkill");
    let stark = get("STARK");
    for lvl in 0..3 {
        assert!(
            ks.cells[lvl].speedup >= stark.cells[lvl].speedup * 0.98,
            "KernelSkill should lead on L{}",
            lvl + 1
        );
        assert!(
            ks.cells[lvl].speedup_per_round > stark.cells[lvl].speedup_per_round,
            "KernelSkill should be more round-efficient on L{}",
            lvl + 1
        );
    }
    let kevin = get("Kevin-32B");
    assert!(kevin.cells[2].success < 0.85, "Kevin collapses on L3");
    println!("shape checks passed: KernelSkill leads every level; Kevin collapses on L3");
}
