//! §Perf — hot-path microbenchmarks for the L3 coordinator (the stack's
//! request path): cost-model pricing, metric synthesis, retrieval, feature
//! extraction, and one full task loop. Used for the before/after log in
//! EXPERIMENTS.md §Perf. `cargo bench --bench perf_hotpath`.
//!
//! Regression gate: `-- --min-suite-throughput <task-runs/s>` exits
//! non-zero when the whole-suite throughput lands below the threshold. The
//! CI `perf` job runs it as a *blocking* check at a conservative floor set
//! well below healthy shared-runner numbers, so only a real hot-path
//! regression (or a pathological runner) trips it.
//!
//! `-- --json-out <path>` additionally writes the measured numbers as one
//! JSON entry in the `BENCH_perf_hotpath.json` schema (see that file at
//! the repo root), so the CI log carries machine-readable trajectory data.
//!
//! Built with `--features alloc-count`, the bench installs the counting
//! allocator from `util::alloc_count` and adds `allocs_per_task_run`
//! (heap allocations per task run over a dedicated 100-task suite pass)
//! to the report and JSON entry; without the feature the field is `null`.

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, LoopConfig};
use kernelskill::device::costmodel;
use kernelskill::device::machine::DeviceSpec;
use kernelskill::device::metrics::{synthesize, ToolVersion};
use kernelskill::harness::bench::bench;
use kernelskill::kir::features;
use kernelskill::kir::schedule::Schedule;
use kernelskill::kir::transforms::{self, ALL_METHODS};
use kernelskill::memory::long_term::retrieval::{self, RetrievalCache};
use kernelskill::memory::long_term::{SkillObs, SkillStore};

#[cfg(feature = "alloc-count")]
#[global_allocator]
static ALLOC: kernelskill::util::alloc_count::CountingAlloc =
    kernelskill::util::alloc_count::CountingAlloc;

fn main() {
    let dev = DeviceSpec::a100_like();
    let tasks = bench_suite::full_suite(42);
    let l3 = tasks.iter().find(|t| t.id.contains("transformer")).unwrap();
    let sched = Schedule::per_op_naive(&l3.graph);
    let cost = costmodel::price(&l3.graph, &sched, &dev);
    let raw = synthesize(&l3.graph, &sched, &cost, ToolVersion::Ncu2023);
    let feats = features::ground_truth(&l3.graph, &sched);

    let mut results = Vec::new();
    results.push(bench("costmodel::price (28-op L3 graph)", 100, 2000, || {
        std::hint::black_box(costmodel::price(&l3.graph, &sched, &dev));
    }));
    results.push(bench("metrics::synthesize", 100, 2000, || {
        std::hint::black_box(synthesize(&l3.graph, &sched, &cost, ToolVersion::Ncu2023));
    }));
    results.push(bench("features::ground_truth", 100, 2000, || {
        std::hint::black_box(features::ground_truth(&l3.graph, &sched));
    }));
    results.push(bench("retrieval (aggregate+decide, audited)", 100, 2000, || {
        std::hint::black_box(retrieval::retrieve_for(l3, &feats, &raw));
    }));

    // Warm retrieval: a populated skill store activates step 8' (rerank +
    // note formatting), which is where repeat retrievals spend their time.
    // Benched twice — without and with the per-task-run RetrievalCache the
    // loop runner uses — to keep the cache's win (or regression) visible.
    let seed_case = retrieval::retrieve_for(l3, &feats, &raw)
        .matched_case
        .unwrap_or("gemm.naive_loop");
    let mut store = SkillStore::new();
    for (i, &m) in ALL_METHODS.iter().enumerate() {
        store.observe(&SkillObs {
            case_id: seed_case.to_string(),
            method: m,
            gain: if i % 3 == 0 { Some(0.12) } else { None },
            device: dev.name.to_string(),
        });
    }
    results.push(bench("retrieval (warm store, uncached)", 100, 2000, || {
        std::hint::black_box(retrieval::retrieve_for_with(
            l3,
            &feats,
            &raw,
            Some(&store),
            dev.name,
        ));
    }));
    let mut cache = RetrievalCache::new();
    results.push(bench("retrieval (warm store, cached)", 100, 2000, || {
        std::hint::black_box(retrieval::retrieve_for_with_cache(
            l3,
            &feats,
            &raw,
            Some(&store),
            dev.name,
            Some(&mut cache),
        ));
    }));

    // Legality sweep: every method's applicability check against the naive
    // schedule — the per-round planner cost the op->group map targets.
    results.push(bench("transforms::applicable (21-method sweep)", 100, 2000, || {
        for &m in ALL_METHODS.iter() {
            std::hint::black_box(transforms::applicable(m, &l3.graph, &sched).is_ok());
        }
    }));
    results.push(bench("eager::eager_time_s", 100, 2000, || {
        std::hint::black_box(bench_suite::eager::eager_time_s(l3, &dev));
    }));
    let strategy = baselines::kernelskill();
    let cfg = LoopConfig::default();
    results.push(bench("run_task (full 15-round L3 loop)", 3, 30, || {
        std::hint::black_box(coordinator::run_task(l3, &strategy, &cfg));
    }));
    let l1 = &tasks[0];
    results.push(bench("run_task (L1 loop)", 3, 100, || {
        std::hint::black_box(coordinator::run_task(l1, &strategy, &cfg));
    }));

    println!("hot-path microbenchmarks (L3 coordinator):");
    for r in &results {
        println!("  {}", r.report());
    }

    // Whole-suite throughput: the number the §Perf pass optimizes.
    let suite_tasks = bench_suite::level_suite(42, 1);
    let r = bench("run_suite (100 L1 tasks, parallel)", 0, 3, || {
        std::hint::black_box(coordinator::run_suite(
            &suite_tasks,
            &strategy,
            &cfg,
            &[0],
            kernelskill::util::pool::default_workers(),
        ));
    });
    println!("  {}", r.report());
    let throughput = 100.0 / r.median_s;
    println!("suite throughput: {throughput:.0} task-runs/s");

    // Heap allocations per task run (alloc-count builds only). Measured on
    // one dedicated suite pass, after the timing loops, so the bench
    // harness's own bookkeeping does not leak into the number.
    #[cfg(feature = "alloc-count")]
    let allocs_per_task_run: Option<f64> = {
        let before = kernelskill::util::alloc_count::allocations();
        std::hint::black_box(coordinator::run_suite(
            &suite_tasks,
            &strategy,
            &cfg,
            &[0],
            kernelskill::util::pool::default_workers(),
        ));
        let per = (kernelskill::util::alloc_count::allocations() - before) as f64 / 100.0;
        println!("allocations per task run: {per:.0}");
        Some(per)
    };
    #[cfg(not(feature = "alloc-count"))]
    let allocs_per_task_run: Option<f64> = None;

    // Flags parsed by hand: the bench is a plain `fn main` binary with no
    // CLI layer of its own.
    let argv: Vec<String> = std::env::args().collect();

    // Machine-readable entry for the BENCH_perf_hotpath.json trajectory.
    if let Some(i) = argv.iter().position(|a| a == "--json-out") {
        let path = argv.get(i + 1).cloned().unwrap_or_else(|| {
            eprintln!("--json-out needs a path argument");
            std::process::exit(2);
        });
        let hotpaths: Vec<String> = results
            .iter()
            .map(|r| format!(r#"{{"name":{:?},"median_s":{}}}"#, r.name, r.median_s))
            .collect();
        let allocs_json = match allocs_per_task_run {
            Some(a) => format!("{a}"),
            None => "null".to_string(),
        };
        let entry = format!(
            r#"{{"bench":"perf_hotpath","suite_tasks":100,"suite_median_s":{},"suite_throughput_task_runs_per_s":{},"allocs_per_task_run":{},"hotpaths":[{}]}}"#,
            r.median_s,
            throughput,
            allocs_json,
            hotpaths.join(",")
        );
        if let Err(e) = std::fs::write(&path, format!("{entry}\n")) {
            eprintln!("writing {path}: {e}");
            std::process::exit(2);
        }
        println!("bench entry written to {path}");
    }

    // Blocking threshold check (see module docs).
    if let Some(i) = argv.iter().position(|a| a == "--min-suite-throughput") {
        let min: f64 = argv
            .get(i + 1)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                eprintln!("--min-suite-throughput needs a numeric argument");
                std::process::exit(2);
            });
        if throughput < min {
            eprintln!(
                "PERF REGRESSION: suite throughput {throughput:.0} task-runs/s is below \
                 the {min:.0} task-runs/s threshold"
            );
            std::process::exit(1);
        }
        println!("perf threshold ok: {throughput:.0} >= {min:.0} task-runs/s");
    }
}
