//! End-to-end battery for the shard launcher: real child processes of the
//! `kernelskill` binary (CARGO_BIN_EXE), forced crashes via the scheduler's
//! test hook, crash-restart into `--resume`, streaming merge, and — with
//! exchange enabled — the live memory-exchange protocol across processes.
//!
//! The contract under test is the launch acceptance criterion: `launch
//! --shards N --run-dir D` (spawn, crash-restart, merge) produces `report`
//! and `skills.json` byte-identical to a single-process run of the same
//! matrix, including with memory exchange enabled.

use std::path::{Path, PathBuf};

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, LaunchConfig, LoopConfig, SuiteOptions};
use kernelskill::harness::experiments;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-launch-{tag}-{}", std::process::id()))
}

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_kernelskill"))
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// The matrix every test here runs: level 1, first 3 tasks, 2 seeds — small
/// enough for CI, large enough for several exchange epochs.
const TAKE: usize = 3;
const SEEDS: usize = 2;

fn launch_cfg(run_dir: &Path, shards: usize) -> LaunchConfig {
    let mut cfg = LaunchConfig::new(bin(), "suite", run_dir, shards);
    cfg.passthrough = [
        "--level", "1", "--take", "3", "--seeds", "2", "--workers", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    cfg.max_restarts = 3;
    // Quarantine the children from an outer test-runner environment (the
    // crash hook only arms when both variables are non-empty).
    cfg.child_env = vec![
        ("KS_TEST_CRASH_AFTER".to_string(), String::new()),
        ("KS_TEST_CRASH_MARKER".to_string(), String::new()),
    ];
    cfg
}

/// Arm the crash hook: every child shard hard-exits (code 86) right after
/// its n-th checkpoint append, once per shard marker file.
fn arm_crash(cfg: &mut LaunchConfig, marker: &Path, after: usize) {
    cfg.child_env = vec![
        ("KS_TEST_CRASH_AFTER".to_string(), after.to_string()),
        (
            "KS_TEST_CRASH_MARKER".to_string(),
            marker.to_string_lossy().into_owned(),
        ),
    ];
}

/// In-process single-process reference run of the same matrix.
fn reference_run(dir: &Path) {
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(TAKE).collect();
    let seeds: Vec<u64> = (0..SEEDS as u64).collect();
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &LoopConfig::default(),
        &seeds,
        4,
        &SuiteOptions::in_dir(dir),
    )
    .unwrap();
}

#[test]
fn launch_with_forced_kill_matches_single_process() {
    let root = tmp_root("kill");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    reference_run(&single);

    let merged = root.join("launched");
    let marker = root.join("crash");
    let mut cfg = launch_cfg(&merged, 2);
    arm_crash(&mut cfg, &marker, 1);
    let report = coordinator::launch(&cfg).unwrap();

    // The forced kill actually happened and was ridden out.
    let restarts: usize = report.shards.iter().map(|s| s.restarts).sum();
    assert!(restarts >= 1, "expected at least one crash-restart: {report:?}");
    for shard in 0..2 {
        assert!(
            root.join(format!("crash.shard-{shard}")).exists(),
            "shard {shard} never hit the crash hook"
        );
    }
    assert_eq!(report.merge.merged_cells, TAKE * SEEDS);
    assert!(report.merge.missing_shards.is_empty());
    assert!(report.render().contains("crash-restart(s)"));

    // ... and the merged output is indistinguishable from one process.
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn launch_with_exchange_and_kill_matches_single_process_launch() {
    // With exchange on, the single-process baseline is a --shards 1 launch
    // with the SAME epoch length: exchange changes the experiment (cells
    // retrieve against epoch-folded memory), and the determinism contract
    // is that the result is a pure function of (matrix, base memory, epoch
    // length) — independent of shard count, crashes, and resumes.
    let root = tmp_root("exchange");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    let mut cfg = launch_cfg(&single, 1);
    cfg.exchange_epoch = Some(2);
    coordinator::launch(&cfg).unwrap();

    let merged = root.join("launched");
    let marker = root.join("crash");
    let mut cfg = launch_cfg(&merged, 2);
    cfg.exchange_epoch = Some(2);
    arm_crash(&mut cfg, &marker, 1);
    let report = coordinator::launch(&cfg).unwrap();

    let restarts: usize = report.shards.iter().map(|s| s.restarts).sum();
    assert!(restarts >= 1, "expected at least one mid-epoch crash-restart");
    assert_eq!(report.merge.merged_cells, TAKE * SEEDS);

    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );
    // The cross-process protocol really ran: every epoch delta from every
    // shard is on disk, and the per-epoch union equals the single-process
    // deltas bit for bit.
    let ex2 = merged.join("exchange").join("kernelskill");
    let ex1 = single.join("exchange").join("kernelskill");
    for epoch in 0..(TAKE * SEEDS + 1) / 2 {
        let mut union = kernelskill::memory::long_term::SkillStore::new();
        for shard in 0..2 {
            let path = ex2.join(format!("epoch-{epoch}.shard-{shard}.json"));
            assert!(path.exists(), "missing {}", path.display());
            union.merge_store(&kernelskill::memory::long_term::SkillStore::load(&path).unwrap());
        }
        let solo = kernelskill::memory::long_term::SkillStore::load(
            &ex1.join(format!("epoch-{epoch}.shard-0.json")),
        )
        .unwrap();
        assert_eq!(
            union.to_json().to_string(),
            solo.to_json().to_string(),
            "epoch {epoch}: sharded delta union must equal the solo delta"
        );
    }

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn launch_rejects_bad_configs() {
    let root = tmp_root("bad");
    let _ = std::fs::remove_dir_all(&root);
    let cfg = LaunchConfig::new(bin(), "suite", root.join("out"), 0);
    assert!(coordinator::launch(&cfg).unwrap_err().contains("--shards"));
    let mut cfg = LaunchConfig::new(bin(), "suite", root.join("out"), 1);
    cfg.exchange_epoch = Some(0);
    assert!(coordinator::launch(&cfg).unwrap_err().contains("--exchange-epoch"));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn launch_fails_cleanly_when_a_shard_cannot_succeed() {
    // A child that exits non-zero every time must exhaust the restart
    // budget and surface a pointed error (with the log path), not hang or
    // panic. An unknown strategy makes the child fail immediately.
    let root = tmp_root("doomed");
    let _ = std::fs::remove_dir_all(&root);
    let mut cfg = LaunchConfig::new(bin(), "suite", root.join("out"), 2);
    cfg.passthrough = vec!["--strategy".to_string(), "NoSuchStrategy".to_string()];
    cfg.max_restarts = 1;
    let err = coordinator::launch(&cfg).unwrap_err();
    assert!(
        err.contains("after 1 restart(s)") && err.contains("shard-"),
        "{err}"
    );
    let _ = std::fs::remove_dir_all(&root);
}
