//! Adversarial environment-fault battery (ISSUE-8): the chaos layer under
//! hostile knob settings. The contract: chaos *degrades gracefully* —
//! every cell still converges to a delivered kernel, nothing panics,
//! nothing is dropped — and chaos *preserves the determinism contract* —
//! a chaotic 2-shard run merges byte-identical to a chaotic single
//! process, zero-knob chaos is byte-identical to no chaos, and resume and
//! merge refuse to mix differing chaos configs (chaos is experiment
//! identity, recorded in the run manifest).

use std::path::{Path, PathBuf};

use kernelskill::baselines;
use kernelskill::bench_suite::{self, Task};
use kernelskill::coordinator::{
    self, merge_run_dirs, Branch, LoopConfig, SuiteOptions,
};
use kernelskill::device::faults::ChaosConfig;
use kernelskill::harness::experiments;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-chaos-{tag}-{}", std::process::id()))
}

fn small_tasks() -> Vec<Task> {
    bench_suite::level_suite(42, 1).into_iter().take(3).collect()
}

const SEEDS: [u64; 2] = [0, 1];

fn chaos_cfg(spec: &str) -> LoopConfig {
    LoopConfig {
        chaos: Some(ChaosConfig::parse(spec).unwrap()),
        ..LoopConfig::default()
    }
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

#[test]
fn adversarial_fault_rates_still_converge_every_cell() {
    // 30% transient compile failures, a profiler that drops every tenth
    // measurement and jitters the rest by ±50%, and a cost model lying by
    // up to 30% per counter. Every cell — both memory tiers, Level 1 and
    // the Level-4 fused pipelines — must still end in success with a
    // positive delivered speedup. No panic, no dropped cell.
    let base = chaos_cfg("tc=0.3,drop=0.1,sigma=0.5,bias=0.3,seed=13");
    let mut tasks = small_tasks();
    tasks.extend(bench_suite::level_suite(42, 4).into_iter().take(3));
    for strategy in [baselines::kernelskill(), baselines::wo_memory()] {
        for task in &tasks {
            for run_seed in 0..2u64 {
                let cfg = LoopConfig { run_seed, ..base.clone() };
                let r = coordinator::run_task(task, &strategy, &cfg);
                assert!(
                    r.success,
                    "{}/{}/seed{run_seed} did not converge under adversarial chaos",
                    strategy.name, task.id
                );
                assert!(
                    r.best_speedup > 0.0,
                    "{}/{}/seed{run_seed} delivered no kernel",
                    strategy.name, task.id
                );
            }
        }
    }
}

#[test]
fn chaotic_two_shard_run_merges_byte_identical_to_single_process() {
    // The determinism contract survives chaos: the chaos stream is derived
    // per (chaos seed, run seed, strategy, task), never positionally, so
    // sharding a chaotic run cannot change which faults a cell sees.
    let root = tmp_root("shard");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let cfg = chaos_cfg("tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7");

    let single = root.join("single");
    coordinator::run_suite_with(&tasks, &strat, &cfg, &SEEDS, 4, &SuiteOptions::in_dir(&single))
        .unwrap();

    let shard_dirs: Vec<PathBuf> = (0..2)
        .map(|i| {
            let d = root.join(format!("shard{i}"));
            coordinator::run_suite_with(
                &tasks,
                &strat,
                &cfg,
                &SEEDS,
                4,
                &SuiteOptions::in_dir(&d).with_shard(i, 2),
            )
            .unwrap();
            d
        })
        .collect();
    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &shard_dirs).unwrap();
    assert_eq!(report.merged_cells, 6);

    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap(),
        "chaotic shard placement must never change a byte of the report"
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json")),
        "chaotic shard placement must never change a byte of the skill store"
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn zero_knob_chaos_is_byte_identical_to_no_chaos() {
    // `--chaos seed=9` arms the machinery but fires nothing: every effect
    // is gated on its knob being > 0, and chaos draws come from a separate
    // stream — so the cells' own RNG consumption is untouched.
    let root = tmp_root("zero");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();

    let clean = root.join("clean");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&clean),
    )
    .unwrap();
    let armed = root.join("armed");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &chaos_cfg("seed=9"),
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&armed),
    )
    .unwrap();

    assert_eq!(
        experiments::report_run_dir(&armed).unwrap(),
        experiments::report_run_dir(&clean).unwrap()
    );
    assert_eq!(
        read_bytes(&armed.join("skills.json")),
        read_bytes(&clean.join("skills.json"))
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn total_profile_drop_degrades_to_convergence_not_failure() {
    // drop=1: the profiler never returns a profile for any healthy kernel.
    // This is the poisoned-cell regression for the missing-profile guard —
    // refinement needs the profile, so each cell must stop with a
    // converged-degraded round (compiled, correct, speedup kept) rather
    // than dropping the cell, failing it, or panicking a whole shard.
    let cfg = chaos_cfg("drop=1,seed=3");
    let strat = baselines::kernelskill();
    for task in &small_tasks() {
        let r = coordinator::run_task(task, &strat, &cfg);
        assert!(r.success, "{}: a dropped profile must not fail the cell", task.id);
        let last = r.rounds.last().unwrap_or_else(|| panic!("{}: no rounds", task.id));
        assert!(
            matches!(last.branch, Branch::Converged),
            "{}: expected converged-degraded, got {:?}",
            task.id, last.branch
        );
        assert!(last.compiled && last.correct, "{}", task.id);
        assert!(
            last.speedup.is_some(),
            "{}: timing survives a dropped profile; only the counters go missing",
            task.id
        );
        assert!(
            r.rounds_used < strat.rounds,
            "{}: refinement must stop at the missing profile, not spin the budget",
            task.id
        );
    }
}

#[test]
fn resume_and_merge_refuse_mismatched_chaos() {
    // Chaos is experiment identity: chaotic cells measured a different
    // environment, so they may not silently mix with clean cells (or with
    // a differently-chaotic run's).
    let root = tmp_root("identity");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let chaotic = chaos_cfg("tc=0.3,seed=7");

    // Shard 0 clean, shard 1 chaotic: the merge must refuse.
    let s0 = root.join("shard0");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&s0).with_shard(0, 2),
    )
    .unwrap();
    let s1 = root.join("shard1");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &chaotic,
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&s1).with_shard(1, 2),
    )
    .unwrap();
    let err = merge_run_dirs(&root.join("merged"), &[s0, s1.clone()]).unwrap_err();
    assert!(err.contains("different cell matrix"), "{err}");

    // Resuming a chaotic dir without its chaos config must refuse too —
    // and so must resuming under a *different* chaos config.
    let err = coordinator::run_suite_with(
        &tasks,
        &strat,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::resumed(&s1),
    )
    .unwrap_err();
    assert!(err.contains("different matrix"), "{err}");
    let err = coordinator::run_suite_with(
        &tasks,
        &strat,
        &chaos_cfg("tc=0.3,seed=8"),
        &SEEDS,
        4,
        &SuiteOptions::resumed(&s1),
    )
    .unwrap_err();
    assert!(err.contains("different matrix"), "{err}");
    // The matching config, by contrast, resumes cleanly (no-op: complete).
    coordinator::run_suite_with(&tasks, &strat, &chaotic, &SEEDS, 4, &SuiteOptions::resumed(&s1))
        .unwrap();

    let _ = std::fs::remove_dir_all(&root);
}
