//! Byte-identity battery for the retrieval cache (the PR-6 hot-path work).
//!
//! The contract under test: the per-task-run `RetrievalCache` is a pure
//! memoization — turning it on or off (`--no-retrieval-cache`) may not
//! change a single byte of any output. Each test runs the same matrix with
//! the cache enabled and disabled and compares the `report` rendering and
//! the `skills.json` store byte-for-byte, across the same topologies the
//! CI determinism gates cover: plain suite, 3-shard + merge, and
//! exchange-enabled shards (the launch-with-exchange shape, where epoch
//! folds advance the store generation and exercise cache invalidation).
//! The last test interrupts an exchange epoch mid-run and resumes it.

use std::path::{Path, PathBuf};

use kernelskill::baselines;
use kernelskill::bench_suite::{self, Task};
use kernelskill::coordinator::{self, merge_run_dirs, LoopConfig, SuiteOptions};
use kernelskill::harness::experiments;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-perfid-{tag}-{}", std::process::id()))
}

fn small_tasks() -> Vec<Task> {
    bench_suite::level_suite(42, 1).into_iter().take(3).collect()
}

const SEEDS: [u64; 2] = [0, 1];

fn loop_cfg(cache: bool) -> LoopConfig {
    LoopConfig {
        retrieval_cache: cache,
        ..LoopConfig::default()
    }
}

/// Run the small matrix into `dir` with the given cache setting.
fn run_into(dir: &Path, cache: bool, opts: &SuiteOptions) {
    let tasks = small_tasks();
    let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
    coordinator::run_matrix_with(&tasks, &strategies, &loop_cfg(cache), &SEEDS, 4, opts)
        .unwrap();
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// report + skills.json of two finished run dirs must match byte-for-byte.
fn assert_dirs_identical(a: &Path, b: &Path) {
    assert_eq!(
        experiments::report_run_dir(a).unwrap(),
        experiments::report_run_dir(b).unwrap(),
        "report rendering diverged between {} and {}",
        a.display(),
        b.display()
    );
    assert_eq!(
        read_bytes(&a.join("skills.json")),
        read_bytes(&b.join("skills.json")),
        "skill store bytes diverged between {} and {}",
        a.display(),
        b.display()
    );
}

#[test]
fn suite_is_byte_identical_with_and_without_retrieval_cache() {
    let root = tmp_root("suite");
    let _ = std::fs::remove_dir_all(&root);

    let cached = root.join("cached");
    let plain = root.join("plain");
    run_into(&cached, true, &SuiteOptions::in_dir(&cached));
    run_into(&plain, false, &SuiteOptions::in_dir(&plain));
    assert_dirs_identical(&cached, &plain);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn sharded_merge_is_byte_identical_across_cache_settings() {
    let root = tmp_root("shard");
    let _ = std::fs::remove_dir_all(&root);

    // Cache OFF, single process: the reference.
    let single = root.join("single");
    run_into(&single, false, &SuiteOptions::in_dir(&single));

    // Cache ON, 3 shards + merge.
    let shard_dirs: Vec<PathBuf> = (0..3)
        .map(|i| {
            let d = root.join(format!("shard{i}"));
            run_into(&d, true, &SuiteOptions::in_dir(&d).with_shard(i, 3));
            d
        })
        .collect();
    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &shard_dirs).unwrap();
    assert_eq!(report.merged_cells, 12);
    assert_dirs_identical(&merged, &single);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn exchange_shards_are_byte_identical_across_cache_settings() {
    // The launch-with-exchange shape at the library level: 2 shards trade
    // learned skills through a shared exchange dir at a fixed epoch
    // length. Epoch folds bump the store generation mid-run, so this is
    // the topology that exercises the cache's invalidation token.
    let root = tmp_root("exchange");
    let _ = std::fs::remove_dir_all(&root);
    const EPOCH: usize = 3;

    // The shards must run concurrently: each one blocks at its epoch
    // boundaries waiting for the peer's published delta.
    let run_pair = |tag: &str, cache: bool| -> PathBuf {
        let xdir = root.join(format!("x-{tag}"));
        let handles: Vec<_> = (0..2usize)
            .map(|i| {
                let d = root.join(format!("{tag}{i}"));
                let xdir = xdir.clone();
                std::thread::spawn(move || {
                    let opts =
                        SuiteOptions::in_dir(&d).with_shard(i, 2).with_exchange(&xdir, EPOCH);
                    run_into(&d, cache, &opts);
                    d
                })
            })
            .collect();
        let dirs: Vec<PathBuf> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let merged = root.join(format!("{tag}-merged"));
        merge_run_dirs(&merged, &dirs).unwrap();
        merged
    };

    let cached = run_pair("cached", true);
    let plain = run_pair("plain", false);
    assert_dirs_identical(&cached, &plain);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn resumed_interrupted_exchange_epoch_is_byte_identical() {
    // Kill an exchange-enabled run one cell into an epoch, resume it with
    // the cache on, and require the finished dir to match an uninterrupted
    // cache-off run byte-for-byte: the resumed scheduler re-folds the
    // partially-published epoch state, and the cache must key off the
    // folded store's generation, not off how many times the process
    // started.
    let root = tmp_root("resume");
    let _ = std::fs::remove_dir_all(&root);
    const EPOCH: usize = 3;

    let plain = root.join("plain");
    let x_plain = root.join("x-plain");
    run_into(
        &plain,
        false,
        &SuiteOptions::in_dir(&plain).with_exchange(&x_plain, EPOCH),
    );

    let resumed = root.join("resumed");
    let x_res = root.join("x-res");
    let mut opts = SuiteOptions::in_dir(&resumed).with_exchange(&x_res, EPOCH);
    opts.stop_after = Some(1);
    run_into(&resumed, true, &opts);
    let opts = SuiteOptions::resumed(&resumed).with_exchange(&x_res, EPOCH);
    run_into(&resumed, true, &opts);

    assert_dirs_identical(&resumed, &plain);

    let _ = std::fs::remove_dir_all(&root);
}
