//! Golden-file compatibility battery for the skill-store on-disk contract
//! (`docs/memory-formats.md`): v1 and v2 `skills.json` fixtures must keep
//! loading forever, and re-saving them must produce the canonical v3 form
//! — idempotently, so one byte representation exists per store state.

use std::path::{Path, PathBuf};

use kernelskill::kir::transforms::MethodId;
use kernelskill::memory::long_term::skill_store::LEGACY_DEVICE;
use kernelskill::memory::long_term::{SkillObs, SkillStore};
use kernelskill::util::json::Json;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-compat-{tag}-{}", std::process::id()))
}

/// Load a store, then assert that serialization is a fixed point: the
/// first re-save is canonical v3 and further load/save cycles reproduce it
/// byte for byte.
fn assert_canonical_v3_resave(store: &SkillStore) -> String {
    let v3 = store.to_json().to_string();
    assert!(v3.contains("\"version\":3"), "{v3}");
    assert!(v3.contains("\"partitions\""), "{v3}");
    assert!(v3.contains("\"generation\""), "{v3}");
    assert!(v3.contains("\"last_gen\""), "{v3}");
    let back = SkillStore::from_json(&Json::parse(&v3).unwrap()).unwrap();
    assert_eq!(&back, store, "reload must reproduce the store exactly");
    assert_eq!(back.to_json().to_string(), v3, "serialization must be idempotent");
    v3
}

#[test]
fn v1_golden_file_loads_and_resaves_as_v3() {
    let store = SkillStore::load(&fixture("skills_v1.json")).unwrap();
    assert_eq!(store.observations, 4);
    assert_eq!(store.generation, 1, "legacy stores load at generation 1");
    // All v1 data lands in the legacy (A100-like) partition.
    assert_eq!(store.partitions.len(), 1);
    let ts = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!((ts.attempts, ts.wins), (3, 2));
    assert_eq!(ts.total_gain(), 1.75);
    assert_eq!(ts.last_gen, 1);
    let db = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::DoubleBuffer).unwrap();
    assert_eq!((db.attempts, db.wins), (1, 0));
    assert_canonical_v3_resave(&store);
}

#[test]
fn v2_golden_file_loads_and_resaves_as_v3() {
    let store = SkillStore::load(&fixture("skills_v2.json")).unwrap();
    assert_eq!(store.observations, 6);
    assert_eq!(store.generation, 1);
    let ts = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(ts.total_gain(), 1.75);
    let tc = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::UseTensorCore).unwrap();
    assert_eq!(tc.total_gain(), -0.5, "v2 exact gain_parts must load");
    let fe = store
        .stat_in(LEGACY_DEVICE, "fusion.elementwise_chain", MethodId::FuseElementwise)
        .unwrap();
    assert_eq!((fe.attempts, fe.wins), (1, 1));
    assert_canonical_v3_resave(&store);
}

#[test]
fn golden_files_resave_through_disk_round_trip() {
    let dir = tmp_dir("resave");
    let _ = std::fs::remove_dir_all(&dir);
    for name in ["skills_v1.json", "skills_v2.json"] {
        let store = SkillStore::load(&fixture(name)).unwrap();
        let path = dir.join(name);
        store.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":3"), "{name} must re-save as v3");
        let back = SkillStore::load(&path).unwrap();
        assert_eq!(back, store, "{name}");
        back.save(&path).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            text,
            "{name}: save/load/save must be byte-stable"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_store_merges_cleanly_with_v3_partitions() {
    // A migrated v2 store and a fresh v3 store with TPU-partition evidence
    // must merge commutatively at the byte level.
    let legacy = SkillStore::load(&fixture("skills_v2.json")).unwrap();
    let mut fresh = SkillStore::new();
    fresh.generation = 3;
    fresh.observe(&SkillObs {
        case_id: "gemm.naive_loop".to_string(),
        method: MethodId::TileSmem,
        gain: Some(0.5),
        device: "tpu-like".to_string(),
    });
    let mut ab = legacy.clone();
    ab.merge_store(&fresh);
    let mut ba = fresh.clone();
    ba.merge_store(&legacy);
    assert_eq!(ab, ba);
    assert_eq!(ab.to_json().to_string(), ba.to_json().to_string());
    assert_eq!(ab.generation, 3);
    // Both partitions survive, and the pooled view folds across them.
    assert!(ab.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).is_some());
    assert!(ab.stat_in("tpu-like", "gemm.naive_loop", MethodId::TileSmem).is_some());
    let pooled = ab.pooled_stat("gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(pooled.attempts, 4);
    assert_eq!(pooled.total_gain(), 2.25);
}

#[test]
fn unknown_partition_and_method_entries_are_tolerated() {
    // A newer writer may add device presets and methods this build does
    // not know; loading must keep everything it understands.
    let text = r#"{"version":3,"generation":2,"observations":3,"partitions":{
        "a100-like":{"gemm.naive_loop":{"tile_smem":{"attempts":1,"wins":1,"total_gain":0.5,"gain_parts":[0.5],"last_gen":2},
                                         "warp_specialize_v9":{"attempts":1,"wins":1,"total_gain":1,"gain_parts":[1],"last_gen":2}}},
        "h100-like":{"gemm.naive_loop":{"tile_smem":{"attempts":1,"wins":0,"total_gain":0,"gain_parts":[],"last_gen":1}}}}}"#;
    let store = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(store.generation, 2);
    assert!(store.stat_in("a100-like", "gemm.naive_loop", MethodId::TileSmem).is_some());
    assert!(
        store.stat_in("h100-like", "gemm.naive_loop", MethodId::TileSmem).is_some(),
        "unknown device partitions are data, not errors"
    );
    // The unknown method was skipped, the known one kept.
    let pooled = store.pooled_stat("gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(pooled.attempts, 2);
}
