//! Golden-file compatibility battery for the skill-store on-disk contract
//! (`docs/memory-formats.md`): v1, v2, and v3 `skills.json` fixtures must
//! keep loading forever, and re-saving them must produce the canonical v4
//! flat form — idempotently, so one byte representation exists per store
//! state — while a segmented v4 store must fold to the byte-identical
//! canonical form its one-blob equivalent serializes to.

use std::path::{Path, PathBuf};

use kernelskill::kir::transforms::MethodId;
use kernelskill::memory::long_term::segmented::SEGMENT_DIR;
use kernelskill::memory::long_term::skill_store::LEGACY_DEVICE;
use kernelskill::memory::long_term::{SegmentedSkillStore, SkillObs, SkillStore};
use kernelskill::util::json::Json;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-compat-{tag}-{}", std::process::id()))
}

fn obs(case: &str, method: MethodId, gain: Option<f64>, device: &str) -> SkillObs {
    SkillObs {
        case_id: case.to_string(),
        method,
        gain,
        device: device.to_string(),
    }
}

/// Load a store, then assert that serialization is a fixed point: the
/// first re-save is canonical v4 (flat form: `"segments":[]`) and further
/// load/save cycles reproduce it byte for byte.
fn assert_canonical_v4_resave(store: &SkillStore) -> String {
    let v4 = store.to_json().to_string();
    assert!(v4.contains("\"version\":4"), "{v4}");
    assert!(v4.contains("\"segments\":[]"), "{v4}");
    assert!(v4.contains("\"partitions\""), "{v4}");
    assert!(v4.contains("\"generation\""), "{v4}");
    assert!(v4.contains("\"last_gen\""), "{v4}");
    let back = SkillStore::from_json(&Json::parse(&v4).unwrap()).unwrap();
    assert_eq!(&back, store, "reload must reproduce the store exactly");
    assert_eq!(back.to_json().to_string(), v4, "serialization must be idempotent");
    v4
}

#[test]
fn v1_golden_file_loads_and_resaves_as_v4() {
    let store = SkillStore::load(&fixture("skills_v1.json")).unwrap();
    assert_eq!(store.observations, 4);
    assert_eq!(store.generation, 1, "legacy stores load at generation 1");
    // All v1 data lands in the legacy (A100-like) partition.
    assert_eq!(store.partitions.len(), 1);
    let ts = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!((ts.attempts, ts.wins), (3, 2));
    assert_eq!(ts.total_gain(), 1.75);
    assert_eq!(ts.last_gen, 1);
    let db = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::DoubleBuffer).unwrap();
    assert_eq!((db.attempts, db.wins), (1, 0));
    assert_canonical_v4_resave(&store);
}

#[test]
fn v2_golden_file_loads_and_resaves_as_v4() {
    let store = SkillStore::load(&fixture("skills_v2.json")).unwrap();
    assert_eq!(store.observations, 6);
    assert_eq!(store.generation, 1);
    let ts = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(ts.total_gain(), 1.75);
    let tc = store.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::UseTensorCore).unwrap();
    assert_eq!(tc.total_gain(), -0.5, "v2 exact gain_parts must load");
    let fe = store
        .stat_in(LEGACY_DEVICE, "fusion.elementwise_chain", MethodId::FuseElementwise)
        .unwrap();
    assert_eq!((fe.attempts, fe.wins), (1, 1));
    assert_canonical_v4_resave(&store);
}

#[test]
fn v3_golden_file_loads_and_resaves_as_v4() {
    let store = SkillStore::load(&fixture("skills_v3.json")).unwrap();
    assert_eq!(store.observations, 9);
    assert_eq!(store.generation, 3, "v3 stores keep their generation clock");
    assert_eq!(store.partitions.len(), 2, "device partitions load as-is");
    let ts = store.stat_in("a100-like", "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!((ts.attempts, ts.wins, ts.last_gen), (3, 2, 2));
    let tpu = store.stat_in("tpu-like", "gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(tpu.total_gain(), 9.25, "multi-part exact gain decomposition must load");
    assert_eq!((tpu.attempts, tpu.wins), (4, 3));
    // The fixture's stale `learned` section is derived data: ignored on
    // load, recomputed from the stats on save.
    let v4 = assert_canonical_v4_resave(&store);
    assert!(!v4.contains("\"version\":3"), "{v4}");
}

#[test]
fn golden_files_resave_through_disk_round_trip() {
    let dir = tmp_dir("resave");
    let _ = std::fs::remove_dir_all(&dir);
    for name in ["skills_v1.json", "skills_v2.json", "skills_v3.json"] {
        let store = SkillStore::load(&fixture(name)).unwrap();
        let path = dir.join(name);
        store.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":4"), "{name} must re-save as v4");
        assert!(text.contains("\"segments\":[]"), "{name}: flat form has no segments");
        let back = SkillStore::load(&path).unwrap();
        assert_eq!(back, store, "{name}");
        back.save(&path).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            text,
            "{name}: save/load/save must be byte-stable"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_store_merges_cleanly_with_partitioned_stores() {
    // A migrated v2 store and a fresh store with TPU-partition evidence
    // must merge commutatively at the byte level.
    let legacy = SkillStore::load(&fixture("skills_v2.json")).unwrap();
    let mut fresh = SkillStore::new();
    fresh.generation = 3;
    fresh.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(0.5), "tpu-like"));
    let mut ab = legacy.clone();
    ab.merge_store(&fresh);
    let mut ba = fresh.clone();
    ba.merge_store(&legacy);
    assert_eq!(ab, ba);
    assert_eq!(ab.to_json().to_string(), ba.to_json().to_string());
    assert_eq!(ab.generation, 3);
    // Both partitions survive, and the pooled view folds across them.
    assert!(ab.stat_in(LEGACY_DEVICE, "gemm.naive_loop", MethodId::TileSmem).is_some());
    assert!(ab.stat_in("tpu-like", "gemm.naive_loop", MethodId::TileSmem).is_some());
    let pooled = ab.pooled_stat("gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(pooled.attempts, 4);
    assert_eq!(pooled.total_gain(), 2.25);
}

#[test]
fn unknown_partition_and_method_entries_are_tolerated() {
    // A newer writer may add device presets and methods this build does
    // not know; loading must keep everything it understands.
    let text = r#"{"version":3,"generation":2,"observations":3,"partitions":{
        "a100-like":{"gemm.naive_loop":{"tile_smem":{"attempts":1,"wins":1,"total_gain":0.5,"gain_parts":[0.5],"last_gen":2},
                                         "warp_specialize_v9":{"attempts":1,"wins":1,"total_gain":1,"gain_parts":[1],"last_gen":2}}},
        "h100-like":{"gemm.naive_loop":{"tile_smem":{"attempts":1,"wins":0,"total_gain":0,"gain_parts":[],"last_gen":1}}}}}"#;
    let store = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(store.generation, 2);
    assert!(store.stat_in("a100-like", "gemm.naive_loop", MethodId::TileSmem).is_some());
    assert!(
        store.stat_in("h100-like", "gemm.naive_loop", MethodId::TileSmem).is_some(),
        "unknown device partitions are data, not errors"
    );
    // The unknown method was skipped, the known one kept.
    let pooled = store.pooled_stat("gemm.naive_loop", MethodId::TileSmem).unwrap();
    assert_eq!(pooled.attempts, 2);
}

/// Drive one epoch of observations into both a segmented store and its
/// flat one-blob twin, keeping their generation clocks in lockstep.
fn epoch(seg: &mut SegmentedSkillStore, flat: &mut SkillStore, gen: u64, batch: &[SkillObs]) {
    seg.advance_to(gen).unwrap();
    seg.merge(batch);
    seg.save().unwrap();
    flat.generation = flat.generation.max(gen);
    for o in batch {
        flat.observe(o);
    }
}

fn three_epoch_stores(dir: &Path) -> (SegmentedSkillStore, SkillStore) {
    let mut seg = SegmentedSkillStore::open(dir).unwrap();
    let mut flat = SkillStore::new();
    epoch(
        &mut seg,
        &mut flat,
        1,
        &[
            obs("gemm.naive_loop", MethodId::TileSmem, Some(0.8), "a100-like"),
            obs("gemm.naive_loop", MethodId::TileSmem, None, "a100-like"),
        ],
    );
    epoch(
        &mut seg,
        &mut flat,
        2,
        &[
            obs("gemm.naive_loop", MethodId::UseTensorCore, Some(1.5), "a100-like"),
            obs("fusion.elementwise_chain", MethodId::FuseElementwise, Some(0.25), "tpu-like"),
        ],
    );
    epoch(
        &mut seg,
        &mut flat,
        3,
        &[obs("gemm.naive_loop", MethodId::TileSmem, Some(0.1), "tpu-like")],
    );
    (seg, flat)
}

#[test]
fn segmented_store_folds_byte_identical_to_one_blob() {
    let dir = tmp_dir("seg-fold");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (seg, flat) = three_epoch_stores(&dir);
    assert_eq!(seg.segments().len(), 2, "epochs 2 and 3 each rotated a segment");

    // Invariant 17 (segment-fold equivalence): the fold of the manifest's
    // segments plus its head serializes to exactly the bytes the
    // equivalent one-blob store would have written.
    assert_eq!(seg.logical(), &flat);
    assert_eq!(seg.logical().to_json().to_string(), flat.to_json().to_string());

    // `SkillStore::load` on the manifest path performs the same fold
    // transparently, so every flat-store reader sees the one-blob view.
    let loaded = SkillStore::load(&dir.join("skills.json")).unwrap();
    assert_eq!(loaded, flat);
    assert_eq!(loaded.to_json().to_string(), flat.to_json().to_string());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compaction_preserves_the_one_blob_bytes() {
    let dir = tmp_dir("seg-compact");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (mut seg, flat) = three_epoch_stores(&dir);
    let before = SkillStore::load(&dir.join("skills.json")).unwrap().to_json().to_string();

    let report = seg.compact().unwrap();
    assert_eq!(report.folded_segments, 2);
    assert_eq!(seg.segments().len(), 1, "compaction folds N segments into one");

    let after = SkillStore::load(&dir.join("skills.json")).unwrap();
    assert_eq!(after, flat, "compaction must not change the logical store");
    assert_eq!(after.to_json().to_string(), before, "…nor its canonical bytes");
    let names: Vec<String> = std::fs::read_dir(dir.join(SEGMENT_DIR))
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .collect();
    assert_eq!(names.len(), 1, "old segment files are deleted after the swap: {names:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
