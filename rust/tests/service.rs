//! End-to-end battery for kernel-optimization-as-a-service: the typed
//! [`JobSpec`] protocol, the strict CLI registry, and the `serve` daemon +
//! `jobs` client driven as real processes (CARGO_BIN_EXE).
//!
//! The contracts under test:
//!
//! - invariant 18 (overlay-fold equivalence): a job run through the
//!   service — including one whose long-term memory is a copy-on-write
//!   overlay over a shared base — produces `report` output and
//!   `skills.json` byte-identical to the same matrix run directly, and
//!   never writes a byte into the base store;
//! - invariant 19 (job replay determinism): SIGKILLing the daemon mid-job
//!   and restarting it re-queues the job, `--resume`s its child, leaves
//!   the re-dispatch audit marker (`.expired` lease), and still converges
//!   to the byte-identical result;
//! - a `JobSpec` round-trips byte-stably through its canonical form, and
//!   malformed or version-skewed job manifests are refused loudly;
//! - the strict flag registry turns typos into hard errors instead of
//!   silently running with defaults.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use kernelskill::coordinator::{validate_service_dir, JobSpec, MATRIX_COMMANDS};

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-svc-e2e-{tag}-{}", std::process::id()))
}

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_kernelskill"))
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Run the binary to success; panics with both streams on failure.
fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin())
        .args(args)
        // Quarantine from an outer test-runner environment: the crash
        // hook only arms when both variables are non-empty.
        .env("KS_TEST_CRASH_AFTER", "")
        .env("KS_TEST_CRASH_MARKER", "")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "kernelskill {args:?} failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

/// Run the binary expecting failure; returns stderr.
fn run_err(args: &[&str]) -> String {
    let out = Command::new(bin())
        .args(args)
        .env("KS_TEST_CRASH_AFTER", "")
        .env("KS_TEST_CRASH_MARKER", "")
        .output()
        .unwrap();
    assert!(
        !out.status.success(),
        "kernelskill {args:?} unexpectedly succeeded\nstdout: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    String::from_utf8_lossy(&out.stderr).to_string()
}

/// Spawn a daemon over `service_dir`, stderr appended to `log`.
fn spawn_serve(service_dir: &Path, log: &Path, extra: &[&str]) -> Child {
    let logf = std::fs::OpenOptions::new().create(true).append(true).open(log).unwrap();
    Command::new(bin())
        .arg("serve")
        .arg("--service-dir")
        .arg(service_dir)
        .args(["--poll-ms", "20"])
        .args(extra)
        .env("KS_TEST_CRASH_AFTER", "")
        .env("KS_TEST_CRASH_MARKER", "")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::from(logf))
        .spawn()
        .unwrap()
}

/// `jobs submit` and return the new job id.
fn submit(service_dir: &Path, matrix: &[&str]) -> String {
    let svc = service_dir.to_str().unwrap();
    let mut args = vec!["jobs", "submit", "--service-dir", svc];
    args.extend_from_slice(matrix);
    let out = run_ok(&args);
    out.split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no job id in submit output: {out}"))
        .to_string()
}

/// Poll `jobs status` until the job reports `state`, with a generous
/// deadline (suite cells take real wall-clock).
fn await_state(service_dir: &Path, job: &str, state: &str) -> String {
    let svc = service_dir.to_str().unwrap();
    for _ in 0..1200 {
        let out = run_ok(&["jobs", "status", job, "--service-dir", svc]);
        if out.contains(state) {
            return out;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    panic!("{job} never reached state {state:?}");
}

/// A tiny deterministic xorshift for the property test (tests must not
/// depend on ambient entropy).
struct Rng(u64);
impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Property: every valid spec serializes canonically, re-parses to an
/// equal spec, and re-serializes to the same bytes — including `u64`
/// suite seeds beyond f64's integer range (they ride as strings).
#[test]
fn jobspec_roundtrips_byte_stable_over_random_valid_specs() {
    let strategies = [
        "KernelSkill", "STARK", "CudaForge", "Astra", "PRAGMA", "QiMeng",
        "Kevin-32B", "w/o memory", "w/o Short_term memory", "w/o Long_term memory",
    ];
    let devices = ["a100-like", "tpu-like", "h100-like", "consumer-gpu-like", "cpu-like"];
    let mut rng = Rng(0x9e3779b97f4a7c15);
    for i in 0..200 {
        let spec = JobSpec {
            cmd: MATRIX_COMMANDS[rng.below(MATRIX_COMMANDS.len() as u64) as usize].to_string(),
            strategy: strategies[rng.below(strategies.len() as u64) as usize].to_string(),
            level: rng.below(5) as usize,
            take: rng.below(10) as usize,
            seeds: 1 + rng.below(8) as usize,
            suite_seed: rng.next(), // full u64 range: exactness is the point
            workers: rng.below(9) as usize,
            device: if rng.below(2) == 0 {
                None
            } else {
                Some(devices[rng.below(devices.len() as u64) as usize].to_string())
            },
            chaos: if rng.below(2) == 0 {
                None
            } else {
                Some(format!(
                    "tc=0.{},drop=0.0{},sigma=0.{},bias=0.0{},seed={}",
                    rng.below(9),
                    rng.below(9),
                    rng.below(9),
                    rng.below(9),
                    rng.below(1000)
                ))
            },
            retrieval_cache: rng.below(2) == 0,
            exchange_adaptive: rng.below(2) == 0,
        }
        .normalized()
        .unwrap_or_else(|e| panic!("iter {i}: spec failed validation: {e}"));
        let bytes = spec.canonical_bytes();
        let back = JobSpec::parse(std::str::from_utf8(&bytes).unwrap())
            .unwrap_or_else(|e| panic!("iter {i}: canonical bytes failed to parse: {e}"));
        assert_eq!(back, spec, "iter {i}: round-trip changed the spec");
        assert_eq!(back.canonical_bytes(), bytes, "iter {i}: bytes not stable");
    }
}

/// Malformed and version-skewed job manifests must be refused loudly at
/// daemon startup — never silently skipped or partially loaded.
#[test]
fn malformed_and_skewed_job_manifests_are_refused() {
    let root = tmp_root("manifests");
    let _ = std::fs::remove_dir_all(&root);
    let job = root.join("jobs").join("job-000001");
    std::fs::create_dir_all(&job).unwrap();
    JobSpec::default().save(&job.join("job-spec.json")).unwrap();

    // Version skew.
    std::fs::write(
        job.join("job.json"),
        b"{\"id\":\"job-000001\",\"restarts\":0,\"state\":\"queued\",\"version\":99}\n",
    )
    .unwrap();
    let err = validate_service_dir(&root).unwrap_err();
    assert!(err.contains("version"), "{err}");

    // Unknown manifest field.
    std::fs::write(
        job.join("job.json"),
        b"{\"frobnicate\":1,\"id\":\"job-000001\",\"restarts\":0,\"state\":\"queued\",\"version\":1}\n",
    )
    .unwrap();
    let err = validate_service_dir(&root).unwrap_err();
    assert!(err.contains("frobnicate"), "{err}");

    // Unknown state.
    std::fs::write(
        job.join("job.json"),
        b"{\"id\":\"job-000001\",\"restarts\":0,\"state\":\"dancing\",\"version\":1}\n",
    )
    .unwrap();
    let err = validate_service_dir(&root).unwrap_err();
    assert!(err.contains("dancing"), "{err}");

    // A gap in the job numbering shifts every later job's lease identity.
    std::fs::write(
        job.join("job.json"),
        b"{\"id\":\"job-000001\",\"restarts\":0,\"state\":\"queued\",\"version\":1}\n",
    )
    .unwrap();
    let gap = root.join("jobs").join("job-000003");
    std::fs::create_dir_all(&gap).unwrap();
    JobSpec::default().save(&gap.join("job-spec.json")).unwrap();
    std::fs::write(
        gap.join("job.json"),
        b"{\"id\":\"job-000003\",\"restarts\":0,\"state\":\"queued\",\"version\":1}\n",
    )
    .unwrap();
    let err = validate_service_dir(&root).unwrap_err();
    assert!(err.contains("contiguous"), "{err}");

    let _ = std::fs::remove_dir_all(&root);
}

/// The strict registry: typos are hard errors with a suggestion, value
/// flags must get values, and identity flags conflict with `--job-spec`.
#[test]
fn typos_and_spec_conflicts_are_hard_errors() {
    let err = run_err(&["suite", "--sees", "3"]);
    assert!(err.contains("--sees") && err.contains("--seeds"), "{err}");

    let err = run_err(&["suiet"]);
    assert!(err.contains("suite"), "{err}");

    let err = run_err(&["suite", "--seeds"]);
    assert!(err.contains("requires a value"), "{err}");

    let root = tmp_root("specfile");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let spec_path = root.join("spec.json");
    JobSpec::default().save(&spec_path).unwrap();
    let err = run_err(&["suite", "--job-spec", spec_path.to_str().unwrap(), "--seeds", "3"]);
    assert!(err.contains("--seeds") && err.contains("--job-spec"), "{err}");

    let table = JobSpec { cmd: "table1".into(), ..JobSpec::default() };
    table.save(&spec_path).unwrap();
    let err = run_err(&["suite", "--job-spec", spec_path.to_str().unwrap()]);
    assert!(err.contains("table1"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}

/// The flagship end-to-end: a plain job and a chaotic job submitted to
/// the daemon, the daemon SIGKILLed mid-chaotic-job and restarted, both
/// jobs watched to completion — and both byte-identical to direct
/// single-process runs of the same specs.
#[test]
fn service_runs_match_direct_runs_including_after_daemon_kill() {
    let root = tmp_root("e2e");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let svc = root.join("svc");
    let svc_s = svc.to_str().unwrap().to_string();
    let log = root.join("serve.log");
    let plain: [&str; 8] = ["--level", "1", "--take", "2", "--seeds", "1", "--workers", "2"];
    const CHAOS: &str = "tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7";
    let chaotic: [&str; 10] =
        ["--level", "1", "--take", "4", "--seeds", "2", "--workers", "2", "--chaos", CHAOS];

    // Direct references.
    let direct1 = root.join("direct1");
    let mut args = vec!["suite"];
    args.extend_from_slice(&plain);
    args.extend_from_slice(&["--run-dir", direct1.to_str().unwrap()]);
    run_ok(&args);
    let direct2 = root.join("direct2");
    let mut args = vec!["suite"];
    args.extend_from_slice(&chaotic);
    args.extend_from_slice(&["--run-dir", direct2.to_str().unwrap()]);
    run_ok(&args);

    // Daemon up; plain job through to completion.
    let mut daemon = spawn_serve(&svc, &log, &[]);
    let job1 = submit(&svc, &plain);
    assert_eq!(job1, "job-000001");
    let out = run_ok(&["jobs", "watch", &job1, "--service-dir", &svc_s]);
    assert!(out.contains("done"), "{out}");

    // Chaotic job; SIGKILL the daemon as soon as it is running.
    let job2 = submit(&svc, &chaotic);
    assert_eq!(job2, "job-000002");
    await_state(&svc, &job2, "running");
    daemon.kill().unwrap();
    daemon.wait().unwrap();
    let completed_before_restart = svc.join("jobs/job-000002/run/complete").exists();

    // Restart: recovery re-queues the job, its child resumes, and the
    // stale lease attempt gets the re-dispatch audit marker.
    let mut daemon = spawn_serve(&svc, &log, &[]);
    let out = run_ok(&["jobs", "watch", &job2, "--service-dir", &svc_s]);
    assert!(out.contains("done"), "{out}");
    if !completed_before_restart {
        let expired = std::fs::read_dir(svc.join("leases"))
            .unwrap()
            .any(|e| e.unwrap().file_name().to_string_lossy().ends_with(".expired"));
        assert!(expired, "recovery must leave the .expired lease audit marker");
    }

    // Byte-identity: report and derived skill store, both jobs.
    for (job, direct) in [(&job1, &direct1), (&job2, &direct2)] {
        let run = svc.join("jobs").join(job).join("run");
        assert_eq!(
            run_ok(&["report", "--run-dir", run.to_str().unwrap()]),
            run_ok(&["report", "--run-dir", direct.to_str().unwrap()]),
            "{job}: report over the service run dir must be byte-identical"
        );
        assert_eq!(
            read_bytes(&run.join("skills.json")),
            read_bytes(&direct.join("skills.json")),
            "{job}: derived skills.json must be byte-identical"
        );
    }

    let list = run_ok(&["jobs", "list", "--service-dir", &svc_s]);
    assert!(list.contains("job-000001") && list.contains("job-000002"), "{list}");

    run_ok(&["jobs", "shutdown", "--service-dir", &svc_s]);
    daemon.wait().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

/// Invariant 18 end-to-end: a service job folding into a copy-on-write
/// overlay over a shared base store produces a store byte-identical to
/// the same run made directly against a private copy of the base — and
/// the base itself is never written.
#[test]
fn overlay_service_job_folds_like_a_direct_run_and_never_writes_the_base() {
    let root = tmp_root("overlay");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let base = root.join("base");
    let matrix: [&str; 8] = ["--level", "1", "--take", "2", "--seeds", "1", "--workers", "2"];

    // Seed the shared base with one prior run.
    run_ok(&[
        "suite", "--level", "1", "--take", "1", "--seeds", "1", "--workers", "2",
        "--memory-dir", base.to_str().unwrap(),
    ]);
    let base_manifest = read_bytes(&base.join("skills.json"));
    let base_segments: Vec<(std::ffi::OsString, Vec<u8>)> = {
        let mut v: Vec<_> = std::fs::read_dir(base.join("skills.segments"))
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (e.file_name(), read_bytes(&e.path()))
            })
            .collect();
        v.sort();
        v
    };

    // A private byte-copy of the base is the direct-run reference start.
    let base2 = root.join("base2");
    std::fs::create_dir_all(base2.join("skills.segments")).unwrap();
    std::fs::write(base2.join("skills.json"), &base_manifest).unwrap();
    for (name, bytes) in &base_segments {
        std::fs::write(base2.join("skills.segments").join(name), bytes).unwrap();
    }
    let direct = root.join("direct");
    let mut args = vec!["suite"];
    args.extend_from_slice(&matrix);
    args.extend_from_slice(&["--memory-dir", base2.to_str().unwrap()]);
    args.extend_from_slice(&["--run-dir", direct.to_str().unwrap()]);
    run_ok(&args);

    // Same matrix through the daemon, folding into a per-job overlay.
    let svc = root.join("svc");
    let svc_s = svc.to_str().unwrap().to_string();
    let log = root.join("serve.log");
    let mut daemon = spawn_serve(&svc, &log, &["--memory-dir", base.to_str().unwrap()]);
    let job = submit(&svc, &matrix);
    run_ok(&["jobs", "watch", &job, "--service-dir", &svc_s]);
    run_ok(&["jobs", "shutdown", "--service-dir", &svc_s]);
    daemon.wait().unwrap();

    let overlay = svc.join("jobs").join(&job).join("memory");
    assert_eq!(
        read_bytes(&overlay.join("skills.json")),
        read_bytes(&base2.join("skills.json")),
        "overlay fold must be byte-identical to the direct fold (invariant 18)"
    );
    assert_eq!(
        read_bytes(&base.join("skills.json")),
        base_manifest,
        "the shared base store must never be written through the service"
    );
    let after: Vec<(std::ffi::OsString, Vec<u8>)> = {
        let mut v: Vec<_> = std::fs::read_dir(base.join("skills.segments"))
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (e.file_name(), read_bytes(&e.path()))
            })
            .collect();
        v.sort();
        v
    };
    assert_eq!(
        after, base_segments,
        "overlay segments are hard links: a job must never mutate a base segment in place"
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// Bounded-queue admission control: a full queue rejects with an explicit
/// backpressure marker, and a running job can be cancelled.
#[test]
fn backpressure_is_explicit_and_running_jobs_cancel() {
    let root = tmp_root("backpressure");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let svc = root.join("svc");
    let svc_s = svc.to_str().unwrap().to_string();
    let log = root.join("serve.log");
    let matrix: [&str; 8] = ["--level", "1", "--take", "4", "--seeds", "2", "--workers", "2"];

    let mut daemon = spawn_serve(&svc, &log, &["--queue-capacity", "1"]);
    let job1 = submit(&svc, &matrix);

    // The queue holds one active job: the second submit must bounce with
    // the explicit retry marker, not hang and not corrupt the queue.
    let mut args = vec!["jobs", "submit", "--service-dir", &svc_s];
    args.extend_from_slice(&matrix);
    let err = run_err(&args);
    assert!(err.contains("backpressure"), "{err}");

    run_ok(&["jobs", "watch", &job1, "--service-dir", &svc_s]);

    // Capacity freed: the next submit is accepted — then cancelled.
    let job2 = submit(&svc, &matrix);
    assert_eq!(job2, "job-000002");
    run_ok(&["jobs", "cancel", &job2, "--service-dir", &svc_s]);
    let status = await_state(&svc, &job2, "cancelled");
    assert!(status.contains("cancelled"), "{status}");
    // Watching a cancelled job exits non-zero: scripts must not mistake
    // a cancelled run for a finished one.
    let err = run_err(&["jobs", "watch", &job2, "--service-dir", &svc_s]);
    assert!(err.contains("cancelled"), "{err}");

    run_ok(&["jobs", "shutdown", "--service-dir", &svc_s]);
    daemon.wait().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

/// The `skills compact --auto N` policy surface: recorded in the
/// manifest, cleared by `--auto 0`, and a threshold of 1 (which would
/// fold on every rotation and thrash) is refused.
#[test]
fn compaction_policy_cli_round_trips() {
    let root = tmp_root("autocompact");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let mem = root.join("mem");
    let mem_s = mem.to_str().unwrap().to_string();
    run_ok(&[
        "suite", "--level", "1", "--take", "1", "--seeds", "1", "--workers", "2",
        "--memory-dir", &mem_s,
    ]);

    let out = run_ok(&["skills", "compact", "--auto", "2", "--memory-dir", &mem_s]);
    assert!(out.contains("auto-compaction at 2"), "{out}");
    let manifest = String::from_utf8(read_bytes(&mem.join("skills.json"))).unwrap();
    assert!(manifest.contains("auto_compact_segments"), "{manifest}");

    let err = run_err(&["skills", "compact", "--auto", "1", "--memory-dir", &mem_s]);
    assert!(err.contains("1"), "{err}");

    let out = run_ok(&["skills", "compact", "--auto", "0", "--memory-dir", &mem_s]);
    assert!(out.contains("auto-compaction off"), "{out}");
    let manifest = String::from_utf8(read_bytes(&mem.join("skills.json"))).unwrap();
    assert!(
        !manifest.contains("auto_compact_segments"),
        "a cleared policy must leave the manifest byte-identical to one that never had it"
    );
    let _ = std::fs::remove_dir_all(&root);
}
