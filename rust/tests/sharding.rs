//! Determinism battery for sharded suite execution + merge.
//!
//! The contract under test: splitting the (strategy, task, seed) cell
//! matrix across N independent processes (each streaming to its own run
//! dir) and then `merge`-ing the shards produces a run directory whose
//! `report` rendering AND skill store are *byte-identical* to a
//! single-process run of the same matrix — including when a shard is
//! killed mid-run (torn checkpoint tail) and resumed, and including the
//! failure modes: conflicting duplicate cells and mismatched matrices must
//! fail loudly, never last-writer-wins.

use std::io::Write;
use std::path::{Path, PathBuf};

use kernelskill::baselines;
use kernelskill::bench_suite::{self, Task};
use kernelskill::coordinator::{
    self, checkpoint, merge_run_dirs, LoopConfig, RunDir, SuiteOptions,
};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::experiments;
use kernelskill::memory::long_term::SkillStore;
use kernelskill::util::json::Json;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-shard-{tag}-{}", std::process::id()))
}

fn small_tasks() -> Vec<Task> {
    bench_suite::level_suite(42, 1).into_iter().take(3).collect()
}

const SEEDS: [u64; 2] = [0, 1];

/// Run the full matrix for both roster strategies into `dir`, optionally as
/// one shard of `count`.
fn run_into(dir: &Path, shard: Option<(usize, usize)>) {
    let tasks = small_tasks();
    let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
    let mut opts = SuiteOptions::in_dir(dir);
    if let Some((index, count)) = shard {
        opts = opts.with_shard(index, count);
    }
    coordinator::run_matrix_with(&tasks, &strategies, &LoopConfig::default(), &SEEDS, 4, &opts)
        .unwrap();
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

#[test]
fn three_shard_merge_is_byte_identical_to_single_process() {
    let root = tmp_root("merge3");
    let _ = std::fs::remove_dir_all(&root);

    let single = root.join("single");
    run_into(&single, None);

    let shard_dirs: Vec<PathBuf> = (0..3)
        .map(|i| {
            let d = root.join(format!("shard{i}"));
            run_into(&d, Some((i, 3)));
            d
        })
        .collect();

    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &shard_dirs).unwrap();
    // 3 tasks x 2 seeds x 2 strategies, nothing duplicated.
    assert_eq!(report.merged_cells, 12);
    assert_eq!(report.deduplicated, 0);

    // report over the merged dir == report over the single-process dir,
    // byte for byte.
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    // ... and so is the skill store file.
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );
    // Folding the per-shard stores by hand (in any order) reproduces the
    // merged store too — the commutative store-merge contract end-to-end.
    let mut fold = SkillStore::new();
    for d in shard_dirs.iter().rev() {
        fold.merge_store(&SkillStore::load(&d.join("skills.json")).unwrap());
    }
    let merged_store = SkillStore::load(&merged.join("skills.json")).unwrap();
    assert_eq!(fold, merged_store);
    assert_eq!(
        fold.to_json().to_string(),
        merged_store.to_json().to_string()
    );

    // Merging in a different input order writes identical bytes.
    let merged_rev = root.join("merged-rev");
    let rev: Vec<PathBuf> = shard_dirs.iter().rev().cloned().collect();
    merge_run_dirs(&merged_rev, &rev).unwrap();
    assert_eq!(
        read_bytes(&merged.join("results.jsonl")),
        read_bytes(&merged_rev.join("results.jsonl"))
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&merged_rev.join("skills.json"))
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn killed_shard_with_torn_tail_resumes_and_merges_identically() {
    let root = tmp_root("kill-resume");
    let _ = std::fs::remove_dir_all(&root);

    let single = root.join("single");
    run_into(&single, None);

    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));

    // Kill shard 1 after a single cell and tear the checkpoint tail the way
    // a hard kill mid-append would.
    let tasks = small_tasks();
    let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
    let s1 = root.join("shard1");
    let mut opts = SuiteOptions::in_dir(&s1).with_shard(1, 2);
    opts.stop_after = Some(1);
    coordinator::run_matrix_with(&tasks, &strategies, &LoopConfig::default(), &SEEDS, 4, &opts)
        .unwrap();
    {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(s1.join("results.jsonl"))
            .unwrap();
        f.write_all(b"{\"strategy\":\"KernelSkill\",\"task_id\":\"to").unwrap();
    }

    // A merge of the partial shard recovers every *complete* cell: shard 0
    // holds 6 (both strategies), the killed shard 1 cell per strategy.
    let partial = root.join("merged-partial");
    let report = merge_run_dirs(&partial, &[s0.clone(), s1.clone()]).unwrap();
    assert_eq!(report.merged_cells, 8, "all complete cells recovered");

    // Resume the killed shard, then merge again: byte-identical to the
    // single-process run.
    let opts = SuiteOptions::resumed(&s1).with_shard(1, 2);
    coordinator::run_matrix_with(&tasks, &strategies, &LoopConfig::default(), &SEEDS, 4, &opts)
        .unwrap();
    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &[s0, s1]).unwrap();
    assert_eq!(report.merged_cells, 12);
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn partially_merged_dir_resumes_to_the_full_matrix() {
    // The merged dir's manifest is unsharded, so `--resume` over it can
    // finish cells a missing shard never ran.
    let root = tmp_root("merge-resume");
    let _ = std::fs::remove_dir_all(&root);

    let single = root.join("single");
    run_into(&single, None);

    // Only shard 0 of 2 ever runs; shard 1's cells are missing.
    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));
    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &[s0]).unwrap();
    assert_eq!(report.merged_cells, 6);
    assert_eq!(report.missing_shards, vec![1], "the gap must be surfaced");
    assert!(report.render().contains("WARNING"), "partial merges are never silent");

    let tasks = small_tasks();
    let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
    coordinator::run_matrix_with(
        &tasks,
        &strategies,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::resumed(&merged),
    )
    .unwrap();
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn warm_sharded_run_merges_identically_when_snapshots_agree() {
    // Sharding a warm run is sound when every shard starts from the same
    // persistent store: the per-shard warm-start snapshots then agree, and
    // the merged dir reproduces the warm single-process run byte for byte
    // (snapshots included, so it stays resumable).
    let root = tmp_root("warm");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();

    // Learn a store first, then hand identical copies to every process.
    let learn = root.join("learn-mem");
    let learn_cfg = LoopConfig {
        memory_dir: Some(learn.clone()),
        ..LoopConfig::default()
    };
    coordinator::run_suite_with(&tasks, &strat, &learn_cfg, &[0], 4, &SuiteOptions::default())
        .unwrap();
    let learned = SkillStore::load(&learn.join("skills.json")).unwrap();
    assert!(learned.observations > 0);
    let mems: Vec<PathBuf> = ["single", "s0", "s1"]
        .iter()
        .map(|t| root.join(format!("mem-{t}")))
        .collect();
    for m in &mems {
        learned.save(&m.join("skills.json")).unwrap();
    }

    let single = root.join("single");
    let cfg = LoopConfig {
        memory_dir: Some(mems[0].clone()),
        ..LoopConfig::default()
    };
    coordinator::run_suite_with(&tasks, &strat, &cfg, &SEEDS, 4, &SuiteOptions::in_dir(&single))
        .unwrap();

    let mut shard_dirs = Vec::new();
    for i in 0..2usize {
        let d = root.join(format!("shard{i}"));
        let cfg = LoopConfig {
            memory_dir: Some(mems[i + 1].clone()),
            ..LoopConfig::default()
        };
        coordinator::run_suite_with(
            &tasks,
            &strat,
            &cfg,
            &SEEDS,
            4,
            &SuiteOptions::in_dir(&d).with_shard(i, 2),
        )
        .unwrap();
        shard_dirs.push(d);
    }

    let merged = root.join("merged");
    merge_run_dirs(&merged, &shard_dirs).unwrap();
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );
    let snap = "memory_snapshot.kernelskill.json";
    assert_eq!(
        read_bytes(&merged.join(snap)),
        read_bytes(&single.join(snap)),
        "warm-start snapshot must be carried into the merged dir"
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_refuses_shards_with_divergent_warm_snapshots() {
    let root = tmp_root("warm-divergent");
    let _ = std::fs::remove_dir_all(&root);
    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));
    let s1 = root.join("shard1");
    run_into(&s1, Some((1, 2)));
    // Plant disagreeing warm-start snapshots: these shards did not run the
    // same experiment, so merging their cells would be meaningless.
    std::fs::write(s0.join("memory_snapshot.kernelskill.json"), b"{\"a\":1}\n").unwrap();
    std::fs::write(s1.join("memory_snapshot.kernelskill.json"), b"{\"a\":2}\n").unwrap();
    let err = merge_run_dirs(&root.join("merged"), &[s0.clone(), s1.clone()]).unwrap_err();
    assert!(err.contains("differs between shards"), "{err}");

    // A warm shard may not merge with a cold one either: remove one side's
    // snapshot entirely and the snapshot *sets* disagree.
    std::fs::remove_file(s1.join("memory_snapshot.kernelskill.json")).unwrap();
    let err = merge_run_dirs(&root.join("merged2"), &[s0, s1]).unwrap_err();
    assert!(err.contains("snapshot set differs"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_fails_loudly_on_conflicting_duplicate_cells() {
    let root = tmp_root("conflict");
    let _ = std::fs::remove_dir_all(&root);

    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));

    // Forge a dir holding one of shard 0's cells with a *different* payload.
    let evil = root.join("evil");
    std::fs::create_dir_all(&evil).unwrap();
    std::fs::copy(s0.join("manifest.json"), evil.join("manifest.json")).unwrap();
    let text = std::fs::read_to_string(s0.join("results.jsonl")).unwrap();
    let first = text.lines().next().unwrap();
    let (key, mut result) =
        checkpoint::result_from_json(&Json::parse(first).unwrap()).unwrap();
    result.best_speedup += 1.0;
    std::fs::write(
        evil.join("results.jsonl"),
        format!("{}\n", checkpoint::result_to_json(&key, &result)),
    )
    .unwrap();

    let out = root.join("merged");
    let err = merge_run_dirs(&out, &[s0.clone(), evil]).unwrap_err();
    assert!(
        err.contains("conflicting results") && err.contains(&key.task_id),
        "conflict must be loud and name the cell, got: {err}"
    );

    // Bit-identical duplicates, by contrast, deduplicate cleanly: merging a
    // shard dir with itself yields the dir's own cells once.
    let out2 = root.join("merged-dup");
    let report = merge_run_dirs(&out2, &[s0.clone(), s0]).unwrap();
    assert_eq!(report.merged_cells, 6);
    assert_eq!(report.deduplicated, 6);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_with_zero_inputs_is_a_clean_error() {
    // Regression: this used to reach a `base.expect("at least one input")`
    // panic path; an empty input list must be a clean CLI-grade error.
    let root = tmp_root("zero-inputs");
    let _ = std::fs::remove_dir_all(&root);
    let err = merge_run_dirs(&root.join("out"), &[]).unwrap_err();
    assert!(err.contains("at least one input"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn streaming_merge_is_byte_identical_to_one_shot() {
    // MergeWatcher follows growing checkpoints (torn mid-append tails
    // included) and must finalize to exactly the bytes a one-shot merge of
    // the finished dirs writes.
    let root = tmp_root("stream");
    let _ = std::fs::remove_dir_all(&root);

    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));
    let s1 = root.join("shard1");
    run_into(&s1, Some((1, 2)));
    let oneshot = root.join("oneshot");
    merge_run_dirs(&oneshot, &[s0.clone(), s1.clone()]).unwrap();

    // Re-play the shards as *growing* dirs, polling between appends.
    let g0 = root.join("grow0");
    let g1 = root.join("grow1");
    for (src, dst) in [(&s0, &g0), (&s1, &g1)] {
        std::fs::create_dir_all(dst).unwrap();
        std::fs::copy(src.join("manifest.json"), dst.join("manifest.json")).unwrap();
    }
    let append = |dst: &PathBuf, text: &str| {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dst.join("results.jsonl"))
            .unwrap();
        f.write_all(text.as_bytes()).unwrap();
    };
    let lines = |src: &PathBuf| -> Vec<String> {
        std::fs::read_to_string(src.join("results.jsonl"))
            .unwrap()
            .lines()
            .map(|l| l.to_string())
            .collect()
    };
    let (l0, l1) = (lines(&s0), lines(&s1));

    let streamed = root.join("streamed");
    let mut watcher =
        coordinator::MergeWatcher::new(&streamed, &[g0.clone(), g1.clone()]).unwrap();
    for i in 0..l0.len().max(l1.len()) {
        if let Some(l) = l0.get(i) {
            append(&g0, &format!("{l}\n"));
        }
        watcher.poll().unwrap();
        if let Some(l) = l1.get(i) {
            // Tear this append in two: the fragment (no newline) must not
            // be consumed by the intervening poll.
            let (a, b) = l.split_at(l.len() / 2);
            append(&g1, a);
            let before = watcher.poll().unwrap().cells;
            append(&g1, &format!("{b}\n"));
            let after = watcher.poll().unwrap().cells;
            assert!(after > before, "completing the torn line must fold a cell");
        }
    }
    for (src, dst) in [(&s0, &g0), (&s1, &g1)] {
        std::fs::copy(src.join("skills.json"), dst.join("skills.json")).unwrap();
        RunDir::open(dst).unwrap().mark_complete().unwrap();
    }
    let status = watcher.poll().unwrap();
    assert!(status.all_complete(), "{status:?}");
    let report = watcher.finalize().unwrap();
    assert_eq!(report.merged_cells, 12);
    for f in ["results.jsonl", "skills.json", "manifest.json"] {
        assert_eq!(
            read_bytes(&streamed.join(f)),
            read_bytes(&oneshot.join(f)),
            "{f} must match the one-shot merge byte for byte"
        );
    }

    let _ = std::fs::remove_dir_all(&root);
}

/// Suite options for an exchange-enabled run (shortened peer-wait timeout
/// so a protocol bug fails the test instead of hanging it for 10 minutes).
fn exchange_opts(
    exchange_dir: &Path,
    run_dir: &Path,
    shard: Option<(usize, usize)>,
    epoch: usize,
) -> SuiteOptions {
    let mut opts = SuiteOptions::in_dir(run_dir).with_exchange(exchange_dir, epoch);
    if let Some((index, count)) = shard {
        opts = opts.with_shard(index, count);
    }
    if let Some(ex) = opts.exchange.as_mut() {
        ex.wait_timeout_ms = 60_000;
    }
    opts
}

#[test]
fn exchange_sharded_threads_match_single_process_with_same_epochs() {
    // The exchange determinism contract: with live memory exchange on, the
    // final report and skill store are a pure function of (matrix, base
    // memory, epoch length) — a 2-shard run trading deltas through a shared
    // exchange dir merges byte-identical to a single process running the
    // same epochs alone.
    let root = tmp_root("exchange");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let cfg = LoopConfig::default();

    let single = root.join("single");
    let ex_single = root.join("ex-single");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &cfg,
        &SEEDS,
        4,
        &exchange_opts(&ex_single, &single, None, 2),
    )
    .unwrap();

    let ex = root.join("ex-sharded");
    let s0 = root.join("shard0");
    let s1 = root.join("shard1");
    std::thread::scope(|scope| {
        let t0 = scope.spawn(|| {
            coordinator::run_suite_with(
                &tasks,
                &strat,
                &cfg,
                &SEEDS,
                4,
                &exchange_opts(&ex, &s0, Some((0, 2)), 2),
            )
            .unwrap();
        });
        let t1 = scope.spawn(|| {
            coordinator::run_suite_with(
                &tasks,
                &strat,
                &cfg,
                &SEEDS,
                4,
                &exchange_opts(&ex, &s1, Some((1, 2)), 2),
            )
            .unwrap();
        });
        t0.join().unwrap();
        t1.join().unwrap();
    });

    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &[s0, s1]).unwrap();
    assert_eq!(report.merged_cells, 6);
    assert_eq!(
        experiments::report_run_dir(&merged).unwrap(),
        experiments::report_run_dir(&single).unwrap()
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json"))
    );
    // The protocol actually ran: 3 epochs x 2 shards of published deltas.
    for epoch in 0..3 {
        for shard in 0..2 {
            let delta = ex
                .join("kernelskill")
                .join(format!("epoch-{epoch}.shard-{shard}.json"));
            assert!(delta.exists(), "missing {}", delta.display());
        }
    }

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn exchange_run_killed_mid_epoch_resumes_identically() {
    // Kill an exchange run mid-epoch (checkpoint tail torn, epoch delta
    // unpublished), resume it, and require byte-identity with an
    // uninterrupted run — including every published epoch delta.
    let root = tmp_root("exchange-resume");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let cfg = LoopConfig::default();

    let full = root.join("full");
    let ex_full = root.join("ex-full");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &cfg,
        &SEEDS,
        4,
        &exchange_opts(&ex_full, &full, None, 2),
    )
    .unwrap();

    // Interrupted twin: stop after 3 of 6 cells — one cell into epoch 1 —
    // and tear the checkpoint tail the way a hard kill mid-append would.
    let part = root.join("part");
    let ex_part = root.join("ex-part");
    let mut opts = exchange_opts(&ex_part, &part, None, 2);
    opts.stop_after = Some(3);
    coordinator::run_suite_with(&tasks, &strat, &cfg, &SEEDS, 4, &opts).unwrap();
    assert!(
        ex_part.join("kernelskill").join("epoch-0.shard-0.json").exists(),
        "the completed epoch's delta must be on disk"
    );
    assert!(
        !ex_part.join("kernelskill").join("epoch-1.shard-0.json").exists(),
        "the interrupted epoch's delta must not be on disk yet"
    );
    {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(part.join("results.jsonl"))
            .unwrap();
        f.write_all(b"{\"strategy\":\"KernelSkill\",\"task_id\":\"to").unwrap();
    }

    let mut opts = exchange_opts(&ex_part, &part, None, 2);
    opts.resume = true;
    coordinator::run_suite_with(&tasks, &strat, &cfg, &SEEDS, 4, &opts).unwrap();

    assert_eq!(
        experiments::report_run_dir(&part).unwrap(),
        experiments::report_run_dir(&full).unwrap()
    );
    assert_eq!(
        read_bytes(&part.join("skills.json")),
        read_bytes(&full.join("skills.json"))
    );
    for epoch in 0..3 {
        let name = format!("epoch-{epoch}.shard-0.json");
        assert_eq!(
            read_bytes(&ex_part.join("kernelskill").join(&name)),
            read_bytes(&ex_full.join("kernelskill").join(&name)),
            "{name} must be recomputed bit-exactly on resume"
        );
    }

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_refuses_mixing_exchange_and_plain_runs() {
    // The exchange epoch is part of the experiment identity (cells saw
    // epoch-folded memory), so a plain shard and an exchange shard of the
    // "same" matrix may not be merged.
    let root = tmp_root("exchange-mix");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let cfg = LoopConfig::default();

    let s0 = root.join("shard0");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &cfg,
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&s0).with_shard(0, 2),
    )
    .unwrap();
    // Epoch 8 >= the 6-cell matrix: a single window, so the lone exchange
    // shard never waits on its (absent) peer.
    let s1 = root.join("shard1");
    let ex = root.join("ex");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &cfg,
        &SEEDS,
        4,
        &exchange_opts(&ex, &s1, Some((1, 2)), 8),
    )
    .unwrap();
    let err = merge_run_dirs(&root.join("merged"), &[s0, s1]).unwrap_err();
    assert!(err.contains("different cell matrix"), "{err}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn device_preset_is_part_of_the_experiment_identity() {
    // A run priced against a different device preset is a different
    // experiment: its cost model differs and its skill observations land
    // in a different store partition. Resume and merge must refuse to mix
    // presets, and tpu-like evidence must actually reach the tpu-like
    // partition (the CI bench-smoke TPU step gates on the same property).
    let root = tmp_root("device");
    let _ = std::fs::remove_dir_all(&root);
    let tasks = small_tasks();
    let strat = baselines::kernelskill();
    let tpu_cfg = LoopConfig {
        dev: DeviceSpec::tpu_like(),
        ..LoopConfig::default()
    };

    let tpu = root.join("tpu");
    coordinator::run_suite_with(&tasks, &strat, &tpu_cfg, &SEEDS, 4, &SuiteOptions::in_dir(&tpu))
        .unwrap();
    let store = std::fs::read_to_string(tpu.join("skills.json")).unwrap();
    assert!(
        store.contains("\"tpu-like\""),
        "tpu-like evidence must land in the tpu-like partition"
    );

    // Resuming under a different preset is refused ...
    let err = coordinator::run_suite_with(
        &tasks,
        &strat,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::resumed(&tpu),
    )
    .unwrap_err();
    assert!(err.contains("different matrix"), "{err}");

    // Merging an a100-like shard with a tpu-like shard, by contrast, is the
    // heterogeneous-fleet contract: their cells are disjoint and their skill
    // evidence lives in separate per-device partitions, so the merge goes
    // through and records the joined device set.
    let a100_shard = root.join("a100-shard");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &LoopConfig::default(),
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&a100_shard).with_shard(0, 2),
    )
    .unwrap();
    let tpu_shard = root.join("tpu-shard");
    coordinator::run_suite_with(
        &tasks,
        &strat,
        &tpu_cfg,
        &SEEDS,
        4,
        &SuiteOptions::in_dir(&tpu_shard).with_shard(1, 2),
    )
    .unwrap();
    let merged = root.join("merged");
    let report = merge_run_dirs(&merged, &[a100_shard, tpu_shard]).unwrap();
    assert_eq!(report.merged_cells, 6);
    let merged_store = std::fs::read_to_string(merged.join("skills.json")).unwrap();
    assert!(
        merged_store.contains("\"a100-like\"") && merged_store.contains("\"tpu-like\""),
        "the merged store must carry both per-device partitions"
    );
    let manifest = RunDir::open(&merged).unwrap().read_manifest().unwrap().unwrap();
    assert_eq!(
        manifest.device, "a100-like+tpu-like",
        "the merged manifest records the sorted joined device set"
    );
    // A mixed-device dir can be reported and re-merged, but no single
    // process prices against two presets at once — resume is refused.
    let err = coordinator::run_suite_with(
        &tasks,
        &strat,
        &tpu_cfg,
        &SEEDS,
        4,
        &SuiteOptions::resumed(&merged),
    )
    .unwrap_err();
    assert!(err.contains("different matrix"), "{err}");

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_refuses_mismatched_matrices_and_missing_manifests() {
    let root = tmp_root("mismatch");
    let _ = std::fs::remove_dir_all(&root);

    let s0 = root.join("shard0");
    run_into(&s0, Some((0, 2)));

    // A run over a *different* matrix (2 tasks instead of 3).
    let other = root.join("other");
    let tasks: Vec<Task> = bench_suite::level_suite(42, 1).into_iter().take(2).collect();
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &LoopConfig::default(),
        &SEEDS,
        2,
        &SuiteOptions::in_dir(&other),
    )
    .unwrap();
    let err = merge_run_dirs(&root.join("m1"), &[s0.clone(), other]).unwrap_err();
    assert!(err.contains("different cell matrix"), "{err}");

    // A directory without a manifest is not a run dir.
    let bare = root.join("bare");
    RunDir::open(&bare).unwrap();
    let err = merge_run_dirs(&root.join("m2"), &[s0.clone(), bare]).unwrap_err();
    assert!(err.contains("no manifest"), "{err}");

    // The output dir may not double as an input.
    let err = merge_run_dirs(&s0, &[s0.clone()]).unwrap_err();
    assert!(err.contains("also a merge input") || err.contains("already holds results"), "{err}");

    let _ = std::fs::remove_dir_all(&root);
}
