//! Runtime integration: real PJRT execution of the AOT Pallas artifacts —
//! the L1/L2 <-> L3 bridge. Requires `make artifacts` (skips otherwise).

use kernelskill::runtime::{self, Registry, Runtime, Tensor};

fn registry() -> Option<Registry> {
    Registry::load("artifacts").ok()
}

#[test]
fn all_variants_verify_against_reference() {
    let Some(reg) = registry() else {
        eprintln!("artifacts not built; skipping");
        return;
    };
    let mut rt = Runtime::new("artifacts").unwrap();
    let reports = runtime::verify_all(&mut rt, &reg, 7, 1e-3).unwrap();
    assert!(!reports.is_empty());
    for r in &reports {
        assert!(r.passed, "{}/{}: err {}", r.task, r.variant, r.max_abs_err);
    }
}

#[test]
fn verification_is_input_seed_sensitive_but_stable() {
    let Some(reg) = registry() else { return };
    let mut rt = Runtime::new("artifacts").unwrap();
    let a = runtime::verify_variant(&mut rt, &reg, "softmax", "rowblock", 1, 1e-3, false).unwrap();
    let b = runtime::verify_variant(&mut rt, &reg, "softmax", "rowblock", 1, 1e-3, false).unwrap();
    let c = runtime::verify_variant(&mut rt, &reg, "softmax", "rowblock", 2, 1e-3, false).unwrap();
    assert_eq!(a.max_abs_err, b.max_abs_err, "same seed => same inputs");
    assert!(a.passed && c.passed);
}

#[test]
fn executes_with_correct_shapes() {
    let Some(reg) = registry() else { return };
    let mut rt = Runtime::new("artifacts").unwrap();
    let entry = reg.task("matmul").unwrap().clone();
    rt.load("matmul/ref", &entry.variants["ref"].file).unwrap();
    let inputs = runtime::verify::seeded_inputs(&reg, "matmul", 3).unwrap();
    let out = rt.execute("matmul/ref", &inputs).unwrap();
    assert_eq!(out.shape, vec![256, 512]);
    assert!(out.data.iter().all(|x| x.is_finite()));
}

#[test]
fn tensor_diff_math() {
    let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let b = Tensor::new(vec![2, 2], vec![1.0, 2.5, 3.0, 3.0]);
    assert_eq!(a.max_abs_diff(&b), 1.0);
    assert_eq!(a.max_abs_diff(&a), 0.0);
}

#[test]
fn missing_artifact_is_an_error_not_a_panic() {
    let Some(reg) = registry() else { return };
    let mut rt = Runtime::new("artifacts").unwrap();
    assert!(rt.execute("nope/nope", &[]).is_err());
    assert!(runtime::verify_variant(&mut rt, &reg, "nope", "ref", 0, 1e-3, false).is_err());
}

#[test]
fn epilogue_fused_variant_matches_reference_closely() {
    // The tiled_fused kernel restructures logsumexp (running-max rewrite);
    // numerics must still be tight — this is the FuseEpilogueReduction
    // method's "numerically unstable if the rewrite is skipped" risk,
    // checked for real.
    let Some(reg) = registry() else { return };
    let mut rt = Runtime::new("artifacts").unwrap();
    let r = runtime::verify_variant(&mut rt, &reg, "fused_epilogue", "tiled_fused", 11, 1e-3, false)
        .unwrap();
    assert!(r.passed, "err {}", r.max_abs_err);
    assert!(r.max_abs_err < 1e-3);
}
