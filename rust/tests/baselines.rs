//! Baseline behavioral contracts: each re-implemented baseline must exhibit
//! the qualitative behavior its paper describes (and that Table 1 encodes).

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, Branch, LoopConfig};
use kernelskill::kir::transforms::MethodId;

fn cfg() -> LoopConfig {
    LoopConfig::default()
}

fn mean_speedup(suite: &coordinator::SuiteResult) -> f64 {
    suite.results.iter().map(|r| r.best_speedup).sum::<f64>() / suite.results.len() as f64
}

#[test]
fn kevin_ignores_profiling_feedback() {
    // Kevin's first optimization move is dictated by its learned ordering,
    // not by the profile: on an L2 chain it fuses first even though the
    // GEMM dominates.
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks
        .iter()
        .find(|t| t.name == "gemm_epilogue")
        .expect("gemm_epilogue task");
    let r = coordinator::run_task(task, &baselines::kevin(), &cfg());
    let first = r.rounds.iter().find_map(|rec| match rec.branch {
        Branch::Optimize(m) => Some(m),
        _ => None,
    });
    assert_eq!(first, Some(MethodId::FuseElementwise));
}

#[test]
fn training_based_methods_degrade_on_l3() {
    let l1: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(30).collect();
    let l3: Vec<_> = bench_suite::level_suite(42, 3).into_iter().take(30).collect();
    for strat in [baselines::kevin(), baselines::qimeng()] {
        let s1 = coordinator::run_suite(&l1, &strat, &cfg(), &[0], 4);
        let s3 = coordinator::run_suite(&l3, &strat, &cfg(), &[0], 4);
        let succ1 = s1.results.iter().filter(|r| r.success).count() as f64 / 30.0;
        let succ3 = s3.results.iter().filter(|r| r.success).count() as f64 / 30.0;
        assert!(
            succ3 <= succ1,
            "{}: L3 success {succ3} should not beat L1 {succ1}",
            strat.name
        );
    }
}

#[test]
fn stark_is_the_strongest_baseline() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(30).collect();
    let stark = mean_speedup(&coordinator::run_suite(
        &tasks,
        &baselines::stark(),
        &cfg(),
        &[0],
        4,
    ));
    for other in [baselines::kevin(), baselines::astra(), baselines::pragma()] {
        let v = mean_speedup(&coordinator::run_suite(&tasks, &other, &cfg(), &[0], 4));
        assert!(
            stark > v,
            "STARK {stark:.2} should beat {} {v:.2} on the L2 slice",
            other.name
        );
    }
}

#[test]
fn kernelskill_structured_gemm_advantage() {
    // The heavy-tailed L1 wins require recognizing operand structure —
    // long-term memory's feature-19 prompt. Judge/rule baselines never
    // notice it.
    let tasks: Vec<_> = bench_suite::level_suite(42, 1)
        .into_iter()
        .filter(|t| t.graph.structured_operands)
        .collect();
    assert!(tasks.len() >= 20);
    let ks = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    let cf = coordinator::run_suite(&tasks, &baselines::cudaforge(), &cfg(), &[0], 4);
    let ks_mean = mean_speedup(&ks);
    let cf_mean = mean_speedup(&cf);
    assert!(
        ks_mean > cf_mean * 3.0,
        "structured tasks: KernelSkill {ks_mean:.2} vs CudaForge {cf_mean:.2}"
    );
}

#[test]
fn ablations_bracket_the_full_system() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(40).collect();
    let full = mean_speedup(&coordinator::run_suite(
        &tasks,
        &baselines::kernelskill(),
        &cfg(),
        &[0],
        4,
    ));
    let wo_mem = mean_speedup(&coordinator::run_suite(
        &tasks,
        &baselines::wo_memory(),
        &cfg(),
        &[0],
        4,
    ));
    let wo_lt = mean_speedup(&coordinator::run_suite(
        &tasks,
        &baselines::wo_long_term(),
        &cfg(),
        &[0],
        4,
    ));
    assert!(full > wo_lt, "full {full:.2} vs w/o LT {wo_lt:.2}");
    assert!(full > wo_mem, "full {full:.2} vs w/o memory {wo_mem:.2}");
}

#[test]
fn pragma_mis_prioritizes_on_naive_gemm() {
    // PRAGMA's flat rule map lacks the GEMM-restructure rule: it must never
    // choose TileSmem on the motivating example.
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks.iter().find(|t| t.id.contains("fused_epilogue")).unwrap();
    for seed in 0..3 {
        let mut c = cfg();
        c.run_seed = seed;
        let r = coordinator::run_task(task, &baselines::pragma(), &c);
        for rec in &r.rounds {
            if let Branch::Optimize(m) = rec.branch {
                assert_ne!(m, MethodId::TileSmem, "seed {seed}");
            }
        }
    }
}
