//! End-to-end battery for the cross-machine launch layer: real worker
//! processes (CARGO_BIN_EXE) and in-process worker/coordinator runtimes
//! syncing through `MirrorDir` transports, with forced worker-machine
//! deaths and interrupted mid-file transfers.
//!
//! The contract under test is ISSUE-5's acceptance criterion: a 2-worker
//! `launch --manifest` run over MirrorDir transports — including a worker
//! kill + resume and an interrupted mid-file sync — produces `report`
//! output and `skills.json` byte-identical to a single-process run of the
//! same matrix. Worker placement and sync timing must never change a
//! byte (invariants 11-13 in docs/memory-formats.md).

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{
    self, FleetConfig, LaunchConfig, LoopConfig, SuiteOptions, WorkerConfig, WorkerManifest,
};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::experiments;

fn tmp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-dist-{tag}-{}", std::process::id()))
}

fn bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_kernelskill"))
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// The matrix every test here runs: level 1, first 3 tasks, 2 seeds.
const TAKE: usize = 3;
const SEEDS: usize = 2;

/// In-process single-process reference run of the same matrix.
fn reference_run(dir: &Path) {
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(TAKE).collect();
    let seeds: Vec<u64> = (0..SEEDS as u64).collect();
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &LoopConfig::default(),
        &seeds,
        4,
        &SuiteOptions::in_dir(dir),
    )
    .unwrap();
}

/// Write a 2-worker mirror-dir manifest splitting `total` shards as
/// `(lo, hi)` ranges.
fn write_manifest(path: &Path, total: usize, rows: &[(&str, usize, usize, &Path)]) {
    let with_dev: Vec<(&str, usize, usize, &Path, Option<&str>)> =
        rows.iter().map(|&(id, lo, hi, root)| (id, lo, hi, root, None)).collect();
    write_device_manifest(path, total, &with_dev);
}

/// Like [`write_manifest`], but rows may pin a per-worker device preset
/// (the heterogeneous-fleet manifest shape).
fn write_device_manifest(
    path: &Path,
    total: usize,
    rows: &[(&str, usize, usize, &Path, Option<&str>)],
) {
    let workers: Vec<String> = rows
        .iter()
        .map(|(id, lo, hi, root, device)| {
            let dev = device.map(|d| format!(r#","device":"{d}""#)).unwrap_or_default();
            format!(
                r#"{{"id":"{id}","shard_lo":{lo},"shard_hi":{hi},"transport":{{"kind":"mirror-dir","root":"{}"}}{dev}}}"#,
                root.to_string_lossy()
            )
        })
        .collect();
    std::fs::write(
        path,
        format!(
            r#"{{"version":1,"total_shards":{total},"workers":[{}]}}"#,
            workers.join(",")
        ),
    )
    .unwrap();
}

/// In-process worker config for one manifest row, quarantined from any
/// outer crash-hook environment.
fn worker_cfg(manifest: &WorkerManifest, id: &str, run_dir: &Path) -> WorkerConfig {
    let mut cfg = WorkerConfig::new(bin(), "suite", run_dir, manifest.clone(), id);
    cfg.passthrough = [
        "--level", "1", "--take", "3", "--seeds", "2", "--workers", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    cfg.poll_ms = 25;
    cfg.child_env = vec![
        ("KS_TEST_CRASH_AFTER".to_string(), String::new()),
        ("KS_TEST_CRASH_MARKER".to_string(), String::new()),
    ];
    cfg
}

fn fleet_cfg(manifest: WorkerManifest, run_dir: &Path) -> FleetConfig {
    let mut cfg = FleetConfig::new(manifest, run_dir);
    cfg.poll_ms = 25;
    cfg
}

fn assert_identical_to_single(merged: &Path, single: &Path) {
    assert_eq!(
        experiments::report_run_dir(merged).unwrap(),
        experiments::report_run_dir(single).unwrap(),
        "report over the fleet-merged dir must be byte-identical"
    );
    assert_eq!(
        read_bytes(&merged.join("skills.json")),
        read_bytes(&single.join("skills.json")),
        "merged skills.json must be byte-identical"
    );
}

#[test]
fn two_workers_over_mirror_dir_match_single_process() {
    let root = tmp_root("basic");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    reference_run(&single);

    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_manifest(&mpath, 2, &[("w0", 0, 0, &t0), ("w1", 1, 1, &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    let merged = root.join("merged");
    let w0 = worker_cfg(&manifest, "w0", &root.join("w0"));
    let w1 = worker_cfg(&manifest, "w1", &root.join("w1"));
    let report = std::thread::scope(|scope| {
        let h0 = scope.spawn(|| coordinator::run_worker(&w0).unwrap());
        let h1 = scope.spawn(|| coordinator::run_worker(&w1).unwrap());
        let fleet = coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)).unwrap();
        let r0 = h0.join().unwrap();
        let r1 = h1.join().unwrap();
        assert_eq!(r0.shards.len(), 1);
        assert_eq!(r1.shards.len(), 1);
        assert!(r0.sync_cycles > 0);
        fleet
    });

    assert_eq!(report.workers.len(), 2);
    assert_eq!(report.merge.merged_cells, TAKE * SEEDS);
    assert!(report.merge.missing_shards.is_empty());
    assert!(!report.workers[0].zero_copy, "mirror-dir must not use the zero-copy path");
    assert!(report.render().contains("coordinated 2 worker(s)"));
    assert_identical_to_single(&merged, &single);

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn mixed_device_fleet_matches_sequential_per_device_runs() {
    // The ISSUE-8 heterogeneous-fleet contract: a manifest row may pin a
    // worker to a device preset; the launcher forwards it to that worker's
    // children as `--device`, the merge accepts the preset mix (cells are
    // disjoint, evidence is partitioned per device), and the merged output
    // is byte-identical to running the two per-device shards sequentially
    // in one process each and merging locally. Placement — fleet vs
    // sequential — never changes a byte.
    let root = tmp_root("mixed-device");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    // Sequential per-device reference pair: shard 0 on the default preset,
    // shard 1 on tpu-like, merged locally.
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(TAKE).collect();
    let seeds: Vec<u64> = (0..SEEDS as u64).collect();
    let (r0, r1) = (root.join("ref0"), root.join("ref1"));
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &LoopConfig::default(),
        &seeds,
        4,
        &SuiteOptions::in_dir(&r0).with_shard(0, 2),
    )
    .unwrap();
    let tpu_cfg = LoopConfig {
        dev: DeviceSpec::tpu_like(),
        ..LoopConfig::default()
    };
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &tpu_cfg,
        &seeds,
        4,
        &SuiteOptions::in_dir(&r1).with_shard(1, 2),
    )
    .unwrap();
    let reference = root.join("reference");
    coordinator::merge_run_dirs(&reference, &[r0, r1]).unwrap();

    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_device_manifest(
        &mpath,
        2,
        &[("w0", 0, 0, &t0, None), ("w1", 1, 1, &t1, Some("tpu-like"))],
    );
    let manifest = WorkerManifest::load(&mpath).unwrap();
    assert_eq!(manifest.workers[0].device, None);
    assert_eq!(manifest.workers[1].device.as_deref(), Some("tpu-like"));

    let merged = root.join("merged");
    let w0 = worker_cfg(&manifest, "w0", &root.join("w0"));
    let w1 = worker_cfg(&manifest, "w1", &root.join("w1"));
    let report = std::thread::scope(|scope| {
        let h0 = scope.spawn(|| coordinator::run_worker(&w0).unwrap());
        let h1 = scope.spawn(|| coordinator::run_worker(&w1).unwrap());
        let fleet = coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)).unwrap();
        h0.join().unwrap();
        h1.join().unwrap();
        fleet
    });
    assert_eq!(report.merge.merged_cells, TAKE * SEEDS);
    assert!(report.merge.missing_shards.is_empty());

    assert_identical_to_single(&merged, &reference);
    // The evidence really is partitioned: both presets appear in the
    // merged store, and the merged manifest records the joined device set.
    let store = std::fs::read_to_string(merged.join("skills.json")).unwrap();
    assert!(
        store.contains("\"a100-like\"") && store.contains("\"tpu-like\""),
        "merged skills.json must hold both per-device partitions"
    );
    let m = coordinator::RunDir::open(&merged).unwrap().read_manifest().unwrap().unwrap();
    assert_eq!(m.device, "a100-like+tpu-like");

    let _ = std::fs::remove_dir_all(&root);
}

/// Spawn a real `worker` CLI process.
fn spawn_worker_cli(
    manifest: &Path,
    id: &str,
    run_dir: &Path,
    log: &Path,
    envs: &[(&str, &str)],
) -> std::process::Child {
    let logf = std::fs::File::create(log).unwrap();
    let loge = logf.try_clone().unwrap();
    let mut cmd = Command::new(bin());
    cmd.arg("worker")
        .arg("--manifest")
        .arg(manifest)
        .arg("--worker-id")
        .arg(id)
        .arg("--run-dir")
        .arg(run_dir)
        .args(["--cmd", "suite", "--level", "1", "--take", "3", "--seeds", "2"])
        .args(["--workers", "2", "--poll-ms", "50"])
        // Quarantine the shard-child crash hook from outer environments.
        .env("KS_TEST_CRASH_AFTER", "")
        .env("KS_TEST_CRASH_MARKER", "");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.stdin(Stdio::null()).stdout(logf).stderr(loge);
    cmd.spawn().unwrap()
}

#[test]
fn worker_kill_and_interrupted_transfer_resume_identically() {
    // The full failure battery in one run: worker w1's "machine" dies
    // mid-run (the worker kills its children and exits 86) and is
    // restarted; worker w0's first checkpoint publish is cut off mid-file
    // and retried. The merged output must still be byte-identical to a
    // single process.
    let root = tmp_root("kill");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    reference_run(&single);

    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_manifest(&mpath, 2, &[("w0", 0, 0, &t0), ("w1", 1, 1, &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    let crash_marker = root.join("crash");
    let xfer_marker = root.join("xfer");
    let merged = root.join("merged");
    std::thread::scope(|scope| {
        let coord =
            scope.spawn(|| coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)));

        // w0: its first results.jsonl publish gets interrupted mid-file.
        let mut w0 = spawn_worker_cli(
            &mpath,
            "w0",
            &root.join("w0"),
            &root.join("w0.log"),
            &[
                ("KS_TEST_TRANSPORT_FAIL_SUBSTR", "results.jsonl"),
                ("KS_TEST_TRANSPORT_FAIL_MARKER", &xfer_marker.to_string_lossy()),
            ],
        );
        // w1: the whole worker machine dies after 3 sync cycles.
        let mut w1 = spawn_worker_cli(
            &mpath,
            "w1",
            &root.join("w1"),
            &root.join("w1.log"),
            &[
                ("KS_TEST_WORKER_CRASH_AFTER_SYNCS", "3"),
                ("KS_TEST_WORKER_CRASH_MARKER", &crash_marker.to_string_lossy()),
            ],
        );

        let status = w1.wait().unwrap();
        assert_eq!(status.code(), Some(86), "w1 must die via the crash hook");
        assert!(
            crash_marker.with_file_name("crash.worker-w1").exists(),
            "the worker crash marker must exist"
        );
        // The operator restarts the dead machine's worker; the marker file
        // keeps the still-set hook disarmed, and the worker resumes its
        // children from their checkpoints.
        let mut w1b = spawn_worker_cli(
            &mpath,
            "w1",
            &root.join("w1"),
            &root.join("w1b.log"),
            &[
                ("KS_TEST_WORKER_CRASH_AFTER_SYNCS", "3"),
                ("KS_TEST_WORKER_CRASH_MARKER", &crash_marker.to_string_lossy()),
            ],
        );
        assert!(w1b.wait().unwrap().success(), "restarted w1 must finish cleanly");
        assert!(w0.wait().unwrap().success(), "w0 must finish cleanly");
        assert!(
            xfer_marker.exists(),
            "the simulated mid-file transfer interruption must have fired"
        );

        let fleet = coord.join().unwrap().unwrap();
        assert_eq!(fleet.merge.merged_cells, TAKE * SEEDS);
        assert!(fleet.merge.missing_shards.is_empty());
    });

    assert_identical_to_single(&merged, &single);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn exchange_across_workers_matches_single_process_launch() {
    // Live memory exchange across machines: each worker's shards fold
    // deltas that traveled worker -> transport -> coordinator -> transport
    // -> worker, and the result must be byte-identical to a --shards 1
    // launch with the same epoch length (the exchange determinism
    // contract, now independent of placement).
    let root = tmp_root("exchange");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    let mut lc = LaunchConfig::new(bin(), "suite", &single, 1);
    lc.passthrough = [
        "--level", "1", "--take", "3", "--seeds", "2", "--workers", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    lc.exchange_epoch = Some(2);
    lc.child_env = vec![
        ("KS_TEST_CRASH_AFTER".to_string(), String::new()),
        ("KS_TEST_CRASH_MARKER".to_string(), String::new()),
    ];
    coordinator::launch(&lc).unwrap();

    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_manifest(&mpath, 2, &[("w0", 0, 0, &t0), ("w1", 1, 1, &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    let merged = root.join("merged");
    let mut w0 = worker_cfg(&manifest, "w0", &root.join("w0"));
    let mut w1 = worker_cfg(&manifest, "w1", &root.join("w1"));
    w0.exchange_epoch = Some(2);
    w1.exchange_epoch = Some(2);
    std::thread::scope(|scope| {
        let h0 = scope.spawn(|| coordinator::run_worker(&w0).unwrap());
        let h1 = scope.spawn(|| coordinator::run_worker(&w1).unwrap());
        coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)).unwrap();
        h0.join().unwrap();
        h1.join().unwrap();
    });

    assert_identical_to_single(&merged, &single);
    // The cross-machine protocol really ran. Every epoch's own delta was
    // published to each worker's transport root (6 cells / epoch 2 = 3
    // epochs) ...
    for epoch in 0..3 {
        for (transport_root, own) in [(&t0, 0), (&t1, 1)] {
            let delta = transport_root
                .join("up/exchange/kernelskill")
                .join(format!("epoch-{epoch}.shard-{own}.json"));
            assert!(delta.exists(), "missing published delta {}", delta.display());
        }
    }
    // ... and the *peer's* deltas each worker actually had to fold (epochs
    // before its last window) were relayed into its local exchange dir.
    // The final epoch's peer delta is never folded by anyone, so it may
    // legitimately still be in flight when a worker exits.
    for epoch in 0..2 {
        for (dir, peer) in [(root.join("w0"), 1), (root.join("w1"), 0)] {
            let delta = dir
                .join("exchange")
                .join("kernelskill")
                .join(format!("epoch-{epoch}.shard-{peer}.json"));
            assert!(delta.exists(), "missing relayed peer delta {}", delta.display());
        }
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn mixed_device_exchange_relay_matches_sequential_threads() {
    // Heterogeneous fleet composed with live memory exchange: per-row
    // device presets AND --exchange-epoch on the same 2-worker mirror
    // fleet. The relay crosses device partitions — each worker folds peer
    // deltas carrying the *other* preset's evidence — and the merged
    // output must be byte-identical to two in-process per-device shard
    // threads trading deltas through one shared exchange dir.
    let root = tmp_root("mixed-exchange");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(TAKE).collect();
    let seeds: Vec<u64> = (0..SEEDS as u64).collect();
    let tpu_cfg = LoopConfig {
        dev: DeviceSpec::tpu_like(),
        ..LoopConfig::default()
    };
    let ref_opts = |run_dir: &Path, ex: &Path, index: usize| {
        let mut opts = SuiteOptions::in_dir(run_dir).with_shard(index, 2).with_exchange(ex, 2);
        if let Some(e) = opts.exchange.as_mut() {
            e.wait_timeout_ms = 60_000;
        }
        opts
    };
    let ex = root.join("ex-ref");
    let (r0, r1) = (root.join("ref0"), root.join("ref1"));
    std::thread::scope(|scope| {
        let a = scope.spawn(|| {
            coordinator::run_suite_with(
                &tasks,
                &baselines::kernelskill(),
                &LoopConfig::default(),
                &seeds,
                4,
                &ref_opts(&r0, &ex, 0),
            )
            .unwrap();
        });
        let b = scope.spawn(|| {
            coordinator::run_suite_with(
                &tasks,
                &baselines::kernelskill(),
                &tpu_cfg,
                &seeds,
                4,
                &ref_opts(&r1, &ex, 1),
            )
            .unwrap();
        });
        a.join().unwrap();
        b.join().unwrap();
    });
    let reference = root.join("reference");
    coordinator::merge_run_dirs(&reference, &[r0, r1]).unwrap();

    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_device_manifest(
        &mpath,
        2,
        &[("w0", 0, 0, &t0, None), ("w1", 1, 1, &t1, Some("tpu-like"))],
    );
    let manifest = WorkerManifest::load(&mpath).unwrap();

    let merged = root.join("merged");
    let mut w0 = worker_cfg(&manifest, "w0", &root.join("w0"));
    let mut w1 = worker_cfg(&manifest, "w1", &root.join("w1"));
    w0.exchange_epoch = Some(2);
    w1.exchange_epoch = Some(2);
    let report = std::thread::scope(|scope| {
        let h0 = scope.spawn(|| coordinator::run_worker(&w0).unwrap());
        let h1 = scope.spawn(|| coordinator::run_worker(&w1).unwrap());
        let fleet = coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)).unwrap();
        h0.join().unwrap();
        h1.join().unwrap();
        fleet
    });
    assert_eq!(report.merge.merged_cells, TAKE * SEEDS);
    assert!(report.merge.missing_shards.is_empty());

    assert_identical_to_single(&merged, &reference);
    let store = std::fs::read_to_string(merged.join("skills.json")).unwrap();
    assert!(
        store.contains("\"a100-like\"") && store.contains("\"tpu-like\""),
        "merged skills.json must hold both per-device partitions"
    );
    let m = coordinator::RunDir::open(&merged).unwrap().read_manifest().unwrap().unwrap();
    assert_eq!(m.device, "a100-like+tpu-like");

    // The relayed peer deltas each worker folded really carry the *other*
    // preset's partition: the exchange crossed the device boundary.
    for (dir, peer, peer_dev) in
        [(root.join("w0"), 1, "tpu-like"), (root.join("w1"), 0, "a100-like")]
    {
        let mut saw_peer_partition = false;
        for epoch in 0..2 {
            let delta = dir
                .join("exchange")
                .join("kernelskill")
                .join(format!("epoch-{epoch}.shard-{peer}.json"));
            assert!(delta.exists(), "missing relayed peer delta {}", delta.display());
            let text = std::fs::read_to_string(&delta).unwrap();
            saw_peer_partition |= text.contains(&format!("\"{peer_dev}\""));
        }
        assert!(
            saw_peer_partition,
            "no relayed delta under {} carried the peer's {peer_dev} partition",
            dir.display()
        );
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// Write an elastic 2-worker mirror-dir manifest: `total` lease batches,
/// a shared lease root, no shard ranges anywhere.
fn write_elastic_manifest(
    path: &Path,
    total: usize,
    lease_root: &Path,
    rows: &[(&str, &Path)],
) {
    let workers: Vec<String> = rows
        .iter()
        .map(|(id, root)| {
            format!(
                r#"{{"id":"{id}","transport":{{"kind":"mirror-dir","root":"{}"}}}}"#,
                root.to_string_lossy()
            )
        })
        .collect();
    std::fs::write(
        path,
        format!(
            r#"{{"version":1,"total_batches":{total},"lease":{{"kind":"mirror-dir","root":"{}"}},"workers":[{}]}}"#,
            lease_root.to_string_lossy(),
            workers.join(",")
        ),
    )
    .unwrap();
}

/// No transport may ever hold a whole-file `results.jsonl` under a batch
/// (or shard) dir — checkpoints travel as append-only segments, so a
/// growing checkpoint never re-pushes bytes already published.
fn assert_segments_only(transport_root: &Path) {
    let up = transport_root.join("up");
    let Ok(entries) = std::fs::read_dir(&up) else { return };
    for entry in entries {
        let dir = entry.unwrap().path();
        if !dir.is_dir() {
            continue;
        }
        assert!(
            !dir.join("results.jsonl").exists(),
            "{} holds a whole-file results.jsonl — the checkpoint was re-pushed wholesale",
            dir.display()
        );
    }
}

#[test]
fn elastic_fleet_with_killed_straggler_matches_single_process() {
    // The ISSUE-7 acceptance battery: a 2-worker *elastic* fleet where one
    // worker's machine dies mid-batch and is never restarted. The
    // coordinator must notice the frozen progress counter, expire the
    // lease, and the surviving worker must re-claim and recompute the
    // batch — with the merged output still byte-identical to a
    // single-process run.
    let root = tmp_root("elastic-kill");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    reference_run(&single);

    let mpath = root.join("workers.json");
    let (t0, t1, lease_root) = (root.join("t0"), root.join("t1"), root.join("lease"));
    write_elastic_manifest(&mpath, 3, &lease_root, &[("w0", &t0), ("w1", &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();
    assert!(manifest.is_elastic());

    let crash_marker = root.join("crash");
    let merged = root.join("merged");
    let fleet = std::thread::scope(|scope| {
        let coord = scope.spawn(|| {
            let mut cfg = fleet_cfg(manifest.clone(), &merged);
            cfg.lease_timeout_ms = 1_500;
            cfg.stall_timeout_ms = 120_000;
            coordinator::launch_workers(&cfg)
        });

        let mut w0 = spawn_worker_cli(&mpath, "w0", &root.join("w0"), &root.join("w0.log"), &[]);
        // w1's machine dies two sync cycles into its first batch — and
        // nobody restarts it: recovery must come from re-dispatch alone.
        let mut w1 = spawn_worker_cli(
            &mpath,
            "w1",
            &root.join("w1"),
            &root.join("w1.log"),
            &[
                ("KS_TEST_WORKER_CRASH_AFTER_SYNCS", "2"),
                ("KS_TEST_WORKER_CRASH_MARKER", &crash_marker.to_string_lossy()),
            ],
        );

        let status = w1.wait().unwrap();
        assert_eq!(status.code(), Some(86), "w1 must die via the crash hook");
        assert!(w0.wait().unwrap().success(), "w0 must finish the whole board");
        coord.join().unwrap().unwrap()
    });

    assert_eq!(fleet.merge.merged_cells, TAKE * SEEDS);
    assert!(fleet.merge.missing_shards.is_empty());
    // Every batch was finished by the survivor (w1 completed none).
    assert_eq!(fleet.workers[0].id, "w0");
    assert_eq!(fleet.workers[0].shards.len(), 3);
    assert!(fleet.workers[1].shards.is_empty());

    // The lease board records the re-dispatch: the batch w1 died holding
    // has an `.expired` attempt-0 marker and a done attempt-1 lease.
    let lease_files: Vec<String> = std::fs::read_dir(lease_root.join("leases"))
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    let expired: Vec<&String> =
        lease_files.iter().filter(|n| n.ends_with(".expired")).collect();
    assert!(
        !expired.is_empty(),
        "w1's frozen lease was never expired; board: {lease_files:?}"
    );
    // w1's batch in particular must have been re-claimed at attempt 1 (a
    // healthy batch can also be benignly expired right as its holder
    // finishes — done-on-attempt-0 then wins and no re-claim happens — so
    // the assertion is existential, not universal).
    assert!(
        expired.iter().any(|name| {
            let batch = name
                .strip_prefix("batch-")
                .and_then(|r| r.split('.').next())
                .unwrap();
            lease_files.contains(&format!("batch-{batch}.attempt-1.json"))
        }),
        "no expired batch was ever re-claimed; board: {lease_files:?}"
    );

    // Checkpoints crossed the transports as append-only segments only.
    assert_segments_only(&t0);
    assert_segments_only(&t1);

    assert_identical_to_single(&merged, &single);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn elastic_exchange_matches_single_process_launch() {
    // Elastic scheduling composed with live memory exchange: batches claim
    // dynamically AND fold peer deltas at epoch boundaries, relayed
    // between transports by the coordinator's route-all hub. The output
    // must be byte-identical to a --shards 1 launch with the same epoch.
    // Epoch (2 cells) never exceeds the batch size (2 cells) — the
    // documented composition rule that keeps lowest-first claiming ahead
    // of every window's peer set.
    let root = tmp_root("elastic-exchange");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();

    let single = root.join("single");
    let mut lc = LaunchConfig::new(bin(), "suite", &single, 1);
    lc.passthrough = [
        "--level", "1", "--take", "3", "--seeds", "2", "--workers", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    lc.exchange_epoch = Some(2);
    lc.child_env = vec![
        ("KS_TEST_CRASH_AFTER".to_string(), String::new()),
        ("KS_TEST_CRASH_MARKER".to_string(), String::new()),
    ];
    coordinator::launch(&lc).unwrap();

    let mpath = root.join("workers.json");
    let (t0, t1, lease_root) = (root.join("t0"), root.join("t1"), root.join("lease"));
    write_elastic_manifest(&mpath, 3, &lease_root, &[("w0", &t0), ("w1", &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    let merged = root.join("merged");
    let mut w0 = worker_cfg(&manifest, "w0", &root.join("w0"));
    let mut w1 = worker_cfg(&manifest, "w1", &root.join("w1"));
    w0.exchange_epoch = Some(2);
    w1.exchange_epoch = Some(2);
    std::thread::scope(|scope| {
        let h0 = scope.spawn(|| coordinator::run_worker(&w0).unwrap());
        let h1 = scope.spawn(|| coordinator::run_worker(&w1).unwrap());
        let fleet = coordinator::launch_workers(&fleet_cfg(manifest.clone(), &merged)).unwrap();
        let r0 = h0.join().unwrap();
        let r1 = h1.join().unwrap();
        // Dynamic placement: who ran what is undetermined, but together
        // they covered the board exactly.
        let mut batches: Vec<usize> =
            r0.shards.iter().chain(&r1.shards).map(|s| s.index).collect();
        batches.sort_unstable();
        assert_eq!(batches, vec![0, 1, 2]);
        assert_eq!(fleet.merge.merged_cells, TAKE * SEEDS);
    });

    assert_segments_only(&t0);
    assert_segments_only(&t1);
    assert_identical_to_single(&merged, &single);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn fleet_and_worker_refuse_bad_configs() {
    let root = tmp_root("bad");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let mpath = root.join("workers.json");
    let (t0, t1) = (root.join("t0"), root.join("t1"));
    write_manifest(&mpath, 2, &[("w0", 0, 0, &t0), ("w1", 1, 1, &t1)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    // Unknown worker id names the known ones.
    let cfg = worker_cfg(&manifest, "w9", &root.join("w9"));
    let err = coordinator::run_worker(&cfg).unwrap_err();
    assert!(err.contains("w9") && err.contains("w0") && err.contains("w1"), "{err}");

    // Exchange epoch 0 is refused.
    let mut cfg = worker_cfg(&manifest, "w0", &root.join("w0"));
    cfg.exchange_epoch = Some(0);
    let err = coordinator::run_worker(&cfg).unwrap_err();
    assert!(err.contains("--exchange-epoch"), "{err}");

    // A run dir already holding merged results is refused by the fleet
    // coordinator before any pulling starts.
    let dirty = root.join("dirty");
    std::fs::create_dir_all(&dirty).unwrap();
    std::fs::write(dirty.join("results.jsonl"), b"{\"x\":1}\n").unwrap();
    let err = coordinator::launch_workers(&fleet_cfg(manifest, &dirty)).unwrap_err();
    assert!(err.contains("already holds"), "{err}");

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn vanished_transport_root_and_absent_workers_fail_cleanly() {
    let root = tmp_root("vanish");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let mpath = root.join("workers.json");
    let t0 = root.join("t0");
    write_manifest(&mpath, 1, &[("w0", 0, 0, &t0)]);
    let manifest = WorkerManifest::load(&mpath).unwrap();

    // A transport root that disappears mid-run is an immediate, clean
    // error naming the worker — no panic, no hang.
    let mut cfg = fleet_cfg(manifest.clone(), &root.join("out1"));
    cfg.stall_timeout_ms = 30_000;
    let t0_del = t0.clone();
    let err = std::thread::scope(|scope| {
        // Delete the root repeatedly so one removal is guaranteed to land
        // after the coordinator built (and thereby created) the transport.
        scope.spawn(move || {
            for _ in 0..12 {
                std::thread::sleep(std::time::Duration::from_millis(250));
                let _ = std::fs::remove_dir_all(&t0_del);
            }
        });
        coordinator::launch_workers(&cfg).unwrap_err()
    });
    assert!(err.contains("disappeared") && err.contains("w0"), "{err}");

    // No worker ever publishing anything trips the stall timeout with a
    // pointed per-worker message instead of hanging forever.
    let mut cfg = fleet_cfg(manifest, &root.join("out2"));
    cfg.stall_timeout_ms = 1_000;
    let err = coordinator::launch_workers(&cfg).unwrap_err();
    assert!(err.contains("no progress") && err.contains("w0"), "{err}");

    let _ = std::fs::remove_dir_all(&root);
}
