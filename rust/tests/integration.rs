//! Integration tests: the full loop over the real task suite, cross-module
//! invariants, and the experiment harness end-to-end (small slices).

use kernelskill::baselines;
use kernelskill::bench_suite::{self, eager};
use kernelskill::coordinator::{self, Branch, LoopConfig};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::metrics;
use kernelskill::kir::transforms::MethodId;

fn cfg() -> LoopConfig {
    LoopConfig::default()
}

#[test]
fn full_pipeline_on_l2_slice() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(20).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    assert_eq!(suite.results.len(), 20);
    let refs: Vec<_> = suite.results.iter().collect();
    let c = metrics::cell(&refs, 15);
    assert!(c.success > 0.9, "KernelSkill should almost always succeed");
    assert!(c.speedup > 1.5, "L2 slice should average well past eager, got {}", c.speedup);
}

#[test]
fn kernelskill_beats_no_memory_on_every_level_slice() {
    for level in [1u8, 2, 3] {
        let take = if level == 3 { 12 } else { 25 };
        let tasks: Vec<_> = bench_suite::level_suite(42, level).into_iter().take(take).collect();
        let ks = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
        let nm = coordinator::run_suite(&tasks, &baselines::wo_memory(), &cfg(), &[0], 4);
        let ks_mean: f64 =
            ks.results.iter().map(|r| r.best_speedup).sum::<f64>() / take as f64;
        let nm_mean: f64 =
            nm.results.iter().map(|r| r.best_speedup).sum::<f64>() / take as f64;
        assert!(
            ks_mean > nm_mean,
            "L{level}: KernelSkill {ks_mean:.2} vs w/o memory {nm_mean:.2}"
        );
    }
}

#[test]
fn speedups_never_exceed_task_ceiling() {
    let dev = DeviceSpec::a100_like();
    let tasks: Vec<_> = bench_suite::full_suite(42).into_iter().take(60).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    for (task, result) in tasks.iter().zip(&suite.results) {
        let ceiling = eager::max_speedup(task, &dev);
        assert!(
            result.best_speedup <= ceiling * 1.05,
            "{}: {} > ceiling {}",
            task.id,
            result.best_speedup,
            ceiling
        );
    }
}

#[test]
fn winning_schedules_are_structurally_valid_and_legal() {
    let dev = DeviceSpec::a100_like();
    let tasks: Vec<_> = bench_suite::full_suite(42).into_iter().take(40).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    for (task, result) in tasks.iter().zip(&suite.results) {
        assert!(result.best_sched.validate(&task.graph).is_ok(), "{}", task.id);
        if result.success {
            let errs = kernelskill::kir::legality::check(&task.graph, &result.best_sched, &dev);
            assert!(errs.is_empty(), "{}: delivered kernel is illegal: {errs:?}", task.id);
        }
    }
}

#[test]
fn motivating_example_first_move_is_gemm_not_fusion() {
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks.iter().find(|t| t.id.contains("fused_epilogue")).unwrap();
    // Across several run seeds, KernelSkill's first optimization move on the
    // Appendix-D task must be the GEMM fix, never fusion (§3).
    for seed in 0..5 {
        let mut c = cfg();
        c.run_seed = seed;
        let r = coordinator::run_task(task, &baselines::kernelskill(), &c);
        let first = r.rounds.iter().find_map(|rec| match rec.branch {
            Branch::Optimize(m) => Some(m),
            _ => None,
        });
        assert_eq!(first, Some(MethodId::TileSmem), "seed {seed}");
    }
}

#[test]
fn repair_memory_prevents_budget_exhaustion() {
    // On the repair-heavy L3 slice, KernelSkill (with repair memory) must
    // succeed strictly more often than the same policy without it.
    let tasks: Vec<_> = bench_suite::level_suite(42, 3).into_iter().collect();
    let with_mem = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0, 1], 4);
    let without = coordinator::run_suite(&tasks, &baselines::wo_short_term(), &cfg(), &[0, 1], 4);
    let s_with = with_mem.results.iter().filter(|r| r.success).count();
    let s_without = without.results.iter().filter(|r| r.success).count();
    assert!(
        s_with >= s_without,
        "repair memory should not hurt success ({s_with} vs {s_without})"
    );
}

#[test]
fn stark_uses_its_30_round_budget() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 3).into_iter().take(8).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::stark(), &cfg(), &[0], 4);
    assert!(suite.results.iter().any(|r| r.rounds_used > 15));
}

#[test]
fn results_deterministic_across_parallelism() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(10).collect();
    let a = coordinator::run_suite(&tasks, &baselines::cudaforge(), &cfg(), &[3], 1);
    let b = coordinator::run_suite(&tasks, &baselines::cudaforge(), &cfg(), &[3], 8);
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.best_speedup, y.best_speedup, "{}", x.task_id);
        assert_eq!(x.rounds.len(), y.rounds.len());
    }
}

#[test]
fn audit_trail_present_for_decision_policy_runs() {
    use kernelskill::device::costmodel::price;
    use kernelskill::device::metrics::{synthesize, ToolVersion};
    use kernelskill::kir::features::ground_truth;
    use kernelskill::kir::schedule::Schedule;
    use kernelskill::memory::long_term::retrieval;
    let tasks = bench_suite::level_suite(42, 2);
    let task = &tasks[1];
    let sched = Schedule::per_op_naive(&task.graph);
    let dev = DeviceSpec::a100_like();
    let cost = price(&task.graph, &sched, &dev);
    let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
    let feats = ground_truth(&task.graph, &sched);
    let r = retrieval::retrieve_for(task, &feats, &raw);
    let audit = r.audit();
    assert!(audit.contains("bottleneck="));
    assert!(audit.contains("allowed:"));
    // Traceability: the matched case must justify every allowed method.
    if let Some(case_id) = r.matched_case {
        let case = kernelskill::memory::long_term::kb_content::DECISION_TABLE
            .iter()
            .find(|c| c.id == case_id)
            .unwrap();
        for m in &r.allowed_methods {
            assert!(case.allowed_methods.contains(m));
        }
    }
}
