//! Integration tests: the full loop over the real task suite, cross-module
//! invariants, the experiment harness end-to-end (small slices), and the
//! orchestration-v2 checkpoint/resume + persistent-memory contracts.

use std::path::PathBuf;

use kernelskill::baselines;
use kernelskill::bench_suite::{self, eager};
use kernelskill::coordinator::{self, Branch, LoopConfig, SuiteOptions};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::metrics;
use kernelskill::kir::transforms::MethodId;
use kernelskill::memory::long_term::SkillStore;

fn cfg() -> LoopConfig {
    LoopConfig::default()
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ks-integ-{tag}-{}", std::process::id()))
}

/// Exact equality of aggregate cells: a resumed run must be byte-identical
/// to an uninterrupted one, so f64 `==` is intended.
fn assert_cells_identical(a: &metrics::Cell, b: &metrics::Cell, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    assert_eq!(a.success, b.success, "{what}: success");
    assert_eq!(a.speedup, b.speedup, "{what}: speedup");
    assert_eq!(a.fast1, b.fast1, "{what}: fast1");
    assert_eq!(a.mean_rounds, b.mean_rounds, "{what}: mean_rounds");
    assert_eq!(a.speedup_per_round, b.speedup_per_round, "{what}: speedup_per_round");
}

#[test]
fn full_pipeline_on_l2_slice() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(20).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    assert_eq!(suite.results.len(), 20);
    let refs: Vec<_> = suite.results.iter().collect();
    let c = metrics::cell(&refs, 15);
    assert!(c.success > 0.9, "KernelSkill should almost always succeed");
    assert!(c.speedup > 1.5, "L2 slice should average well past eager, got {}", c.speedup);
}

#[test]
fn kernelskill_beats_no_memory_on_every_level_slice() {
    for level in [1u8, 2, 3] {
        let take = if level == 3 { 12 } else { 25 };
        let tasks: Vec<_> = bench_suite::level_suite(42, level).into_iter().take(take).collect();
        let ks = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
        let nm = coordinator::run_suite(&tasks, &baselines::wo_memory(), &cfg(), &[0], 4);
        let ks_mean: f64 =
            ks.results.iter().map(|r| r.best_speedup).sum::<f64>() / take as f64;
        let nm_mean: f64 =
            nm.results.iter().map(|r| r.best_speedup).sum::<f64>() / take as f64;
        assert!(
            ks_mean > nm_mean,
            "L{level}: KernelSkill {ks_mean:.2} vs w/o memory {nm_mean:.2}"
        );
    }
}

#[test]
fn speedups_never_exceed_task_ceiling() {
    let dev = DeviceSpec::a100_like();
    let tasks: Vec<_> = bench_suite::full_suite(42).into_iter().take(60).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    for (task, result) in tasks.iter().zip(&suite.results) {
        let ceiling = eager::max_speedup(task, &dev);
        assert!(
            result.best_speedup <= ceiling * 1.05,
            "{}: {} > ceiling {}",
            task.id,
            result.best_speedup,
            ceiling
        );
    }
}

#[test]
fn winning_schedules_are_structurally_valid_and_legal() {
    let dev = DeviceSpec::a100_like();
    let tasks: Vec<_> = bench_suite::full_suite(42).into_iter().take(40).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0], 4);
    for (task, result) in tasks.iter().zip(&suite.results) {
        assert!(result.best_sched.validate(&task.graph).is_ok(), "{}", task.id);
        if result.success {
            let errs = kernelskill::kir::legality::check(&task.graph, &result.best_sched, &dev);
            assert!(errs.is_empty(), "{}: delivered kernel is illegal: {errs:?}", task.id);
        }
    }
}

#[test]
fn motivating_example_first_move_is_gemm_not_fusion() {
    let tasks = bench_suite::level_suite(42, 2);
    let task = tasks.iter().find(|t| t.id.contains("fused_epilogue")).unwrap();
    // Across several run seeds, KernelSkill's first optimization move on the
    // Appendix-D task must be the GEMM fix, never fusion (§3).
    for seed in 0..5 {
        let mut c = cfg();
        c.run_seed = seed;
        let r = coordinator::run_task(task, &baselines::kernelskill(), &c);
        let first = r.rounds.iter().find_map(|rec| match rec.branch {
            Branch::Optimize(m) => Some(m),
            _ => None,
        });
        assert_eq!(first, Some(MethodId::TileSmem), "seed {seed}");
    }
}

#[test]
fn repair_memory_prevents_budget_exhaustion() {
    // On the repair-heavy L3 slice, KernelSkill (with repair memory) must
    // succeed strictly more often than the same policy without it.
    let tasks: Vec<_> = bench_suite::level_suite(42, 3).into_iter().collect();
    let with_mem = coordinator::run_suite(&tasks, &baselines::kernelskill(), &cfg(), &[0, 1], 4);
    let without = coordinator::run_suite(&tasks, &baselines::wo_short_term(), &cfg(), &[0, 1], 4);
    let s_with = with_mem.results.iter().filter(|r| r.success).count();
    let s_without = without.results.iter().filter(|r| r.success).count();
    assert!(
        s_with >= s_without,
        "repair memory should not hurt success ({s_with} vs {s_without})"
    );
}

#[test]
fn stark_uses_its_30_round_budget() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 3).into_iter().take(8).collect();
    let suite = coordinator::run_suite(&tasks, &baselines::stark(), &cfg(), &[0], 4);
    assert!(suite.results.iter().any(|r| r.rounds_used > 15));
}

#[test]
fn results_deterministic_across_parallelism() {
    let tasks: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(10).collect();
    let a = coordinator::run_suite(&tasks, &baselines::cudaforge(), &cfg(), &[3], 1);
    let b = coordinator::run_suite(&tasks, &baselines::cudaforge(), &cfg(), &[3], 8);
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.best_speedup, y.best_speedup, "{}", x.task_id);
        assert_eq!(x.rounds.len(), y.rounds.len());
    }
}

#[test]
fn audit_trail_present_for_decision_policy_runs() {
    use kernelskill::device::costmodel::price;
    use kernelskill::device::metrics::{synthesize, ToolVersion};
    use kernelskill::kir::features::ground_truth;
    use kernelskill::kir::schedule::Schedule;
    use kernelskill::memory::long_term::retrieval;
    let tasks = bench_suite::level_suite(42, 2);
    let task = &tasks[1];
    let sched = Schedule::per_op_naive(&task.graph);
    let dev = DeviceSpec::a100_like();
    let cost = price(&task.graph, &sched, &dev);
    let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
    let feats = ground_truth(&task.graph, &sched);
    let r = retrieval::retrieve_for(task, &feats, &raw);
    let audit = r.audit();
    assert!(audit.contains("bottleneck="));
    assert!(audit.contains("allowed:"));
    // Traceability: the matched case must justify every allowed method.
    if let Some(case_id) = r.matched_case {
        let case = kernelskill::memory::long_term::kb_content::DECISION_TABLE
            .iter()
            .find(|c| c.id == case_id)
            .unwrap();
        for m in &r.allowed_methods {
            assert!(case.allowed_methods.contains(m));
        }
    }
}

// ------------------------------------------------------------------------
// Orchestration v2: checkpoint / resume / persistent long-term memory.
// ------------------------------------------------------------------------

#[test]
fn interrupted_run_resumes_to_identical_aggregates() {
    let dir = tmp_dir("resume");
    let _ = std::fs::remove_dir_all(&dir);
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(6).collect();
    let strat = baselines::kernelskill();
    let seeds = [0u64, 1];

    // Uninterrupted reference (fully in-memory).
    let full = coordinator::run_suite(&tasks, &strat, &cfg(), &seeds, 4);

    // Kill the checkpointed run mid-matrix (5 of 12 cells complete) ...
    let mut opts = SuiteOptions::in_dir(&dir);
    opts.stop_after = Some(5);
    let partial = coordinator::run_suite_with(&tasks, &strat, &cfg(), &seeds, 4, &opts).unwrap();
    assert_eq!(partial.results.len(), 5, "kill point respected");

    // ... tear the checkpoint tail the way a hard kill would ...
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("results.jsonl"))
            .unwrap();
        f.write_all(b"{\"strategy\":\"KernelSkill\",\"task_id\":\"tr").unwrap();
    }

    // ... and resume.
    let resumed = coordinator::run_suite_with(
        &tasks,
        &strat,
        &cfg(),
        &seeds,
        4,
        &SuiteOptions::resumed(&dir),
    )
    .unwrap();
    assert_eq!(resumed.results.len(), full.results.len());
    for (a, b) in full.results.iter().zip(&resumed.results) {
        assert_eq!(a.task_id, b.task_id);
        assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
        assert_eq!(a.rounds, b.rounds, "{}", a.task_id);
    }
    let split_full = metrics::by_level(&full.results);
    let split_res = metrics::by_level(&resumed.results);
    for lvl in 0..3 {
        assert_cells_identical(
            &metrics::cell(&split_full[lvl], strat.rounds),
            &metrics::cell(&split_res[lvl], strat.rounds),
            &format!("level {}", lvl + 1),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resumed_run_with_warm_memory_matches_uninterrupted() {
    // Seed both memory dirs with the same learned store, then compare an
    // uninterrupted warm run against a killed + resumed warm run: the
    // snapshot persisted in the run dir must make them identical.
    let root = tmp_dir("warm-resume");
    let _ = std::fs::remove_dir_all(&root);
    let learn_dir = root.join("learn");
    let tasks_l1: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(4).collect();
    let strat = baselines::kernelskill();
    let mut learn_cfg = cfg();
    learn_cfg.memory_dir = Some(learn_dir.clone());
    coordinator::run_suite_with(&tasks_l1, &strat, &learn_cfg, &[0], 4, &SuiteOptions::default())
        .unwrap();
    let learned = SkillStore::load(&learn_dir.join("skills.json")).unwrap();
    assert!(learned.observations > 0, "learning run must record skills");

    let tasks_l2: Vec<_> = bench_suite::level_suite(42, 2).into_iter().take(4).collect();
    let mem_a = root.join("mem-a");
    let mem_b = root.join("mem-b");
    learned.save(&mem_a.join("skills.json")).unwrap();
    learned.save(&mem_b.join("skills.json")).unwrap();

    let mut cfg_a = cfg();
    cfg_a.memory_dir = Some(mem_a);
    let uninterrupted =
        coordinator::run_suite_with(&tasks_l2, &strat, &cfg_a, &[0], 4, &SuiteOptions::default())
            .unwrap();

    let run_dir = root.join("run");
    let mut cfg_b = cfg();
    cfg_b.memory_dir = Some(mem_b);
    let mut opts = SuiteOptions::in_dir(&run_dir);
    opts.stop_after = Some(2);
    coordinator::run_suite_with(&tasks_l2, &strat, &cfg_b, &[0], 4, &opts).unwrap();
    let resumed = coordinator::run_suite_with(
        &tasks_l2,
        &strat,
        &cfg_b,
        &[0],
        4,
        &SuiteOptions::resumed(&run_dir),
    )
    .unwrap();

    for (a, b) in uninterrupted.results.iter().zip(&resumed.results) {
        assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
        assert_eq!(a.rounds, b.rounds, "{}", a.task_id);
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn warm_memory_loads_from_disk_and_shows_in_audit() {
    use kernelskill::device::costmodel::price;
    use kernelskill::device::metrics::{synthesize, ToolVersion};
    use kernelskill::kir::features::ground_truth;
    use kernelskill::kir::schedule::Schedule;
    use kernelskill::memory::long_term::retrieval;

    let root = tmp_dir("warm-audit");
    let _ = std::fs::remove_dir_all(&root);
    let mem = root.join("memory");

    // Learn on a slice that includes the Appendix-D task: its first move is
    // the gemm.naive_loop -> TileSmem decision, so the store must end up
    // with that skill recorded.
    let tasks: Vec<_> = bench_suite::level_suite(42, 2)
        .into_iter()
        .filter(|t| t.id.contains("fused_epilogue"))
        .chain(bench_suite::level_suite(42, 1).into_iter().take(2))
        .collect();
    assert!(!tasks.is_empty());
    let mut mem_cfg = cfg();
    mem_cfg.memory_dir = Some(mem.clone());
    coordinator::run_suite_with(
        &tasks,
        &baselines::kernelskill(),
        &mem_cfg,
        &[0],
        2,
        &SuiteOptions::default(),
    )
    .unwrap();

    // The store was persisted to disk and holds the motivating skill.
    let store = SkillStore::load(&mem.join("skills.json")).unwrap();
    assert!(store.observations > 0);
    let stat = store
        .pooled_stat("gemm.naive_loop", MethodId::TileSmem)
        .expect("appendix-D run must record the TileSmem skill");
    assert!(stat.attempts > 0);
    // v3: suite runs record under the device partition they ran on (the
    // default LoopConfig device is the A100-like preset).
    assert!(
        store.stat_in("a100-like", "gemm.naive_loop", MethodId::TileSmem).is_some(),
        "observations must land in the matching device partition"
    );

    // Warm-started retrieval reflects the persisted skills in its audit.
    let task = bench_suite::level_suite(42, 2)
        .into_iter()
        .find(|t| t.id.contains("fused_epilogue"))
        .unwrap();
    let sched = Schedule::per_op_naive(&task.graph);
    let dev = DeviceSpec::a100_like();
    let cost = price(&task.graph, &sched, &dev);
    let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
    let feats = ground_truth(&task.graph, &sched);
    let r = retrieval::retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
    let audit = r.audit();
    assert!(
        audit.contains("skills (persistent long-term memory)"),
        "audit must surface persisted skills:\n{audit}"
    );
    assert!(audit.contains("tile_smem:"), "{audit}");
    let _ = std::fs::remove_dir_all(&root);
}
