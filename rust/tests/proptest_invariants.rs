//! Property-based tests over coordinator/kir invariants (hand-rolled
//! generators over the seeded RNG — the proptest crate is unavailable
//! offline, so each property sweeps a few hundred random cases).

use kernelskill::bench_suite::eager;
use kernelskill::coordinator::{
    batch_bounds, claim_next_batch, expire_lease, read_lease_board, Batch, LocalFs, Shard,
};
use kernelskill::device::costmodel;
use kernelskill::device::machine::DeviceSpec;
use kernelskill::kir::graph::KernelGraph;
use kernelskill::kir::op::{EwKind, NormKind, OpKind, RedKind};
use kernelskill::kir::schedule::Schedule;
use kernelskill::kir::transforms::{self, ALL_METHODS};
use kernelskill::memory::long_term::{SkillObs, SkillStore};
use kernelskill::memory::short_term::OptMemory;
use kernelskill::util::rng::Rng;

/// Random DAG generator: 1..=16 ops, chain-with-skips topology.
fn random_graph(rng: &mut Rng) -> KernelGraph {
    let mut g = KernelGraph::new();
    let n = rng.range_usize(1, 17);
    for i in 0..n {
        let m = 8 * rng.range(1, 129);
        let nn = 8 * rng.range(1, 129);
        let k = 8 * rng.range(1, 129);
        let kind = match rng.range(0, 8) {
            0 => OpKind::MatMul,
            1 => OpKind::Conv,
            2 => OpKind::Elementwise(EwKind::Relu),
            3 => OpKind::Elementwise(EwKind::Gelu),
            4 => OpKind::Reduction(RedKind::Row),
            5 => OpKind::Norm(NormKind::Softmax),
            6 => OpKind::Transpose,
            _ => OpKind::Elementwise(EwKind::Add),
        };
        let inputs = if i == 0 || rng.chance(0.15) {
            vec![]
        } else {
            vec![rng.range_usize(0, i)]
        };
        let kk = if matches!(kind, OpKind::MatMul | OpKind::Conv) { k } else { 1 };
        g.push(kind, m, nn, kk, inputs);
    }
    if rng.chance(0.2) {
        g.structured_operands = true;
    }
    g
}

/// Apply a random sequence of applicable transforms.
fn random_schedule(rng: &mut Rng, g: &KernelGraph) -> Schedule {
    let mut s = Schedule::per_op_naive(g);
    for _ in 0..rng.range_usize(0, 12) {
        let m = *rng.choose(&ALL_METHODS);
        let tg = rng.range_usize(0, s.num_kernels());
        if transforms::applicable_at(m, g, &s, tg).is_ok() {
            transforms::apply_at(m, g, &mut s, tg);
        }
    }
    s
}

#[test]
fn prop_transforms_preserve_schedule_validity() {
    let mut rng = Rng::new(101);
    for _ in 0..300 {
        let g = random_graph(&mut rng);
        let s = random_schedule(&mut rng, &g);
        assert!(s.validate(&g).is_ok(), "graph={} ops", g.len());
    }
}

#[test]
fn prop_cost_is_positive_and_roofline_bounded() {
    let mut rng = Rng::new(102);
    let dev = DeviceSpec::a100_like();
    for _ in 0..300 {
        let g = random_graph(&mut rng);
        let s = random_schedule(&mut rng, &g);
        let cost = costmodel::price(&g, &s, &dev);
        assert!(cost.total_s.is_finite() && cost.total_s > 0.0);
        let rl = costmodel::roofline_s(&g, &dev);
        assert!(
            cost.total_s >= rl * 0.999,
            "cost {} below roofline {}",
            cost.total_s,
            rl
        );
        let legal_rl = costmodel::legal_roofline_s(&g, &dev);
        assert!(legal_rl >= rl * 0.999, "legal roofline below ideal roofline");
    }
}

#[test]
fn prop_applicable_respects_apply_idempotence_guards() {
    // After applying a knob method everywhere, it must not remain
    // applicable at any group (no infinite self-application).
    let mut rng = Rng::new(103);
    let idempotent_guarded = [
        transforms::MethodId::TileSmem,
        transforms::MethodId::UseTensorCore,
        transforms::MethodId::VectorizeLoads,
        transforms::MethodId::DoubleBuffer,
        transforms::MethodId::PadScratch,
        transforms::MethodId::UnrollInner,
        transforms::MethodId::PrecisionDowncast,
        transforms::MethodId::SpecializeStructure,
    ];
    for _ in 0..200 {
        let g = random_graph(&mut rng);
        let mut s = random_schedule(&mut rng, &g);
        for &m in &idempotent_guarded {
            if transforms::applicable_at(m, &g, &s, 0).is_ok() {
                transforms::apply_at(m, &g, &mut s, 0);
                assert!(
                    transforms::applicable_at(m, &g, &s, 0).is_err(),
                    "{m:?} still applicable after whole-program apply"
                );
            }
        }
    }
}

#[test]
fn prop_fusion_methods_reduce_or_keep_kernel_count() {
    let mut rng = Rng::new(104);
    for _ in 0..200 {
        let g = random_graph(&mut rng);
        let mut s = Schedule::per_op_naive(&g);
        let before = s.num_kernels();
        for m in [
            transforms::MethodId::FuseElementwise,
            transforms::MethodId::FuseEpilogueReduction,
            transforms::MethodId::HorizontalFuse,
        ] {
            if transforms::applicable(m, &g, &s).is_ok() {
                transforms::apply(m, &g, &mut s);
            }
        }
        assert!(s.num_kernels() <= before);
        assert!(s.validate(&g).is_ok());
    }
}

#[test]
fn prop_speedup_monotone_in_custom_time() {
    // For any task, a schedule with lower custom_time has higher speedup.
    let mut rng = Rng::new(105);
    let dev = DeviceSpec::a100_like();
    let tasks = kernelskill::bench_suite::full_suite(42);
    for _ in 0..100 {
        let task = &tasks[rng.range_usize(0, tasks.len())];
        let a = random_schedule(&mut rng, &task.graph);
        let b = random_schedule(&mut rng, &task.graph);
        let (ta, tb) = (
            eager::custom_time_s(task, &a, &dev),
            eager::custom_time_s(task, &b, &dev),
        );
        let (sa, sb) = (eager::speedup(task, &a, &dev), eager::speedup(task, &b, &dev));
        if ta < tb {
            assert!(sa >= sb, "{}: time {ta} < {tb} but speedup {sa} < {sb}", task.id);
        }
    }
}

#[test]
fn prop_opt_memory_promotion_is_threshold_exact() {
    let mut rng = Rng::new(106);
    for _ in 0..500 {
        let base = rng.log_uniform(0.05, 10.0);
        let cand = rng.log_uniform(0.05, 10.0);
        let mem = OptMemory::new(0.3, 0.3, base);
        let expect = cand / base > 1.3 || cand - base > 0.3;
        assert_eq!(mem.should_promote(cand), expect, "base={base} cand={cand}");
    }
}

#[test]
fn prop_shard_slices_are_a_disjoint_exact_cover() {
    // For arbitrary matrix shapes and shard counts 1..=8: every cell of the
    // (task x seed) matrix is owned by exactly one shard, slices are stable
    // under re-enumeration, and sizes are balanced to within one cell.
    let mut rng = Rng::new(108);
    for _ in 0..300 {
        let n_tasks = rng.range_usize(1, 21);
        let n_seeds = rng.range_usize(1, 7);
        let n_cells = n_tasks * n_seeds;
        let count = rng.range_usize(1, 9);
        let mut owners = vec![0u32; n_cells];
        for index in 0..count {
            let shard = Shard { index, count };
            assert!(shard.validate().is_ok());
            let owned: Vec<usize> = (0..n_cells).filter(|&ci| shard.owns(ci)).collect();
            let again: Vec<usize> = (0..n_cells).filter(|&ci| shard.owns(ci)).collect();
            assert_eq!(owned, again, "slice must be stable under re-enumeration");
            let fair = n_cells / count;
            assert!(
                owned.len() == fair || owned.len() == fair + 1,
                "shard {index}/{count} owns {} of {n_cells} cells — unbalanced",
                owned.len()
            );
            for ci in owned {
                owners[ci] += 1;
            }
        }
        assert!(
            owners.iter().all(|&c| c == 1),
            "{n_tasks}x{n_seeds} matrix, {count} shards: not a disjoint exact cover"
        );
    }
}

#[test]
fn prop_batch_slices_are_a_contiguous_exact_cover() {
    // Elastic lease scheduling cuts the matrix into contiguous batches:
    // for arbitrary matrix sizes and batch counts 1..=8, the batches must
    // tile the cell range exactly (no gap, no overlap, ending at the
    // matrix), be balanced to within one cell, and agree with owns().
    let mut rng = Rng::new(110);
    for _ in 0..300 {
        let n_cells = rng.range_usize(1, 121);
        let count = rng.range_usize(1, 9);
        let mut prev_hi = 0usize;
        for index in 0..count {
            let batch = Batch { index, count };
            assert!(batch.validate().is_ok());
            let (lo, hi) = batch_bounds(index, count, n_cells);
            assert_eq!((lo, hi), batch.bounds(n_cells));
            assert_eq!(lo, prev_hi, "batch {index}/{count} must start where its predecessor ended");
            let fair = n_cells / count;
            assert!(
                hi - lo == fair || hi - lo == fair + 1,
                "batch {index}/{count} owns {} of {n_cells} cells — unbalanced",
                hi - lo
            );
            for ci in lo..hi {
                assert!(batch.owns(ci, n_cells));
            }
            if lo > 0 {
                assert!(!batch.owns(lo - 1, n_cells));
            }
            assert!(!batch.owns(hi, n_cells));
            prev_hi = hi;
        }
        assert_eq!(prev_hi, n_cells, "{count} batches must end at the {n_cells}-cell matrix");
    }
}

#[test]
fn prop_lease_claims_are_exclusive_under_worker_races() {
    // The elastic scheduling safety property: however many workers race
    // the lease board, every batch is claimed by exactly one of them
    // (first-publish-wins on the attempt file), and after the coordinator
    // expires an attempt the batch is re-claimed at exactly the next
    // attempt number — never in parallel with a live claim.
    let mut rng = Rng::new(111);
    for case in 0..12 {
        let total = rng.range_usize(1, 9);
        let n_workers = rng.range_usize(2, 7);
        let root = std::env::temp_dir().join(format!(
            "ks-prop-lease-{case}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);

        let claims: Vec<Vec<usize>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_workers)
                .map(|w| {
                    let root = &root;
                    scope.spawn(move || {
                        let t = LocalFs::new(root).unwrap();
                        let mut mine = Vec::new();
                        loop {
                            let board = read_lease_board(&t, total).unwrap();
                            if board.iter().all(|b| b.attempts > 0) {
                                break;
                            }
                            if let Some(lease) =
                                claim_next_batch(&t, &board, &format!("w{w}")).unwrap()
                            {
                                assert_eq!(lease.attempt, 0);
                                mine.push(lease.batch);
                            }
                        }
                        mine
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let mut owners = vec![0usize; total];
        for mine in &claims {
            for &b in mine {
                owners[b] += 1;
            }
        }
        assert!(
            owners.iter().all(|&c| c == 1),
            "{total} batches, {n_workers} racing workers: claims {owners:?} not exclusive"
        );

        // The board read back agrees with the winners' own records.
        let t = LocalFs::new(&root).unwrap();
        let board = read_lease_board(&t, total).unwrap();
        for st in &board {
            assert_eq!(st.attempts, 1, "batch {} must hold exactly one attempt", st.batch);
            assert!(!st.claimable(), "a held batch must not be claimable");
            let l = st.latest.as_ref().unwrap();
            let w: usize = l.worker.strip_prefix('w').unwrap().parse().unwrap();
            assert!(claims[w].contains(&st.batch), "board holder {} never claimed {}", l.worker, st.batch);
        }

        // Coordinator-side re-dispatch: expire a random subset of the
        // attempts; exactly those batches become claimable again, and a
        // fresh claim round takes them at attempt 1.
        let expired: Vec<usize> = (0..total).filter(|_| rng.chance(0.5)).collect();
        for &b in &expired {
            assert!(expire_lease(&t, b, 0).unwrap());
            // Expiry is idempotent: the second publish loses the race.
            assert!(!expire_lease(&t, b, 0).unwrap());
        }
        let board = read_lease_board(&t, total).unwrap();
        for st in &board {
            assert_eq!(st.claimable(), expired.contains(&st.batch));
        }
        let mut reclaimed = Vec::new();
        while let Some(lease) = claim_next_batch(&t, &read_lease_board(&t, total).unwrap(), "wr").unwrap() {
            assert_eq!(lease.attempt, 1, "a re-dispatched batch must be claimed at attempt 1");
            reclaimed.push(lease.batch);
        }
        reclaimed.sort_unstable();
        assert_eq!(reclaimed, expired, "exactly the expired batches must be re-claimable");

        let _ = std::fs::remove_dir_all(&root);
    }
}

#[test]
fn prop_confidence_rerank_is_invariant_under_shard_merge_order() {
    // The v3 contract: however a multiset of observations is partitioned
    // into shard stores and in whatever order those stores are merged, the
    // merged store serializes to the same bytes AND ranks methods
    // identically (confidence weighting, device partitions, and staleness
    // decay included) as the store a single process would have built.
    let cases = ["gemm.naive_loop", "gemm.exposed_pipeline", "access.strided"];
    let devices = ["a100-like", "tpu-like"];
    let mut rng = Rng::new(109);
    for _ in 0..40 {
        let n_obs = rng.range_usize(1, 60);
        let obs: Vec<SkillObs> = (0..n_obs)
            .map(|_| SkillObs {
                case_id: cases[rng.range_usize(0, cases.len())].to_string(),
                method: *rng.choose(&ALL_METHODS),
                gain: if rng.chance(0.3) {
                    None
                } else {
                    Some(rng.log_uniform(0.01, 10.0) - 1.0)
                },
                device: devices[rng.range_usize(0, devices.len())].to_string(),
            })
            .collect();

        let mut reference = SkillStore::new();
        reference.merge(&obs);
        let reference_bytes = reference.to_json().to_string();

        for &shards in &[2usize, 3, 5] {
            // Round-robin partition, then merge the shard stores in a
            // random order.
            let mut stores: Vec<SkillStore> = (0..shards).map(|_| SkillStore::new()).collect();
            for (i, o) in obs.iter().enumerate() {
                stores[i % shards].observe(o);
            }
            let mut order: Vec<usize> = (0..shards).collect();
            rng.shuffle(&mut order);
            let mut merged = SkillStore::new();
            for &i in &order {
                merged.merge_store(&stores[i]);
            }
            assert_eq!(merged, reference, "{shards} shards, order {order:?}");
            assert_eq!(
                merged.to_json().to_string(),
                reference_bytes,
                "merge must be byte-identical ({shards} shards, order {order:?})"
            );
            // Rerank parity on every (device, case) the run could consult —
            // including a device with no partition (pooled fallback) and
            // the pooled view itself.
            for device in devices.iter().copied().chain(["h100-like", ""]) {
                for case in &cases {
                    let mut a: Vec<_> = ALL_METHODS.to_vec();
                    let mut b: Vec<_> = ALL_METHODS.to_vec();
                    reference.rerank(device, case, &mut a);
                    merged.rerank(device, case, &mut b);
                    assert_eq!(a, b, "rerank diverged for ({device:?}, {case})");
                }
            }
        }
    }
}

#[test]
fn prop_level4_legality_is_total_deterministic_and_trap_free() {
    // The Level-4 fused-pipeline workload is built to stress kir::legality:
    // (a) its per-op naive starting point compiles clean on every device
    // preset (including cpu-like, which has no scratchpad at all); (b) any
    // sequence of *applicable* transforms keeps the partition valid and
    // never panics the checker; (c) the checker is deterministic; and
    // (d) a schedule the checker passes never hides a structural trap
    // (multi-GEMM or non-standalone-scan group) and always prices to a
    // finite positive cost.
    use kernelskill::kir::legality;

    let tasks = kernelskill::bench_suite::level_suite(42, 4);
    let devs = DeviceSpec::presets();
    assert_eq!(devs.len(), 5);
    for t in &tasks {
        let s = Schedule::per_op_naive(&t.graph);
        for d in &devs {
            assert!(
                legality::check(&t.graph, &s, d).is_empty(),
                "{} naive schedule illegal on {}",
                t.id,
                d.name
            );
        }
    }

    let mut rng = Rng::new(112);
    for _ in 0..150 {
        let task = &tasks[rng.range_usize(0, tasks.len())];
        let g = &task.graph;
        let mut s = Schedule::per_op_naive(g);
        for _ in 0..rng.range_usize(0, 12) {
            let m = *rng.choose(&ALL_METHODS);
            let tg = rng.range_usize(0, s.num_kernels());
            if transforms::applicable_at(m, g, &s, tg).is_ok() {
                transforms::apply_at(m, g, &mut s, tg);
            }
            assert!(s.validate(g).is_ok(), "{}: partition broken", task.id);
        }
        for d in &devs {
            let errs = legality::check(g, &s, d);
            assert_eq!(errs, legality::check(g, &s, d), "checker not deterministic");
            if errs.is_empty() {
                let c = costmodel::price(g, &s, d);
                assert!(
                    c.total_s.is_finite() && c.total_s > 0.0,
                    "{} on {}: legal schedule priced {}",
                    task.id,
                    d.name,
                    c.total_s
                );
                for group in &s.groups {
                    let gemms = group.iter().filter(|&&o| g.op(o).is_gemm_like()).count();
                    assert!(gemms <= 1, "{}: legal schedule fused {gemms} GEMMs", task.id);
                    if group.len() > 1 {
                        assert!(
                            !group.iter().any(|&o| matches!(g.op(o).kind, OpKind::Scan)),
                            "{}: legal schedule fused a scan",
                            task.id
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_feature_extraction_total_and_bounded() {
    let mut rng = Rng::new(107);
    for _ in 0..200 {
        let g = random_graph(&mut rng);
        let s = random_schedule(&mut rng, &g);
        for focus in 0..s.num_kernels() {
            let f = kernelskill::kir::features::ground_truth_at(&g, &s, focus);
            assert!(f.kernel_launches as usize == s.num_kernels());
            assert!(f.register_pressure <= 2);
        }
    }
}
