//! Level 2: 100 multi-operator tasks — fusion-dominated workloads.
//!
//! Each task is a short producer-consumer chain (GEMM/conv + elementwise
//! epilogue, optionally a row-reduction/normalization tail) in the style of
//! the paper's Appendix-D example. Eager runs one kernel per op, so the
//! ceiling comes from fusing intermediates away plus saved launches —
//! the regime where the paper reports 2.82x and Fast₁ = 1.00.

use super::task::Task;
use crate::kir::graph::KernelGraph;
use crate::kir::op::{EwKind, NormKind, OpKind, RedKind};
use crate::util::rng::Rng;

const EW_POOL: [EwKind; 8] = [
    EwKind::Add,
    EwKind::Mul,
    EwKind::Scale,
    EwKind::Clamp,
    EwKind::Relu,
    EwKind::Gelu,
    EwKind::Bias,
    EwKind::Residual,
];

fn dim(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    (((rng.log_uniform(lo as f64, hi as f64) as u64) + 7) / 8 * 8).max(8)
}

/// The Appendix-D shape: linear -> scale -> double -> clamp -> logsumexp ->
/// mish. Kept verbatim as task l2_000 and backed by the real Pallas
/// artifacts (`fused_epilogue`).
pub fn appendix_d_graph(b: u64, k: u64, n: u64) -> KernelGraph {
    let mut g = KernelGraph::new();
    let mm = g.push(OpKind::MatMul, b, n, k, vec![]);
    let bias = g.push(OpKind::Elementwise(EwKind::Bias), b, n, 1, vec![mm]);
    let sc = g.push(OpKind::Elementwise(EwKind::Scale), b, n, 1, vec![bias]);
    let rs = g.push(OpKind::Elementwise(EwKind::Residual), b, n, 1, vec![sc]);
    let cl = g.push(OpKind::Elementwise(EwKind::Clamp), b, n, 1, vec![rs]);
    let lse = g.push(OpKind::Reduction(RedKind::Row), b, n, 1, vec![cl]);
    let _ = g.push(OpKind::Elementwise(EwKind::Mish), b, 1, 1, vec![lse]);
    g
}

pub fn generate(rng: &mut Rng) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(100);

    // Task 0: the paper's motivating example, artifact-backed.
    tasks.push(Task {
        id: "l2_000_fused_epilogue".to_string(),
        level: 2,
        name: "fused_epilogue".to_string(),
        graph: appendix_d_graph(1024, 8192, 8192),
        eager_waste: 1.0,
        sched_ceiling: 3.2,
        strict_tolerance: false,
        translation_risk: 0.1,
        artifact: Some("fused_epilogue".to_string()),
    });

    for i in 1..100 {
        let mut g = KernelGraph::new();
        let family = rng.range(0, 4);
        let name;
        match family {
            0 => {
                // GEMM + elementwise epilogue chain (2-5 ew ops).
                name = "gemm_epilogue";
                let m = dim(rng, 256, 2048);
                let n = dim(rng, 256, 4096);
                let k = dim(rng, 256, 4096);
                let mut prev = g.push(OpKind::MatMul, m, n, k, vec![]);
                for _ in 0..rng.range(2, 6) {
                    let ew = *rng.choose(&EW_POOL);
                    prev = g.push(OpKind::Elementwise(ew), m, n, 1, vec![prev]);
                }
            }
            1 => {
                // GEMM + epilogue + row-reduction tail (Appendix-D style).
                name = "gemm_reduce";
                let m = dim(rng, 256, 2048);
                let n = dim(rng, 512, 4096);
                let k = dim(rng, 512, 4096);
                let mut prev = g.push(OpKind::MatMul, m, n, k, vec![]);
                for _ in 0..rng.range(1, 4) {
                    prev = g.push(OpKind::Elementwise(*rng.choose(&EW_POOL)), m, n, 1, vec![prev]);
                }
                let red = g.push(OpKind::Reduction(RedKind::Row), m, n, 1, vec![prev]);
                let _ = g.push(OpKind::Elementwise(EwKind::Mish), m, 1, 1, vec![red]);
            }
            2 => {
                // Conv + norm + activation (vision block).
                name = "conv_norm_act";
                let m = dim(rng, 512, 4096);
                let n = dim(rng, 128, 1024);
                let k = dim(rng, 128, 2048);
                let c = g.push(OpKind::Conv, m, n, k, vec![]);
                let bn = g.push(OpKind::Norm(NormKind::BatchNorm), m, n, 1, vec![c]);
                let _ = g.push(OpKind::Elementwise(EwKind::Relu), m, n, 1, vec![bn]);
            }
            _ => {
                // Pure elementwise/norm chain over a big tensor.
                name = "ew_chain";
                let m = dim(rng, 1024, 8192);
                let n = dim(rng, 1024, 4096);
                let mut prev = g.push(OpKind::Elementwise(*rng.choose(&EW_POOL)), m, n, 1, vec![]);
                for _ in 0..rng.range(2, 6) {
                    prev = g.push(OpKind::Elementwise(*rng.choose(&EW_POOL)), m, n, 1, vec![prev]);
                }
                if rng.chance(0.4) {
                    let _ = g.push(OpKind::Norm(NormKind::LayerNorm), m, n, 1, vec![prev]);
                }
            }
        }
        // Occasional exotic-chain waste (eager composes transcendentals).
        let waste = if rng.chance(0.2) {
            rng.lognormal(1.8f64.ln(), 0.3).clamp(1.0, 4.0)
        } else {
            1.0
        };
        tasks.push(Task {
            id: format!("l2_{i:03}_{name}"),
            level: 2,
            name: name.to_string(),
            graph: g,
            eager_waste: waste,
            sched_ceiling: rng.lognormal(3.0f64.ln(), 0.35).clamp(1.05, 8.0),
            strict_tolerance: rng.chance(0.2),
            translation_risk: if rng.chance(0.08) {
                rng.log_uniform(0.55, 0.9)
            } else {
                rng.log_uniform(0.06, 0.2)
            },
            artifact: None,
        });
    }

    assert_eq!(tasks.len(), 100);
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::eager;
    use crate::device::machine::DeviceSpec;
    use crate::util::stats;

    #[test]
    fn generates_100_multi_op_tasks() {
        let tasks = generate(&mut Rng::new(42));
        assert_eq!(tasks.len(), 100);
        for t in &tasks {
            assert!(t.graph.validate().is_ok(), "{}", t.id);
            assert!(t.graph.len() >= 3, "{} has {} ops", t.id, t.graph.len());
        }
    }

    #[test]
    fn appendix_d_matches_paper_shape() {
        let g = appendix_d_graph(1024, 8192, 8192);
        assert_eq!(g.len(), 7);
        assert!(g.dominant_op().unwrap().is_gemm_like());
        assert!(g.dominant_flop_fraction() > 0.99);
        assert!(g.has_row_reduction());
    }

    #[test]
    fn ceilings_are_fusion_scaled() {
        let dev = DeviceSpec::a100_like();
        let tasks = generate(&mut Rng::new(42));
        let ceilings: Vec<f64> = tasks.iter().map(|t| eager::max_speedup(t, &dev)).collect();
        let m = stats::mean(&ceilings);
        assert!(m > 2.5 && m < 8.0, "L2 mean ceiling {m}");
        // Fast1 = 1.00 on L2 in the paper: essentially every task's ceiling
        // clears parity.
        let below = ceilings.iter().filter(|c| **c < 1.0).count();
        assert!(below <= 2, "L2 sub-parity tasks: {below}");
    }
}
