//! Level 3: 50 model-architecture tasks — many-op graphs where launch
//! overhead, mixed bottlenecks, and repair difficulty dominate.
//!
//! Graphs are transformer blocks, MLP stacks, and conv backbones with
//! 12-40 ops. The paper's L3 regime: modest ceilings (1.92x achieved),
//! hardest repairs (training-based baselines collapse to 0.46 success),
//! and a handful of library-dominated models where custom kernels never
//! reach parity (Fast₁ = 0.82).

use super::task::Task;
use crate::kir::graph::KernelGraph;
use crate::kir::op::{EwKind, NormKind, OpKind, RedKind};
use crate::util::rng::Rng;

fn dim(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    (((rng.log_uniform(lo as f64, hi as f64) as u64) + 7) / 8 * 8).max(8)
}

/// One transformer encoder block: qkv projections, attention score GEMM,
/// softmax, value GEMM, output projection, residual/norm, MLP.
fn transformer_block(
    g: &mut KernelGraph,
    rng: &mut Rng,
    seq: u64,
    d: u64,
    prev_in: Option<usize>,
) -> usize {
    let inp = prev_in.map(|p| vec![p]).unwrap_or_default();
    let q = g.push(OpKind::MatMul, seq, d, d, inp.clone());
    let k = g.push(OpKind::MatMul, seq, d, d, inp.clone());
    let v = g.push(OpKind::MatMul, seq, d, d, inp);
    let scores = g.push(OpKind::MatMul, seq, seq, d, vec![q, k]);
    let sm = g.push(OpKind::Norm(NormKind::Softmax), seq, seq, 1, vec![scores]);
    let ctx = g.push(OpKind::MatMul, seq, d, seq, vec![sm, v]);
    let proj = g.push(OpKind::MatMul, seq, d, d, vec![ctx]);
    let res = g.push(OpKind::Elementwise(EwKind::Residual), seq, d, 1, vec![proj]);
    let ln = g.push(OpKind::Norm(NormKind::LayerNorm), seq, d, 1, vec![res]);
    let h = dim(rng, 2 * d, 4 * d + 8);
    let up = g.push(OpKind::MatMul, seq, h, d, vec![ln]);
    let act = g.push(OpKind::Elementwise(EwKind::Gelu), seq, h, 1, vec![up]);
    let down = g.push(OpKind::MatMul, seq, d, h, vec![act]);
    let res2 = g.push(OpKind::Elementwise(EwKind::Residual), seq, d, 1, vec![down]);
    g.push(OpKind::Norm(NormKind::LayerNorm), seq, d, 1, vec![res2])
}

/// Conv backbone stage: conv + bn + relu (+ pool).
fn conv_stage(g: &mut KernelGraph, rng: &mut Rng, hw: u64, c: u64, prev: Option<usize>) -> usize {
    let inp = prev.map(|p| vec![p]).unwrap_or_default();
    let conv = g.push(OpKind::Conv, hw, c, c * 9, inp);
    let bn = g.push(OpKind::Norm(NormKind::BatchNorm), hw, c, 1, vec![conv]);
    let relu = g.push(OpKind::Elementwise(EwKind::Relu), hw, c, 1, vec![bn]);
    if rng.chance(0.5) {
        g.push(OpKind::Pool, hw, c, 1, vec![relu])
    } else {
        relu
    }
}

pub fn generate(rng: &mut Rng) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(50);
    for i in 0..50 {
        let mut g = KernelGraph::new();
        let family = i % 3;
        let name = match family {
            0 => {
                // 1-2 transformer blocks.
                let seq = dim(rng, 128, 1024);
                let d = dim(rng, 256, 1024);
                let blocks = rng.range(1, 3);
                let mut prev = None;
                for _ in 0..blocks {
                    prev = Some(transformer_block(&mut g, rng, seq, d, prev));
                }
                "transformer"
            }
            1 => {
                // Conv backbone (3-8 stages) + classifier head.
                let mut hw = dim(rng, 2048, 16384);
                let mut c = dim(rng, 32, 128);
                let stages = rng.range(3, 9);
                let mut prev = None;
                for _ in 0..stages {
                    prev = Some(conv_stage(&mut g, rng, hw, c, prev));
                    hw = (hw / 2).max(64);
                    c = (c * 2).min(1024);
                }
                let head = g.push(OpKind::Reduction(RedKind::Row), 8, c, 1, vec![prev.unwrap()]);
                let _ = g.push(OpKind::MatMul, 8, 1000, c, vec![head]);
                "convnet"
            }
            _ => {
                // Deep MLP with activations and norms.
                let b = dim(rng, 64, 512);
                let mut width = dim(rng, 512, 2048);
                let layers = rng.range(4, 10);
                let mut prev: Option<usize> = None;
                for _ in 0..layers {
                    let next_w = dim(rng, 512, 2048);
                    let mm = g.push(
                        OpKind::MatMul,
                        b,
                        next_w,
                        width,
                        prev.map(|p| vec![p]).unwrap_or_default(),
                    );
                    let act = g.push(OpKind::Elementwise(EwKind::Gelu), b, next_w, 1, vec![mm]);
                    prev = Some(if rng.chance(0.4) {
                        g.push(OpKind::Norm(NormKind::LayerNorm), b, next_w, 1, vec![act])
                    } else {
                        act
                    });
                    width = next_w;
                }
                "mlp"
            }
        };

        let g_len = g.len();
        tasks.push(Task {
            id: format!("l3_{i:03}_{name}"),
            level: 3,
            name: name.to_string(),
            graph: g,
            eager_waste: if rng.chance(0.25) {
                rng.lognormal(1.5f64.ln(), 0.25).clamp(1.0, 3.0)
            } else {
                1.0
            },
            // Library-dominated models (cuDNN-tuned convnets) carry a
            // sub-parity quality ceiling: the paper's Fast1 < 1 cases on L3.
            sched_ceiling: if name == "convnet" && rng.chance(0.5) {
                rng.lognormal(0.92f64.ln(), 0.12).clamp(0.5, 1.1)
            } else {
                rng.lognormal(2.2f64.ln(), 0.30).clamp(1.0, 5.0)
            },
            strict_tolerance: rng.chance(0.15),
            // Whole-model translation is the L3 nightmare: risk grows with
            // graph size, with a heavy tail of near-impossible models.
            translation_risk: if rng.chance(0.2) {
                rng.log_uniform(0.75, 0.95)
            } else {
                (0.25 + 0.015 * g_len as f64).min(0.8)
            },
            artifact: None,
        });
    }
    assert_eq!(tasks.len(), 50);
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::eager;
    use crate::device::machine::DeviceSpec;
    use crate::util::stats;

    #[test]
    fn generates_50_deep_graphs() {
        let tasks = generate(&mut Rng::new(42));
        assert_eq!(tasks.len(), 50);
        for t in &tasks {
            assert!(t.graph.validate().is_ok(), "{}", t.id);
            assert!(t.graph.len() >= 8, "{} has {} ops", t.id, t.graph.len());
        }
    }

    #[test]
    fn launch_overhead_matters_at_l3() {
        use crate::kir::schedule::Schedule;
        let dev = DeviceSpec::a100_like();
        let tasks = generate(&mut Rng::new(42));
        // On per-op schedules, a meaningful share of eager time is launches.
        let t = &tasks[0];
        let s = Schedule::per_op_naive(&t.graph);
        let c = crate::device::costmodel::price(&t.graph, &s, &dev);
        assert!(c.launch_fraction() > 0.005);
    }

    #[test]
    fn ceilings_modest_with_some_sub_parity() {
        let dev = DeviceSpec::a100_like();
        let tasks = generate(&mut Rng::new(42));
        let ceilings: Vec<f64> = tasks.iter().map(|t| eager::max_speedup(t, &dev)).collect();
        let m = stats::mean(&ceilings);
        assert!(m > 1.7 && m < 5.0, "L3 mean ceiling {m}");
        let below = ceilings.iter().filter(|c| **c < 1.0).count();
        assert!(below >= 2 && below <= 15, "L3 sub-parity: {below}");
    }

    #[test]
    fn fault_scale_highest_at_l3() {
        let l3 = generate(&mut Rng::new(42));
        let mut r1 = Rng::new(42);
        let l1 = crate::bench_suite::level1::generate(&mut r1);
        let m3 = stats::mean(&l3.iter().map(|t| t.fault_scale()).collect::<Vec<_>>());
        let m1 = stats::mean(&l1.iter().map(|t| t.fault_scale()).collect::<Vec<_>>());
        assert!(m3 > m1 + 0.5);
    }
}
