//! Level 4: 40 generated fused multi-kernel pipelines — a stress workload
//! whose graphs are deliberately shaped so that the *tempting* schedule
//! transform on each is structurally illegal.
//!
//! Level 1-3 graphs mostly punish bad schedules through the cost model;
//! Level 4 punishes them through `kir::legality`. Each family is built
//! around one fusion/tiling trap:
//!
//! * `gemm_chain`      — back-to-back GEMM+epilogue stages; fusing two
//!   adjacent GEMMs trips `multi_gemm_fusion`.
//! * `scan_pipeline`   — elementwise → scan → elementwise stages; any
//!   fusion across the scan trips `scan_fusion`.
//! * `splitk_tail`     — a deep-K GEMM feeding a reduction/softmax tail;
//!   split-K on the fused tail trips `splitk_fused_reduction`.
//! * `scatter_gather`  — GEMM feeding column-reduction and scatter
//!   consumers; fusing them trips `cross_block_fusion`.
//! * `ragged_attention`— attention with dims nudged off 8-alignment (the
//!   MXU trap, `mxu_alignment`) plus an independent side stream big
//!   enough that horizontal batching trips `disconnected_fusion`.
//!
//! Not part of `full_suite` (the 250-task paper population); reachable as
//! `level_suite(seed, 4)` and via `--level 4`.

use super::task::Task;
use crate::kir::graph::KernelGraph;
use crate::kir::op::{EwKind, NormKind, OpKind, RedKind};
use crate::util::rng::Rng;

/// 8-aligned log-uniform dim (the MXU-friendly default, as in Level 3).
fn dim(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    (((rng.log_uniform(lo as f64, hi as f64) as u64) + 7) / 8 * 8).max(8)
}

/// Deliberately misaligned: an aligned dim nudged off by 1-7, so the MXU
/// path's 8-alignment requirement can never be satisfied on it.
fn ragged(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    dim(rng, lo, hi) + rng.range(1, 8)
}

pub fn generate(rng: &mut Rng) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(40);
    for i in 0..40 {
        let mut g = KernelGraph::new();
        let family = i % 5;
        let name = match family {
            0 => {
                // 3-5 GEMM+epilogue stages chained end to end.
                let b = dim(rng, 64, 512);
                let mut w = dim(rng, 256, 1024);
                let stages = rng.range(3, 6);
                let mut prev: Option<usize> = None;
                for _ in 0..stages {
                    let next = dim(rng, 256, 1024);
                    let mm = g.push(
                        OpKind::MatMul,
                        b,
                        next,
                        w,
                        prev.map(|p| vec![p]).unwrap_or_default(),
                    );
                    prev = Some(g.push(OpKind::Elementwise(EwKind::Relu), b, next, 1, vec![mm]));
                    w = next;
                }
                "gemm_chain"
            }
            1 => {
                // 2-4 elementwise → scan → elementwise stages.
                let m = dim(rng, 512, 4096);
                let n = dim(rng, 64, 512);
                let stages = rng.range(2, 5);
                let mut prev: Option<usize> = None;
                for _ in 0..stages {
                    let ew = g.push(
                        OpKind::Elementwise(EwKind::Gelu),
                        m,
                        n,
                        1,
                        prev.map(|p| vec![p]).unwrap_or_default(),
                    );
                    let sc = g.push(OpKind::Scan, m, n, 1, vec![ew]);
                    prev = Some(g.push(OpKind::Elementwise(EwKind::Relu), m, n, 1, vec![sc]));
                }
                "scan_pipeline"
            }
            2 => {
                // Deep-K GEMM whose natural split-K collides with the
                // fused reduction/softmax tail.
                let m = dim(rng, 64, 256);
                let n = dim(rng, 64, 256);
                let k = dim(rng, 4096, 16384);
                let mm = g.push(OpKind::MatMul, m, n, k, vec![]);
                let bias = g.push(OpKind::Elementwise(EwKind::Residual), m, n, 1, vec![mm]);
                let red = g.push(OpKind::Reduction(RedKind::Row), m, n, 1, vec![bias]);
                let _ = g.push(OpKind::Norm(NormKind::Softmax), m, n, 1, vec![red]);
                "splitk_tail"
            }
            3 => {
                // GEMM feeding cross-block consumers (col-reduction,
                // scatter) that must stay in their own kernels.
                let m = dim(rng, 128, 512);
                let n = dim(rng, 128, 512);
                let k = dim(rng, 256, 2048);
                let mm = g.push(OpKind::MatMul, m, n, k, vec![]);
                let col = g.push(OpKind::Reduction(RedKind::Col), m, n, 1, vec![mm]);
                let sc = g.push(OpKind::Scatter, m, n, 1, vec![col]);
                let _ = g.push(OpKind::Elementwise(EwKind::Relu), m, n, 1, vec![sc]);
                "scatter_gather"
            }
            _ => {
                // Attention block on ragged (non-8-aligned) dims, plus an
                // independent large side stream with no dataflow into it.
                let seq = ragged(rng, 128, 512);
                let d = ragged(rng, 128, 512);
                let q = g.push(OpKind::MatMul, seq, d, d, vec![]);
                let kk = g.push(OpKind::MatMul, seq, d, d, vec![]);
                let scores = g.push(OpKind::MatMul, seq, seq, d, vec![q, kk]);
                let sm = g.push(OpKind::Norm(NormKind::Softmax), seq, seq, 1, vec![scores]);
                let _ = g.push(OpKind::MatMul, seq, d, seq, vec![sm]);
                let side = dim(rng, 1024, 4096);
                let e = g.push(OpKind::Elementwise(EwKind::Gelu), side, side, 1, vec![]);
                let _ = g.push(OpKind::Reduction(RedKind::Row), side, side, 1, vec![e]);
                "ragged_attention"
            }
        };

        let g_len = g.len();
        tasks.push(Task {
            id: format!("l4_{i:03}_{name}"),
            level: 4,
            name: name.to_string(),
            graph: g,
            eager_waste: if rng.chance(0.3) {
                rng.lognormal(1.4f64.ln(), 0.25).clamp(1.0, 3.0)
            } else {
                1.0
            },
            // Fused pipelines carry real fusion headroom — when the legal
            // schedule is found.
            sched_ceiling: rng.lognormal(2.4f64.ln(), 0.30).clamp(1.2, 6.0),
            strict_tolerance: rng.chance(0.2),
            // Multi-kernel pipelines are moderately hard translations;
            // risk grows with graph size like Level 3's.
            translation_risk: (0.2 + 0.015 * g_len as f64).min(0.7),
            artifact: None,
        });
    }
    assert_eq!(tasks.len(), 40);
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::machine::DeviceSpec;
    use crate::kir::legality;
    use crate::kir::schedule::Schedule;

    #[test]
    fn generates_40_valid_pipelines() {
        let tasks = generate(&mut Rng::new(42));
        assert_eq!(tasks.len(), 40);
        let dev = DeviceSpec::a100_like();
        for t in &tasks {
            assert_eq!(t.level, 4, "{}", t.id);
            assert!(t.graph.validate().is_ok(), "{}", t.id);
            assert!(t.graph.len() >= 4, "{} has {} ops", t.id, t.graph.len());
            // The per-op naive schedule must always compile: the traps are
            // in the transforms, not the starting point.
            let s = Schedule::per_op_naive(&t.graph);
            assert!(legality::check(&t.graph, &s, &dev).is_empty(), "{}", t.id);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(&mut Rng::new(7));
        let b = generate(&mut Rng::new(7));
        let ids_a: Vec<&str> = a.iter().map(|t| t.id.as_str()).collect();
        let ids_b: Vec<&str> = b.iter().map(|t| t.id.as_str()).collect();
        assert_eq!(ids_a, ids_b);
    }

    #[test]
    fn gemm_chain_refuses_adjacent_gemm_fusion() {
        let tasks = generate(&mut Rng::new(42));
        let t = tasks.iter().find(|t| t.name == "gemm_chain").unwrap();
        let dev = DeviceSpec::a100_like();
        // Fuse the first three per-op groups: GEMM + epilogue + next GEMM.
        let mut s = Schedule::per_op_naive(&t.graph);
        s.merge_groups(0, 1);
        s.merge_groups(0, 1);
        let errs = legality::check(&t.graph, &s, &dev);
        assert!(errs.iter().any(|e| e.rule == "multi_gemm_fusion"), "{errs:?}");
    }

    #[test]
    fn scan_pipeline_refuses_fusion_across_the_scan() {
        let tasks = generate(&mut Rng::new(42));
        let t = tasks.iter().find(|t| t.name == "scan_pipeline").unwrap();
        let dev = DeviceSpec::a100_like();
        // Group 0 is the leading elementwise, group 1 the scan.
        let mut s = Schedule::per_op_naive(&t.graph);
        s.merge_groups(0, 1);
        let errs = legality::check(&t.graph, &s, &dev);
        assert!(errs.iter().any(|e| e.rule == "scan_fusion"), "{errs:?}");
    }

    #[test]
    fn ragged_attention_dims_defeat_the_mxu_path() {
        let tasks = generate(&mut Rng::new(42));
        let t = tasks.iter().find(|t| t.name == "ragged_attention").unwrap();
        let dev = DeviceSpec::a100_like();
        let mut s = Schedule::per_op_naive(&t.graph);
        s.cfg[0].mxu = true;
        s.cfg[0].staging = true;
        let errs = legality::check(&t.graph, &s, &dev);
        assert!(errs.iter().any(|e| e.rule == "mxu_alignment"), "{errs:?}");
    }
}
