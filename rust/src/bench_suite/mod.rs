//! KernelBenchSim: the 250-task benchmark suite (100 + 100 + 50) standing in
//! for KernelBench Levels 1-3 (DESIGN.md §Substitutions), plus the
//! Torch-Eager baseline cost model.

pub mod eager;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod task;

use crate::util::rng::Rng;
pub use task::Task;

/// Generate the full suite for one suite seed. Deterministic.
pub fn full_suite(seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    let mut tasks = level1::generate(&mut rng.child("l1"));
    tasks.extend(level2::generate(&mut rng.child("l2")));
    tasks.extend(level3::generate(&mut rng.child("l3")));
    tasks
}

/// Tasks of one level only.
pub fn level_suite(seed: u64, level: u8) -> Vec<Task> {
    full_suite(seed).into_iter().filter(|t| t.level == level).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_suite_is_250() {
        let tasks = full_suite(42);
        assert_eq!(tasks.len(), 250);
        assert_eq!(tasks.iter().filter(|t| t.level == 1).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 2).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 3).count(), 50);
    }

    #[test]
    fn ids_unique() {
        let tasks = full_suite(42);
        let mut ids: Vec<&str> = tasks.iter().map(|t| t.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 250);
    }
}
