//! KernelBenchSim: the 250-task benchmark suite (100 + 100 + 50) standing in
//! for KernelBench Levels 1-3 (DESIGN.md §Substitutions), plus the
//! Torch-Eager baseline cost model. A generated Level-4 fused-pipeline
//! stress workload (`level4`, 40 tasks) rides alongside — reachable via
//! `level_suite(seed, 4)` but deliberately outside the 250-task paper
//! population.

pub mod eager;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod level4;
pub mod task;

use crate::util::rng::Rng;
pub use task::Task;

/// Generate the full suite for one suite seed. Deterministic.
pub fn full_suite(seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    let mut tasks = level1::generate(&mut rng.child("l1"));
    tasks.extend(level2::generate(&mut rng.child("l2")));
    tasks.extend(level3::generate(&mut rng.child("l3")));
    tasks
}

/// Tasks of one level only. Levels 1-3 slice the 250-task paper suite;
/// Level 4 is the generated fused-pipeline stress workload
/// (`bench_suite::level4`), which is *not* part of `full_suite`.
pub fn level_suite(seed: u64, level: u8) -> Vec<Task> {
    if level == 4 {
        let mut rng = Rng::new(seed);
        return level4::generate(&mut rng.child("l4"));
    }
    full_suite(seed).into_iter().filter(|t| t.level == level).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_suite_is_250() {
        let tasks = full_suite(42);
        assert_eq!(tasks.len(), 250);
        assert_eq!(tasks.iter().filter(|t| t.level == 1).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 2).count(), 100);
        assert_eq!(tasks.iter().filter(|t| t.level == 3).count(), 50);
    }

    #[test]
    fn level4_is_reachable_but_not_in_full_suite() {
        let l4 = level_suite(42, 4);
        assert_eq!(l4.len(), 40);
        assert!(l4.iter().all(|t| t.level == 4));
        assert!(full_suite(42).iter().all(|t| t.level != 4));
        // Same seed, same workload — and a stable slice of nothing else.
        let again = level_suite(42, 4);
        let ids: Vec<&str> = l4.iter().map(|t| t.id.as_str()).collect();
        let ids2: Vec<&str> = again.iter().map(|t| t.id.as_str()).collect();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn ids_unique() {
        let tasks = full_suite(42);
        let mut ids: Vec<&str> = tasks.iter().map(|t| t.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 250);
    }
}
