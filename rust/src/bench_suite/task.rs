//! KernelBenchSim task definition — the KernelBench substitution.
//!
//! A task is a graph plus the two scalars that define its *optimization
//! landscape* relative to Torch Eager:
//!
//! * `eager_waste`  — multiplier on the eager baseline's cost: redundant work
//!   the framework implementation does that a specialized kernel avoids
//!   (e.g. materializing a diagonal matrix before a GEMM). This is where
//!   KernelBench's heavy-tailed Level-1 speedups come from.
//! * `sched_ceiling` — the best speedup *schedule quality alone* can deliver
//!   over a waste-free eager baseline: >1 where custom kernels beat the
//!   framework's generic kernels (fusion headroom, better reductions), <1
//!   where hand-tuned-library magic (cuBLAS/cuDNN) cannot be recovered from
//!   scratch — those are the Fast₁ misses in Table 3.

use crate::kir::graph::KernelGraph;

#[derive(Debug, Clone)]
pub struct Task {
    /// Stable id, e.g. "l1_017_gemm_diag".
    pub id: String,
    /// KernelBench level (1, 2, 3).
    pub level: u8,
    /// Operator-family name for traces.
    pub name: String,
    pub graph: KernelGraph,
    /// Eager redundant-work multiplier (>= 1).
    pub eager_waste: f64,
    /// Schedule-quality speedup ceiling vs waste-free eager (may be < 1).
    pub sched_ceiling: f64,
    /// Strict numeric tolerance: precision downcast is vetoed
    /// (global_forbidden_rules) and NaN faults are likelier.
    pub strict_tolerance: bool,
    /// How hard a faithful CUDA translation of the reference is: the
    /// Generator's per-seed fault probability scales with this. Exotic ops
    /// and deep model graphs are translation nightmares.
    pub translation_risk: f64,
    /// If set, this task is backed by real AOT Pallas artifacts under
    /// `artifacts/` and the Verifier runs real PJRT numeric checks.
    pub artifact: Option<String>,
}

impl Task {
    /// Scale factor for the fault model: bigger graphs mean more code per
    /// edit and harder repairs (the Level-3 brittleness of Table 1).
    pub fn fault_scale(&self) -> f64 {
        1.0 + (self.graph.len() as f64).ln().max(0.0) * 0.35
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::OpKind;

    #[test]
    fn fault_scale_grows_with_graph() {
        let mut small = KernelGraph::new();
        small.push(OpKind::MatMul, 64, 64, 64, vec![]);
        let mut big = KernelGraph::new();
        let mut prev = big.push(OpKind::MatMul, 64, 64, 64, vec![]);
        for _ in 0..30 {
            prev = big.push(
                OpKind::Elementwise(crate::kir::op::EwKind::Relu),
                64,
                64,
                1,
                vec![prev],
            );
        }
        let t_small = Task {
            id: "s".into(),
            level: 1,
            name: "s".into(),
            graph: small,
            eager_waste: 1.0,
            sched_ceiling: 1.0,
            strict_tolerance: false,
            translation_risk: 0.05,
            artifact: None,
        };
        let t_big = Task {
            id: "b".into(),
            level: 3,
            name: "b".into(),
            graph: big,
            eager_waste: 1.0,
            sched_ceiling: 1.0,
            strict_tolerance: false,
            translation_risk: 0.4,
            artifact: None,
        };
        assert!(t_big.fault_scale() > t_small.fault_scale());
    }
}
