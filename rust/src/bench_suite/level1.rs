//! Level 1: 100 single-operator tasks (KernelBench L1 mix).
//!
//! Category mix mirrors KernelBench's operator distribution; the heavy-tailed
//! `eager_waste` on *structured* GEMM tasks (diagonal/triangular/banded
//! operands that eager materializes densely) is what produces the level's
//! large average speedups, while plain library-op tasks whose
//! `sched_ceiling` lands below 1.0 produce the Fast₁ misses.

use super::task::Task;
use crate::kir::graph::KernelGraph;
use crate::kir::op::{EwKind, NormKind, OpKind, RedKind};
use crate::util::rng::Rng;

/// Round to a multiple of 8 (MXU-friendly); occasionally leave ragged to
/// exercise the mxu_alignment veto.
fn dim(rng: &mut Rng, lo: u64, hi: u64, ragged_ok: bool) -> u64 {
    let d = rng.log_uniform(lo as f64, hi as f64) as u64;
    if ragged_ok && rng.chance(0.08) {
        (d | 1).max(lo) // odd: not 8-aligned
    } else {
        ((d + 7) / 8 * 8).max(8)
    }
}

fn ceiling(rng: &mut Rng, mu: f64, sigma: f64) -> f64 {
    rng.lognormal(mu.ln(), sigma).clamp(0.5, 4.0)
}

pub fn generate(rng: &mut Rng) -> Vec<Task> {
    let mut tasks = Vec::with_capacity(100);
    let mut idx = 0usize;
    let mut push = |tasks: &mut Vec<Task>,
                    name: &str,
                    graph: KernelGraph,
                    waste: f64,
                    ceiling: f64,
                    strict: bool,
                    risk: f64,
                    artifact: Option<String>| {
        tasks.push(Task {
            id: format!("l1_{idx:03}_{name}"),
            level: 1,
            name: name.to_string(),
            graph,
            eager_waste: waste,
            sched_ceiling: ceiling,
            strict_tolerance: strict,
            translation_risk: risk,
            artifact,
        });
        idx += 1;
    };

    // -- 28 plain GEMM / conv (library parity territory) ------------------
    for i in 0..28 {
        let mut g = KernelGraph::new();
        let kind = if i % 3 == 2 { OpKind::Conv } else { OpKind::MatMul };
        let m = dim(rng, 256.0 as u64, 4096, true);
        let n = dim(rng, 256, 4096, true);
        let k = dim(rng, 256, 4096, true);
        g.push(kind, m, n, k, vec![]);
        let name = if matches!(kind, OpKind::Conv) { "conv" } else { "gemm" };
        // Library parity territory: the quality ceiling straddles 1.0, so a
        // sizable minority of plain GEMM/conv tasks can never clear Fast1.
        let artifact = if i == 0 { Some("matmul".to_string()) } else { None };
        let risk = if rng.chance(0.06) { rng.log_uniform(0.6, 0.9) } else { 0.05 };
        push(&mut tasks, name, g, 1.0, ceiling(rng, 1.03, 0.20), rng.chance(0.3), risk, artifact);
    }

    // -- 22 structured GEMM (the heavy tail) ------------------------------
    for i in 0..22 {
        let mut g = KernelGraph::new();
        let m = dim(rng, 512, 4096, false);
        let n = dim(rng, 512, 4096, false);
        let k = dim(rng, 512, 4096, false);
        g.push(OpKind::MatMul, m, n, k, vec![]);
        g.structured_operands = true;
        // Diagonal / triangular / banded / symmetric operand: eager
        // materializes and does dense work; a specialized kernel skips it.
        let waste = rng.lognormal(17.0f64.ln(), 0.55).clamp(3.0, 80.0);
        let name = ["gemm_diag", "gemm_tril", "gemm_band", "gemm_sym"][i % 4];
        let risk = if rng.chance(0.12) { rng.log_uniform(0.6, 0.9) } else { 0.10 };
        push(&mut tasks, name, g, waste, ceiling(rng, 1.25, 0.20), rng.chance(0.2), risk, None);
    }

    // -- 16 reductions ------------------------------------------------------
    for i in 0..16 {
        let mut g = KernelGraph::new();
        let rows = dim(rng, 512, 8192, false);
        let cols = dim(rng, 512, 8192, false);
        let red = [RedKind::Row, RedKind::Col, RedKind::Full, RedKind::ArgMinMax][i % 4];
        g.push(OpKind::Reduction(red), rows, cols, 1, vec![]);
        let waste = rng.lognormal(1.7f64.ln(), 0.3).clamp(1.0, 4.0);
        let risk = if rng.chance(0.15) { rng.log_uniform(0.55, 0.9) } else { 0.12 };
        let ceil = ceiling(rng, 1.35, 0.25);
        push(&mut tasks, "reduction", g, waste, ceil, rng.chance(0.2), risk, None);
    }

    // -- 16 normalizations --------------------------------------------------
    for i in 0..16 {
        let mut g = KernelGraph::new();
        let rows = dim(rng, 256, 4096, false);
        let cols = dim(rng, 256, 4096, false);
        let nk = [
            NormKind::Softmax,
            NormKind::LayerNorm,
            NormKind::RmsNorm,
            NormKind::BatchNorm,
            NormKind::GroupNorm,
        ][i % 5];
        g.push(OpKind::Norm(nk), rows, cols, 1, vec![]);
        let waste = rng.lognormal(2.0f64.ln(), 0.35).clamp(1.0, 5.0);
        let artifact = match (i, nk) {
            (_, NormKind::Softmax) if i < 5 => Some("softmax".to_string()),
            (_, NormKind::LayerNorm) if i < 5 => Some("layernorm".to_string()),
            _ => None,
        };
        let risk = if rng.chance(0.12) { rng.log_uniform(0.55, 0.9) } else { 0.10 };
        let ceil = ceiling(rng, 1.45, 0.25);
        push(&mut tasks, "norm", g, waste, ceil, rng.chance(0.25), risk, artifact);
    }

    // -- 10 elementwise ------------------------------------------------------
    for i in 0..10 {
        let mut g = KernelGraph::new();
        let rows = dim(rng, 1024, 8192, false);
        let cols = dim(rng, 1024, 8192, false);
        let ew = [EwKind::Gelu, EwKind::Mish, EwKind::Sigmoid, EwKind::Tanh, EwKind::Clamp][i % 5];
        g.push(OpKind::Elementwise(ew), rows, cols, 1, vec![]);
        // Transcendental activations: eager sometimes uses a slow composed
        // form (mish = softplus+tanh+mul as three kernels).
        let waste = if i % 5 == 1 {
            rng.lognormal(2.6f64.ln(), 0.3)
        } else {
            rng.lognormal(1.15f64.ln(), 0.12)
        };
        let ceil = ceiling(rng, 1.03, 0.10);
        push(&mut tasks, "elementwise", g, waste.clamp(1.0, 6.0), ceil, false, 0.03, None);
    }

    // -- 8 data movement ------------------------------------------------------
    for i in 0..8 {
        let mut g = KernelGraph::new();
        let rows = dim(rng, 1024, 8192, false);
        let cols = dim(rng, 1024, 8192, false);
        let kind = [OpKind::Transpose, OpKind::Gather, OpKind::Pool, OpKind::Scan][i % 4];
        g.push(kind, rows, cols, 1, vec![]);
        let waste = rng.lognormal(1.5f64.ln(), 0.3).clamp(1.0, 4.0);
        let risk = if rng.chance(0.25) { rng.log_uniform(0.5, 0.9) } else { 0.15 };
        push(&mut tasks, "datamove", g, waste, ceiling(rng, 1.25, 0.20), false, risk, None);
    }

    assert_eq!(tasks.len(), 100);
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::eager;
    use crate::device::machine::DeviceSpec;
    use crate::util::stats;

    #[test]
    fn generates_100_valid_tasks() {
        let mut rng = Rng::new(42);
        let tasks = generate(&mut rng);
        assert_eq!(tasks.len(), 100);
        for t in &tasks {
            assert!(t.graph.validate().is_ok(), "{}", t.id);
            assert_eq!(t.graph.len(), 1, "L1 is single-op");
            assert!(t.eager_waste >= 1.0);
            assert!(t.sched_ceiling > 0.4);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&mut Rng::new(7));
        let b = generate(&mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.eager_waste, y.eager_waste);
        }
    }

    #[test]
    fn ceiling_distribution_shape() {
        let dev = DeviceSpec::a100_like();
        let tasks = generate(&mut Rng::new(42));
        let ceilings: Vec<f64> = tasks.iter().map(|t| eager::max_speedup(t, &dev)).collect();
        let m = stats::mean(&ceilings);
        // The level's mean *ceiling* must sit above the paper's 5.44x
        // achieved mean, with a heavy tail and a sub-1.0 fraction.
        assert!(m > 4.5 && m < 20.0, "mean ceiling {m}");
        let below = ceilings.iter().filter(|c| **c < 1.0).count();
        assert!(below >= 5 && below <= 45, "sub-parity tasks: {below}");
        let big = ceilings.iter().filter(|c| **c > 10.0).count();
        assert!(big >= 8, "heavy tail too light: {big}");
    }

    #[test]
    fn some_artifact_backed_tasks() {
        let tasks = generate(&mut Rng::new(42));
        assert!(tasks.iter().any(|t| t.artifact.is_some()));
    }
}
