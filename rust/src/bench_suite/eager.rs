//! Torch-Eager baseline cost + the custom-kernel floor.
//!
//! Eager execution = one library-quality kernel per op (cuBLAS/cuDNN for
//! GEMM-shaped ops, decent-but-generic kernels for the rest), a launch per
//! op, no cross-op fusion — times the task's `eager_waste`. The custom floor
//! is `roofline * custom_edge`: no agent-written kernel can beat the task's
//! roofline, and on library-dominated ops it cannot even reach it.

use super::task::Task;
use crate::device::costmodel::{self, price_group};
use crate::device::machine::DeviceSpec;
use crate::kir::op::{Op, OpKind};
use crate::kir::schedule::{GroupSchedule, Layout, Schedule};

/// Per-op framework dispatch overhead in eager mode (python dispatcher,
/// autograd bookkeeping, stream sync) — on top of the raw kernel launch.
/// This is the structural reason custom kernels win on deep graphs.
pub const FRAMEWORK_DISPATCH_S: f64 = 9.0e-6;

/// Library schedule the eager framework would dispatch for one op.
pub fn lib_cfg(op: &Op) -> GroupSchedule {
    if op.is_gemm_like() {
        let mut c = GroupSchedule::library_gemm();
        // Libraries autotune tiles to the problem (parallelism-aware).
        let (tm, tn) = crate::kir::transforms::gemm_tiles(op.m, op.n);
        c.tile_m = tm;
        c.tile_n = tn;
        c
    } else {
        // Generic framework kernel: coalesced, vectorized, unfused.
        let mut c = GroupSchedule::naive();
        c.tile_m = 64;
        c.tile_n = 128;
        c.layout = Layout::Coalesced;
        c.vector_width = 4;
        c.unroll = 2;
        // Framework reduction kernels are reasonably tuned.
        if matches!(op.kind, OpKind::Reduction(_) | OpKind::Norm(_)) {
            c.unroll = 4;
        }
        c
    }
}

/// Eager latency with no redundant work: one library kernel per op.
pub fn eager_no_waste_s(task: &Task, dev: &DeviceSpec) -> f64 {
    let kernels: f64 = task
        .graph
        .ops
        .iter()
        .map(|op| price_group(&task.graph, &[op.id], &lib_cfg(op), dev).time_s)
        .sum();
    kernels + task.graph.len() as f64 * FRAMEWORK_DISPATCH_S
}

/// Torch-Eager latency for the task (seconds).
pub fn eager_time_s(task: &Task, dev: &DeviceSpec) -> f64 {
    eager_no_waste_s(task, dev) * task.eager_waste
}

/// Hard floor on any custom kernel's latency for this task: the task's
/// schedule-quality ceiling relative to waste-free eager, but never below
/// the legality-aware roofline (physics).
pub fn custom_floor_s(task: &Task, dev: &DeviceSpec) -> f64 {
    let quality_floor = eager_no_waste_s(task, dev) / task.sched_ceiling;
    costmodel::legal_roofline_s(&task.graph, dev).max(quality_floor)
}

/// Latency of a candidate schedule, floored by the task's custom edge.
///
/// On structured tasks (diagonal/triangular operands), a faithful custom
/// translation does the same dense redundant work as eager until the
/// SpecializeStructure method rewrites the kernel — the waste multiplier
/// stays on the custom kernel until then.
pub fn custom_time_s(task: &Task, sched: &Schedule, dev: &DeviceSpec) -> f64 {
    let mut t = costmodel::price(&task.graph, sched, dev).total_s;
    if task.graph.structured_operands && !sched.specialized {
        t *= task.eager_waste;
    }
    t.max(custom_floor_s(task, dev))
}

/// Speedup of a schedule over Torch Eager (the paper's headline metric).
pub fn speedup(task: &Task, sched: &Schedule, dev: &DeviceSpec) -> f64 {
    eager_time_s(task, dev) / custom_time_s(task, sched, dev)
}

/// The best speedup any method could reach on this task (ceiling).
pub fn max_speedup(task: &Task, dev: &DeviceSpec) -> f64 {
    eager_time_s(task, dev) / custom_floor_s(task, dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::KernelGraph;
    use crate::kir::op::EwKind;

    fn dev() -> DeviceSpec {
        DeviceSpec::a100_like()
    }

    fn task(graph: KernelGraph, waste: f64, ceiling: f64) -> Task {
        Task {
            id: "t".into(),
            level: 1,
            name: "t".into(),
            graph,
            eager_waste: waste,
            sched_ceiling: ceiling,
            strict_tolerance: false,
            translation_risk: 0.05,
            artifact: None,
        }
    }

    #[test]
    fn max_speedup_is_waste_times_ceiling() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 2048, 2048, 2048, vec![]);
        let t = task(g, 3.0, 1.1);
        let max = max_speedup(&t, &dev());
        assert!((max - 3.3).abs() < 1e-9, "got {max}");
    }

    #[test]
    fn physics_caps_the_ceiling() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 2048, 2048, 2048, vec![]);
        // An absurd quality ceiling cannot push custom below the roofline.
        let t = task(g, 1.0, 1000.0);
        let max = max_speedup(&t, &dev());
        assert!(max < 10.0, "physics should cap, got {max}");
    }

    #[test]
    fn sub_parity_ceiling_forces_fast1_miss() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 2048, 2048, 2048, vec![]);
        let t = task(g, 1.0, 0.85);
        assert!(max_speedup(&t, &dev()) < 1.0);
    }

    #[test]
    fn naive_seed_far_below_eager() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 1024, 1024, 1024, vec![]);
        let t = task(g, 1.0, 1.05);
        let seed = Schedule::per_op_naive(&t.graph);
        let s = speedup(&t, &seed, &dev());
        assert!(s < 0.1, "naive seed should be ~0.03x (motivating example), got {s}");
    }

    #[test]
    fn custom_time_respects_floor() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 512, 512, 512, vec![]);
        let t = task(g, 1.0, 0.5);
        let seed = Schedule::per_op_naive(&t.graph);
        assert!(custom_time_s(&t, &seed, &dev()) >= custom_floor_s(&t, &dev()));
    }
}
