//! Device substrate: the A100/NCU substitution (DESIGN.md §Substitutions).
//!
//! * `machine`   — hardware presets (A100-like, TPU-like)
//! * `costmodel` — roofline pricing of (graph, schedule) pairs
//! * `metrics`   — raw NCU/NSYS-flavored signal synthesis
//! * `faults`    — the LLM-surrogate's buggy-edit model

pub mod costmodel;
pub mod faults;
pub mod machine;
pub mod metrics;
