//! Roofline-style analytical cost model: prices a (graph, schedule) pair on
//! a [`DeviceSpec`].
//!
//! This is the Profiler's ground truth (the NCU/nsys substitute, DESIGN.md
//! §Substitutions). It models exactly the effects the long-term memory's
//! decision table reasons about: HBM traffic as a function of blocking/reuse,
//! matrix-unit vs vector-unit throughput, occupancy, pipeline overlap,
//! scratchpad bank conflicts, layout/vectorization bandwidth efficiency, and
//! per-kernel launch overhead.

use super::machine::DeviceSpec;
use crate::kir::graph::KernelGraph;
use crate::kir::op::OpKind;
use crate::kir::schedule::{GroupSchedule, Layout, Precision, Schedule};

/// What limits a group's runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Memory,
    Compute,
    Launch,
    Balanced,
}

/// Cost breakdown for one fusion group (one launched kernel).
#[derive(Debug, Clone)]
pub struct GroupCost {
    pub time_s: f64,
    pub mem_time_s: f64,
    pub compute_time_s: f64,
    pub launch_s: f64,
    /// HBM bytes moved (first-touch traffic).
    pub traffic_bytes: f64,
    /// Re-read bytes served from L2 (naive-GEMM re-streaming).
    pub l2_traffic_bytes: f64,
    pub flops: f64,
    pub occupancy: f64,
    pub bw_eff_frac: f64,
    pub compute_eff_frac: f64,
    pub uses_mxu: bool,
    pub bound: Bound,
    /// Scratch bytes resident per block.
    pub scratch_bytes: u64,
}

/// Whole-task cost.
#[derive(Debug, Clone)]
pub struct TaskCost {
    pub groups: Vec<GroupCost>,
    pub total_s: f64,
}

impl TaskCost {
    /// Index of the slowest group (the profiling hot spot).
    pub fn hot_group(&self) -> usize {
        self.groups
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.time_s.partial_cmp(&b.1.time_s).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    pub fn launch_fraction(&self) -> f64 {
        if self.total_s == 0.0 {
            return 0.0;
        }
        self.groups.iter().map(|g| g.launch_s).sum::<f64>() / self.total_s
    }
}

fn layout_bw_mult(layout: Layout) -> f64 {
    match layout {
        Layout::Strided => 0.22,
        Layout::Coalesced => 0.75,
        Layout::Tiled => 0.95,
    }
}

fn vector_bw_mult(width: u8) -> f64 {
    match width {
        0 | 1 => 0.65,
        2 => 0.85,
        _ => 1.0,
    }
}

/// Effective HBM bandwidth fraction for a group.
fn bw_eff(cfg: &GroupSchedule, has_unshuffled_reduction: bool) -> f64 {
    // Staged coalesced loads stream whole tiles sequentially — nearly as
    // good as an explicitly swizzled layout.
    let layout = if cfg.staging && matches!(cfg.layout, Layout::Coalesced) {
        0.9
    } else {
        layout_bw_mult(cfg.layout)
    };
    let mut f = layout * vector_bw_mult(cfg.vector_width);
    if has_unshuffled_reduction {
        // Tree reduction through scratch without lane shuffles / wide loads.
        f *= 0.6;
    }
    f.min(1.0)
}

/// HBM + L2 traffic for one group. Returns (hbm_bytes, l2_bytes).
fn group_traffic(graph: &KernelGraph, group: &[usize], cfg: &GroupSchedule) -> (f64, f64) {
    let mut hbm = 0.0;
    let mut l2 = 0.0;
    for &oid in group {
        let op = graph.op(oid);
        if op.is_gemm_like() {
            let b = op.dtype_bytes as f64;
            let (m, n, k) = (op.m as f64, op.n as f64, op.k as f64);
            let (tm, tn) = (cfg.tile_m.max(1) as f64, cfg.tile_n.max(1) as f64);
            let a_bytes = m * k * b;
            let w_bytes = k * n * b;
            let out_bytes = m * n * b;
            // Each operand is read once from HBM; re-reads (from poor
            // blocking) are served by L2 when they fit, HBM otherwise —
            // the l2 split is resolved by the caller against the device.
            let a_rereads = (n / tn - 1.0).max(0.0);
            let w_rereads = (m / tm - 1.0).max(0.0);
            hbm += a_bytes + w_bytes + out_bytes;
            l2 += a_bytes * a_rereads + w_bytes * w_rereads;
            if cfg.split_k > 1 {
                // Partials written + re-read for the combine pass.
                hbm += 2.0 * out_bytes * (cfg.split_k as f64 - 1.0);
            }
        } else {
            // Fused dataflow: in-group producers' outputs stay in registers/
            // scratch; external inputs are read, external outputs written.
            let in_group_inputs: f64 = op
                .inputs
                .iter()
                .filter(|i| group.contains(i))
                .map(|&i| graph.op(i).output_bytes())
                .sum();
            let external_read = (op.ideal_bytes() - op.output_bytes() - in_group_inputs).max(0.0);
            hbm += external_read;
            let consumed_in_group = graph
                .consumers(oid)
                .iter()
                .all(|c| group.contains(c));
            let has_consumers = !graph.consumers(oid).is_empty();
            if !(has_consumers && consumed_in_group) {
                hbm += op.output_bytes();
            }
        }
    }
    (hbm, l2)
}

/// Occupancy fraction: enough blocks to fill the device, and scratch not
/// over-subscribed.
fn occupancy(graph: &KernelGraph, group: &[usize], cfg: &GroupSchedule, dev: &DeviceSpec) -> f64 {
    let big = group
        .iter()
        .map(|&o| graph.op(o))
        .max_by(|a, b| a.flops().partial_cmp(&b.flops()).unwrap());
    let Some(op) = big else { return 1.0 };
    let blocks = ((op.m as f64 / cfg.tile_m.max(1) as f64).ceil()
        * (op.n as f64 / cfg.tile_n.max(1) as f64).ceil()
        * cfg.split_k as f64)
        .max(1.0);
    let mut occ = (blocks / dev.sm_count as f64).min(1.0);
    let scratch = cfg.scratch_bytes(4);
    if scratch > dev.scratch_bytes / 2 {
        occ *= 0.85; // one block per SM: latency hiding suffers
    }
    // Thread-count mistuning: tiny blocks under-fill SMs.
    if cfg.block_threads < 128 {
        occ *= 0.9;
    }
    occ.max(0.02)
}

/// Compute-efficiency fraction on the selected math path.
fn compute_eff(cfg: &GroupSchedule, occ: f64, is_gemm: bool) -> f64 {
    let mut eff = 0.9 * occ;
    if cfg.staging && !cfg.smem_padding {
        eff *= 0.75; // bank conflicts on the staged operands
    }
    if cfg.unroll <= 1 {
        // The matrix unit pipelines its own fragment loop; manual unrolling
        // matters mainly on the vector path.
        eff *= if is_gemm && cfg.mxu {
            0.95
        } else if is_gemm {
            0.75
        } else {
            0.9
        };
    }
    if is_gemm && cfg.mxu && (cfg.tile_m < 32 || cfg.tile_n < 32) {
        eff *= 0.5; // MXU fragments under-filled
    }
    eff.clamp(0.01, 1.0)
}

/// Price one group.
pub fn price_group(
    graph: &KernelGraph,
    group: &[usize],
    cfg: &GroupSchedule,
    dev: &DeviceSpec,
) -> GroupCost {
    let flops: f64 = group.iter().map(|&o| graph.op(o).flops()).sum();
    let is_gemm = group.iter().any(|&o| graph.op(o).is_gemm_like());
    // Wide (lane-aligned) loads are what keep a reduction tree streaming;
    // narrow loads serialize it regardless of unrolling.
    let has_unshuffled_red = group.iter().any(|&o| {
        matches!(graph.op(o).kind, OpKind::Reduction(_) | OpKind::Norm(_))
    }) && cfg.vector_width < 4;

    let (hbm_bytes, l2_bytes) = group_traffic(graph, group, cfg);
    let bwf = bw_eff(cfg, has_unshuffled_red);
    let bw = dev.hbm_bytes_per_s * bwf;

    // Re-read traffic is served by L2 at ~3x HBM bandwidth when the per-pass
    // panel working set (an A row-panel plus a B column-panel) fits, else it
    // spills back to HBM rates.
    let panel_bytes: f64 = group
        .iter()
        .map(|&o| graph.op(o))
        .filter(|op| op.is_gemm_like())
        .map(|op| ((cfg.tile_m * op.k + op.k * cfg.tile_n) * op.dtype_bytes) as f64)
        .fold(0.0, f64::max);
    let l2_bw = if panel_bytes <= dev.l2_bytes as f64 {
        dev.hbm_bytes_per_s * 3.0 * bwf
    } else {
        bw
    };
    let mem_time = hbm_bytes / bw + l2_bytes / l2_bw;

    let occ = occupancy(graph, group, cfg, dev);
    let ceff = compute_eff(cfg, occ, is_gemm);
    let use_mxu = is_gemm && cfg.mxu && !matches!(cfg.precision, Precision::F32);
    let peak = if use_mxu { dev.mxu_flops } else { dev.fp32_flops };
    // TF32 on the vector path still beats plain f32 slightly.
    let peak = if !use_mxu && matches!(cfg.precision, Precision::Tf32) {
        peak * 1.1
    } else {
        peak
    };
    let compute_time = flops / (peak * ceff);

    // Overlap: double buffering hides the smaller phase under the bigger.
    let overlap = if cfg.double_buffer { 0.9 } else { 0.35 };
    let body = mem_time.max(compute_time) + (1.0 - overlap) * mem_time.min(compute_time);
    let launch = dev.launch_overhead_s;
    let time = body + launch;

    let bound = if launch > body {
        Bound::Launch
    } else if mem_time > 1.5 * compute_time {
        Bound::Memory
    } else if compute_time > 1.5 * mem_time {
        Bound::Compute
    } else {
        Bound::Balanced
    };

    GroupCost {
        time_s: time,
        mem_time_s: mem_time,
        compute_time_s: compute_time,
        launch_s: launch,
        traffic_bytes: hbm_bytes,
        l2_traffic_bytes: l2_bytes,
        flops,
        occupancy: occ,
        bw_eff_frac: bwf,
        compute_eff_frac: ceff,
        uses_mxu: use_mxu,
        bound,
        scratch_bytes: cfg.scratch_bytes(4),
    }
}

/// Price the whole schedule.
pub fn price(graph: &KernelGraph, sched: &Schedule, dev: &DeviceSpec) -> TaskCost {
    let groups: Vec<GroupCost> = sched
        .groups
        .iter()
        .zip(&sched.cfg)
        .map(|(g, c)| price_group(graph, g, c, dev))
        .collect();
    let total = groups.iter().map(|g| g.time_s).sum();
    TaskCost {
        groups,
        total_s: total,
    }
}

/// Roofline lower bound for the task: perfect fusion, peak matrix unit,
/// full bandwidth, one launch. The headroom tiers are measured against this.
pub fn roofline_s(graph: &KernelGraph, dev: &DeviceSpec) -> f64 {
    let gemm_flops: f64 = graph
        .ops
        .iter()
        .filter(|o| o.is_gemm_like())
        .map(|o| o.flops())
        .sum();
    let other_flops = graph.total_flops() - gemm_flops;
    let compute = gemm_flops / dev.mxu_flops + other_flops / dev.fp32_flops;
    let mem = graph.fused_ideal_bytes() / dev.hbm_bytes_per_s;
    compute.max(mem) + dev.launch_overhead_s
}

/// Legality-aware roofline: the best latency any *legal* schedule can reach.
///
/// Unlike [`roofline_s`], this respects the fusion rules the compiler
/// enforces: GEMM-shaped ops are fusion barriers (a producer cannot be
/// inlined into a GEMM's prologue, and two GEMMs never share a kernel), so
/// every intermediate crossing into a GEMM costs an HBM round-trip, and each
/// GEMM costs its own launch. This is the custom-kernel floor for deep L3
/// graphs.
pub fn legal_roofline_s(graph: &KernelGraph, dev: &DeviceSpec) -> f64 {
    let gemm_flops: f64 = graph
        .ops
        .iter()
        .filter(|o| o.is_gemm_like())
        .map(|o| o.flops())
        .sum();
    let other_flops = graph.total_flops() - gemm_flops;
    let compute = gemm_flops / dev.mxu_flops + other_flops / dev.fp32_flops;

    let mut mem_bytes = graph.fused_ideal_bytes();
    for op in &graph.ops {
        if op.is_gemm_like() {
            // Every in-graph producer feeding this GEMM is written + read.
            for &inp in &op.inputs {
                mem_bytes += 2.0 * graph.op(inp).output_bytes();
            }
        }
    }
    let mem = mem_bytes / dev.hbm_bytes_per_s;

    let n_gemms = graph.ops.iter().filter(|o| o.is_gemm_like()).count();
    let launches = n_gemms.max(1) as f64;
    compute.max(mem) + launches * dev.launch_overhead_s
}

/// Estimated VMEM footprint + matrix-unit utilization for a schedule on the
/// TPU preset — the §Perf L1 report (interpret=True gives no real timings).
pub fn tpu_perf_estimate(graph: &KernelGraph, sched: &Schedule) -> (u64, f64) {
    let dev = DeviceSpec::tpu_like();
    let cost = price(graph, sched, &dev);
    let footprint = cost.groups.iter().map(|g| g.scratch_bytes).max().unwrap_or(0);
    let gemm_flops: f64 = graph
        .ops
        .iter()
        .filter(|o| o.is_gemm_like())
        .map(|o| o.flops())
        .sum();
    let mxu_util = if gemm_flops > 0.0 && cost.total_s > 0.0 {
        (gemm_flops / cost.total_s) / dev.mxu_flops
    } else {
        0.0
    };
    (footprint, mxu_util)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::EwKind;
    use crate::kir::transforms::{self, MethodId};

    fn dev() -> DeviceSpec {
        DeviceSpec::a100_like()
    }

    fn gemm_task() -> KernelGraph {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 1024, 1024, 1024, vec![]);
        g
    }

    #[test]
    fn naive_gemm_is_memory_bound_and_slow() {
        let g = gemm_task();
        let s = Schedule::per_op_naive(&g);
        let c = price(&g, &s, &dev());
        assert_eq!(c.groups[0].bound, Bound::Memory);
        assert!(c.groups[0].l2_traffic_bytes > c.groups[0].traffic_bytes);
    }

    #[test]
    fn tiling_then_mxu_approaches_roofline() {
        let g = gemm_task();
        let mut s = Schedule::per_op_naive(&g);
        let naive = price(&g, &s, &dev()).total_s;
        transforms::apply(MethodId::TileSmem, &g, &mut s);
        let tiled = price(&g, &s, &dev()).total_s;
        transforms::apply(MethodId::UseTensorCore, &g, &mut s);
        transforms::apply(MethodId::VectorizeLoads, &g, &mut s);
        transforms::apply(MethodId::DoubleBuffer, &g, &mut s);
        transforms::apply(MethodId::PadScratch, &g, &mut s);
        transforms::apply(MethodId::UnrollInner, &g, &mut s);
        let opt = price(&g, &s, &dev()).total_s;
        assert!(tiled < naive * 0.5, "tiling should be >2x: {naive} -> {tiled}");
        assert!(
            opt < tiled * 0.2,
            "mxu path should be >5x more: {tiled} -> {opt}"
        );
        assert!(
            opt < naive * 0.05,
            "naive -> fully optimized should exceed 20x (paper's 0.032x example): {naive} -> {opt}"
        );
        let rl = roofline_s(&g, &dev());
        assert!(
            opt < rl * 6.0,
            "optimized within 6x of roofline: {opt} vs {rl}"
        );
        assert!(opt > rl * 0.99, "cannot beat roofline");
    }

    #[test]
    fn fusion_cuts_traffic_and_launches() {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::Elementwise(EwKind::Add), 2048, 2048, 1, vec![]);
        let b = g.push(OpKind::Elementwise(EwKind::Relu), 2048, 2048, 1, vec![a]);
        let _ = g.push(OpKind::Elementwise(EwKind::Scale), 2048, 2048, 1, vec![b]);
        let unfused = Schedule::per_op_naive(&g);
        let mut fused = unfused.clone();
        fused.merge_groups(0, 1);
        fused.merge_groups(0, 1);
        let cu = price(&g, &unfused, &dev());
        let cf = price(&g, &fused, &dev());
        let tu: f64 = cu.groups.iter().map(|x| x.traffic_bytes).sum();
        let tf: f64 = cf.groups.iter().map(|x| x.traffic_bytes).sum();
        assert!(tf < tu * 0.6, "fusion removes intermediate traffic");
        assert!(cf.total_s < cu.total_s);
    }

    #[test]
    fn tiny_ops_are_launch_bound() {
        let mut g = KernelGraph::new();
        g.push(OpKind::Elementwise(EwKind::Relu), 32, 32, 1, vec![]);
        let s = Schedule::per_op_naive(&g);
        let c = price(&g, &s, &dev());
        assert_eq!(c.groups[0].bound, Bound::Launch);
        assert!(c.launch_fraction() > 0.5);
    }

    #[test]
    fn roofline_is_a_lower_bound_across_methods() {
        let g = gemm_task();
        let rl = roofline_s(&g, &dev());
        let mut s = Schedule::per_op_naive(&g);
        for m in crate::kir::transforms::ALL_METHODS {
            if transforms::applicable(m, &g, &s).is_ok() {
                transforms::apply(m, &g, &mut s);
                assert!(price(&g, &s, &dev()).total_s >= rl * 0.999);
            }
        }
    }

    #[test]
    fn tpu_estimate_reports_footprint() {
        let g = gemm_task();
        let mut s = Schedule::per_op_naive(&g);
        transforms::apply(MethodId::TileSmem, &g, &mut s);
        let (fp, util) = tpu_perf_estimate(&g, &s);
        assert!(fp > 0);
        assert!((0.0..=1.0).contains(&util));
    }
}
