//! Device specifications for the analytical performance model.
//!
//! Five presets: an A100-like card (the paper's testbed), a TPU-like core
//! (the hardware-adaptation target), an H100-like card (TMA-era async-copy
//! costs), a consumer-GPU-like card (small SRAM, occupancy pressure), and a
//! CPU-like socket (no shared memory, wide vector units). Only *ratios*
//! matter downstream — the decision workflow normalizes everything to
//! pct-of-peak, and reproduction targets the tables' shape, not absolute
//! microseconds. Preset names double as skill-store partition keys, so each
//! preset is also a cross-device transfer-learning experiment via the
//! pooled `CROSS_DEVICE_DISCOUNT` fallback.

/// Hardware model parameters. Units: bytes, FLOP/s, seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Sustainable HBM bandwidth (bytes/s).
    pub hbm_bytes_per_s: f64,
    /// Peak FP32 vector throughput (FLOP/s).
    pub fp32_flops: f64,
    /// Peak matrix-unit throughput — tensor core TF32/BF16 or MXU (FLOP/s).
    pub mxu_flops: f64,
    /// Scratchpad budget per block: CUDA smem/SM or a VMEM slice (bytes).
    pub scratch_bytes: u64,
    /// Number of SMs / cores the grid must fill for full throughput.
    pub sm_count: u32,
    /// Fixed cost per kernel launch (seconds) — dominates L3 graphs.
    pub launch_overhead_s: f64,
    /// Upper bound on threads per block.
    pub max_block_threads: u32,
    /// L2 / CMEM capacity (bytes); caps the naive-GEMM re-read penalty.
    pub l2_bytes: u64,
}

impl DeviceSpec {
    /// NVIDIA A100-80GB-like numbers (the paper's testbed).
    pub fn a100_like() -> DeviceSpec {
        DeviceSpec {
            name: "a100-like",
            hbm_bytes_per_s: 1.555e12,
            fp32_flops: 19.5e12,
            mxu_flops: 156.0e12, // TF32 tensor core
            scratch_bytes: 160 * 1024,
            sm_count: 108,
            launch_overhead_s: 4.0e-6,
            max_block_threads: 1024,
            l2_bytes: 40 * 1024 * 1024,
        }
    }

    /// TPU-v4-like core (DESIGN.md §Hardware-Adaptation): bigger scratchpad
    /// (VMEM), stronger matrix unit, fewer-but-fatter cores.
    pub fn tpu_like() -> DeviceSpec {
        DeviceSpec {
            name: "tpu-like",
            hbm_bytes_per_s: 1.2e12,
            fp32_flops: 17.0e12,
            mxu_flops: 275.0e12, // BF16 MXU
            scratch_bytes: 16 * 1024 * 1024,
            sm_count: 2, // tensor cores per chip; grid must only fill these
            launch_overhead_s: 1.5e-6,
            max_block_threads: 1024,
            l2_bytes: 128 * 1024 * 1024,
        }
    }

    /// NVIDIA H100-SXM-like numbers. The interesting delta vs A100 is the
    /// TMA-style async-copy machinery: staging traffic is effectively free
    /// to issue, modeled here as a much cheaper launch/setup cost plus a
    /// bigger per-block scratchpad (228 KiB smem/SM era) and fatter HBM3.
    pub fn h100_like() -> DeviceSpec {
        DeviceSpec {
            name: "h100-like",
            hbm_bytes_per_s: 3.35e12,
            fp32_flops: 67.0e12,
            mxu_flops: 495.0e12, // TF32 tensor core (wgmma path)
            scratch_bytes: 224 * 1024,
            sm_count: 132,
            launch_overhead_s: 2.0e-6, // TMA descriptors amortize setup
            max_block_threads: 1024,
            l2_bytes: 50 * 1024 * 1024,
        }
    }

    /// Consumer-GPU-like numbers (a 4090-class card): strong ALUs behind a
    /// narrow GDDR bus, and a *small* per-block SRAM budget (48 KiB default
    /// smem window) that puts staging schedules under occupancy pressure —
    /// `scratch_overflow` trips far earlier than on the datacenter parts.
    pub fn consumer_gpu_like() -> DeviceSpec {
        DeviceSpec {
            name: "consumer-gpu-like",
            hbm_bytes_per_s: 1.008e12,
            fp32_flops: 82.6e12,
            mxu_flops: 165.2e12, // TF32 tensor core
            scratch_bytes: 48 * 1024,
            sm_count: 128,
            launch_overhead_s: 6.0e-6,
            max_block_threads: 1024,
            l2_bytes: 72 * 1024 * 1024,
        }
    }

    /// CPU-socket-like numbers: wide vector units (AVX-512-class) and an
    /// AMX-style matrix path, but **no shared-memory scratchpad at all** —
    /// `scratch_bytes = 0` makes every staging schedule illegal
    /// (`scratch_overflow`), which in turn makes the MXU path unreachable
    /// (`mxu_unstaged` requires staging). Naive per-op schedules stay legal
    /// because an unstaged group's scratch footprint is zero.
    pub fn cpu_like() -> DeviceSpec {
        DeviceSpec {
            name: "cpu-like",
            hbm_bytes_per_s: 0.3e12, // DDR5 dual-socket class
            fp32_flops: 2.0e12,
            mxu_flops: 8.0e12, // AMX tiles — structurally unreachable here
            scratch_bytes: 0,
            sm_count: 64, // cores
            launch_overhead_s: 5.0e-7, // a function call, not a grid launch
            max_block_threads: 256,
            l2_bytes: 96 * 1024 * 1024,
        }
    }

    /// All built-in presets. Preset `name`s double as skill-store partition
    /// keys: learned stats are recorded per device so evidence from
    /// different presets never pollutes each other (retrieval falls back to
    /// the pooled cross-device view at a discount).
    pub fn presets() -> Vec<DeviceSpec> {
        vec![
            DeviceSpec::a100_like(),
            DeviceSpec::tpu_like(),
            DeviceSpec::h100_like(),
            DeviceSpec::consumer_gpu_like(),
            DeviceSpec::cpu_like(),
        ]
    }

    /// Look up a preset by its `name` (e.g. a skill-store partition key).
    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        DeviceSpec::presets().into_iter().find(|d| d.name == name)
    }

    /// Machine balance point (FLOP/byte) above which a kernel is
    /// compute-bound on the vector path.
    pub fn ridge_fp32(&self) -> f64 {
        self.fp32_flops / self.hbm_bytes_per_s
    }

    /// Balance point for the matrix-unit path.
    pub fn ridge_mxu(&self) -> f64 {
        self.mxu_flops / self.hbm_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_sane() {
        for dev in DeviceSpec::presets() {
            assert!(dev.hbm_bytes_per_s > 1e11, "{}", dev.name);
            assert!(dev.mxu_flops > dev.fp32_flops, "{}", dev.name);
            assert!(dev.ridge_mxu() > dev.ridge_fp32(), "{}", dev.name);
            assert!(dev.launch_overhead_s > 0.0, "{}", dev.name);
            assert!(dev.sm_count > 0 && dev.max_block_threads > 0, "{}", dev.name);
        }
    }

    #[test]
    fn tpu_has_bigger_scratch() {
        assert!(DeviceSpec::tpu_like().scratch_bytes > DeviceSpec::a100_like().scratch_bytes);
    }

    #[test]
    fn presets_resolve_by_name() {
        let presets = DeviceSpec::presets();
        assert_eq!(presets.len(), 5);
        for dev in &presets {
            assert_eq!(DeviceSpec::by_name(dev.name).map(|d| d.name), Some(dev.name));
        }
        assert!(DeviceSpec::by_name("unknown-gpu").is_none());
    }

    #[test]
    fn preset_names_are_unique() {
        let mut names: Vec<&str> = DeviceSpec::presets().iter().map(|d| d.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 5);
    }

    #[test]
    fn cpu_like_has_no_scratchpad_and_cheap_launches() {
        let cpu = DeviceSpec::cpu_like();
        assert_eq!(cpu.scratch_bytes, 0, "cpu-like models no shared memory");
        assert!(cpu.launch_overhead_s < DeviceSpec::a100_like().launch_overhead_s);
        // The small-SRAM consumer preset sits strictly between cpu (none)
        // and the datacenter parts.
        let consumer = DeviceSpec::consumer_gpu_like();
        assert!(consumer.scratch_bytes > 0);
        assert!(consumer.scratch_bytes < DeviceSpec::a100_like().scratch_bytes);
    }

    #[test]
    fn h100_outclasses_a100_on_every_axis() {
        let (h, a) = (DeviceSpec::h100_like(), DeviceSpec::a100_like());
        assert!(h.hbm_bytes_per_s > a.hbm_bytes_per_s);
        assert!(h.mxu_flops > a.mxu_flops);
        assert!(h.scratch_bytes > a.scratch_bytes);
        assert!(h.launch_overhead_s < a.launch_overhead_s, "TMA-style async copy");
    }
}
