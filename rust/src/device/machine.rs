//! Device specifications for the analytical performance model.
//!
//! Two presets: an A100-like card (the paper's testbed) and a TPU-like core
//! (the hardware-adaptation target). Only *ratios* matter downstream — the
//! decision workflow normalizes everything to pct-of-peak, and reproduction
//! targets the tables' shape, not absolute microseconds.

/// Hardware model parameters. Units: bytes, FLOP/s, seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Sustainable HBM bandwidth (bytes/s).
    pub hbm_bytes_per_s: f64,
    /// Peak FP32 vector throughput (FLOP/s).
    pub fp32_flops: f64,
    /// Peak matrix-unit throughput — tensor core TF32/BF16 or MXU (FLOP/s).
    pub mxu_flops: f64,
    /// Scratchpad budget per block: CUDA smem/SM or a VMEM slice (bytes).
    pub scratch_bytes: u64,
    /// Number of SMs / cores the grid must fill for full throughput.
    pub sm_count: u32,
    /// Fixed cost per kernel launch (seconds) — dominates L3 graphs.
    pub launch_overhead_s: f64,
    /// Upper bound on threads per block.
    pub max_block_threads: u32,
    /// L2 / CMEM capacity (bytes); caps the naive-GEMM re-read penalty.
    pub l2_bytes: u64,
}

impl DeviceSpec {
    /// NVIDIA A100-80GB-like numbers (the paper's testbed).
    pub fn a100_like() -> DeviceSpec {
        DeviceSpec {
            name: "a100-like",
            hbm_bytes_per_s: 1.555e12,
            fp32_flops: 19.5e12,
            mxu_flops: 156.0e12, // TF32 tensor core
            scratch_bytes: 160 * 1024,
            sm_count: 108,
            launch_overhead_s: 4.0e-6,
            max_block_threads: 1024,
            l2_bytes: 40 * 1024 * 1024,
        }
    }

    /// TPU-v4-like core (DESIGN.md §Hardware-Adaptation): bigger scratchpad
    /// (VMEM), stronger matrix unit, fewer-but-fatter cores.
    pub fn tpu_like() -> DeviceSpec {
        DeviceSpec {
            name: "tpu-like",
            hbm_bytes_per_s: 1.2e12,
            fp32_flops: 17.0e12,
            mxu_flops: 275.0e12, // BF16 MXU
            scratch_bytes: 16 * 1024 * 1024,
            sm_count: 2, // tensor cores per chip; grid must only fill these
            launch_overhead_s: 1.5e-6,
            max_block_threads: 1024,
            l2_bytes: 128 * 1024 * 1024,
        }
    }

    /// All built-in presets. Preset `name`s double as skill-store partition
    /// keys: learned stats are recorded per device so A100-like and
    /// TPU-like evidence never pollute each other.
    pub fn presets() -> Vec<DeviceSpec> {
        vec![DeviceSpec::a100_like(), DeviceSpec::tpu_like()]
    }

    /// Look up a preset by its `name` (e.g. a skill-store partition key).
    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        DeviceSpec::presets().into_iter().find(|d| d.name == name)
    }

    /// Machine balance point (FLOP/byte) above which a kernel is
    /// compute-bound on the vector path.
    pub fn ridge_fp32(&self) -> f64 {
        self.fp32_flops / self.hbm_bytes_per_s
    }

    /// Balance point for the matrix-unit path.
    pub fn ridge_mxu(&self) -> f64 {
        self.mxu_flops / self.hbm_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_sane() {
        for dev in [DeviceSpec::a100_like(), DeviceSpec::tpu_like()] {
            assert!(dev.hbm_bytes_per_s > 1e11);
            assert!(dev.mxu_flops > dev.fp32_flops);
            assert!(dev.ridge_mxu() > dev.ridge_fp32());
            assert!(dev.launch_overhead_s > 0.0);
        }
    }

    #[test]
    fn tpu_has_bigger_scratch() {
        assert!(DeviceSpec::tpu_like().scratch_bytes > DeviceSpec::a100_like().scratch_bytes);
    }

    #[test]
    fn presets_resolve_by_name() {
        for dev in DeviceSpec::presets() {
            assert_eq!(DeviceSpec::by_name(dev.name).map(|d| d.name), Some(dev.name));
        }
        assert!(DeviceSpec::by_name("h100-like").is_none());
    }
}
