//! Fault model: the LLM-surrogate's buggy edits.
//!
//! When the Optimizer/Repairer executes a plan, the edit may introduce a
//! fault (the paper's compilation failures and correctness violations that
//! drive the repair branch of Algorithm 1). Every fault carries a *signature*
//! (what the Compiler/Verifier reports) and a hidden `true_fix` among
//! `n_candidate_fixes` plausible repairs — diagnosis is the search for that
//! fix. A Diagnoser **with** short-term repair memory enumerates untried
//! candidates (expected ~F/2 rounds); one **without** samples with
//! replacement and can cycle through known-failing edits — exactly the
//! oscillation failure mode of §4.1.5.
//!
//! On top of the *kernel* faults sits the **environment chaos layer**
//! ([`ChaosConfig`]): harness faults the paper's single healthy testbed
//! never produced — transient compile failures (succeed-on-retry), a flaky
//! profiler (noisy or dropped measurements), and a lying cost model (biased
//! planner-visible counters). Chaos is seeded and derived per cell, so a
//! chaotic run is exactly as deterministic (shardable, mergeable,
//! resumable) as a clean one.

use crate::kir::transforms::{Complexity, MethodId};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Kernel does not build: bad syntax / template instantiation.
    CompileSyntax,
    /// Kernel does not build: resource over-subscription from the edit.
    CompileResource,
    /// Builds, runs, wrong numbers (indexing / reduction order bug).
    WrongNumerics,
    /// Builds, runs, NaN/Inf (overflow in a downcast or missing guard).
    Nan,
    /// Builds, intermittently wrong (missing sync after staging edit).
    Race,
    /// *Environment* fault, not an edit bug: the build box flaked (driver
    /// hiccup, OOM-killed nvcc). Injected only by the chaos layer; exactly
    /// one candidate fix ("retry the build") which is always the true fix,
    /// so the repair branch clears it in a single diagnose→repair round.
    TransientCompile,
}

impl FaultKind {
    /// Compile-stage faults are reported by the Compiler; the rest by the
    /// Verifier.
    pub fn is_compile(&self) -> bool {
        matches!(
            self,
            FaultKind::CompileSyntax | FaultKind::CompileResource | FaultKind::TransientCompile
        )
    }

    /// Environment faults come from the chaos layer, not the edit: their
    /// repair is deterministic (retry) and they must never count against a
    /// method's skill statistics.
    pub fn is_transient(&self) -> bool {
        matches!(self, FaultKind::TransientCompile)
    }

    pub fn signature(&self, method: MethodId) -> String {
        let what = match self {
            FaultKind::CompileSyntax => "error: expected ';' in kernel body",
            FaultKind::CompileResource => "ptxas error: too much shared data",
            FaultKind::WrongNumerics => "verification failed: max abs err 3.2e+01",
            FaultKind::Nan => "verification failed: output contains NaN",
            FaultKind::Race => "verification failed intermittently (run-to-run variance)",
            FaultKind::TransientCompile => "nvcc fatal: transient driver failure (retry)",
        };
        format!("{what} [after {}]", method.name())
    }
}

/// One injected defect attached to a kernel version.
#[derive(Debug, Clone, PartialEq)]
pub struct Fault {
    pub kind: FaultKind,
    pub injected_by: MethodId,
    pub signature: String,
    /// Index of the correct fix among the candidate set (hidden from agents).
    pub true_fix: u8,
    /// Number of plausible candidate fixes the Diagnoser can see.
    pub n_candidate_fixes: u8,
    /// Translation-stage defect in unfamiliar generated code: diagnosis is
    /// materially harder and botched fixes regress more.
    pub hard: bool,
}

impl Fault {
    /// A chaos-injected transient compile failure: one candidate fix
    /// ("retry"), always correct, never hard. Succeed-on-retry by
    /// construction.
    pub fn transient(method: MethodId) -> Fault {
        Fault {
            kind: FaultKind::TransientCompile,
            injected_by: method,
            signature: FaultKind::TransientCompile.signature(method),
            true_fix: 0,
            n_candidate_fixes: 1,
            hard: false,
        }
    }
}

/// Deterministic environment-chaos configuration, parsed from the CLI
/// `--chaos` spec string (e.g. `"tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7"`).
///
/// Every knob defaults to 0 (off); `seed` decorrelates the chaos stream
/// from the run seed. The canonical [`ChaosConfig::render`] form is what
/// the run manifest records — chaos is experiment identity, so resume and
/// merge refuse to mix differing chaos configs. All chaos randomness is
/// drawn from a dedicated RNG derived per (run seed, chaos seed, strategy,
/// task), never from the cell's own stream, so `--chaos` with all knobs at
/// 0 is byte-identical to no `--chaos` at all.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// Probability a fresh candidate's build transiently fails
    /// (succeed-on-retry via the repair branch).
    pub transient_compile_p: f64,
    /// Probability the profiler drops the measurement for a healthy kernel
    /// (the `RawProfile` goes missing; timing survives).
    pub profile_drop_p: f64,
    /// Flaky-profiler noise amplitude: measured latency is scaled by
    /// `1 ± sigma` (uniform), on top of the intrinsic measurement noise.
    pub profile_sigma: f64,
    /// Lying cost model: planner-visible profile counters are biased by up
    /// to this relative fraction (uniform per counter draw).
    pub cost_bias: f64,
    /// Chaos stream seed, mixed into the per-cell derivation.
    pub seed: u64,
}

impl ChaosConfig {
    /// Parse a `k=v,k=v` spec. Keys: `tc`, `drop`, `sigma`, `bias`, `seed`.
    /// Unknown keys, malformed numbers, and out-of-range probabilities are
    /// errors; an empty spec is an error (omit `--chaos` for no chaos).
    pub fn parse(spec: &str) -> Result<ChaosConfig, String> {
        if spec.trim().is_empty() {
            return Err("--chaos spec is empty (omit the flag for no chaos)".to_string());
        }
        let mut cfg = ChaosConfig {
            transient_compile_p: 0.0,
            profile_drop_p: 0.0,
            profile_sigma: 0.0,
            cost_bias: 0.0,
            seed: 0,
        };
        for part in spec.split(',') {
            let part = part.trim();
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("--chaos entry '{part}' is not k=v"))?;
            let fval = || -> Result<f64, String> {
                let v: f64 = val
                    .parse()
                    .map_err(|_| format!("--chaos {key}: '{val}' is not a number"))?;
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!("--chaos {key}: {val} outside [0, 1]"));
                }
                Ok(v)
            };
            match key {
                "tc" => cfg.transient_compile_p = fval()?,
                "drop" => cfg.profile_drop_p = fval()?,
                "sigma" => cfg.profile_sigma = fval()?,
                "bias" => cfg.cost_bias = fval()?,
                "seed" => {
                    cfg.seed = val
                        .parse()
                        .map_err(|_| format!("--chaos seed: '{val}' is not a u64"))?
                }
                other => {
                    return Err(format!(
                        "--chaos key '{other}' unknown (expected tc, drop, sigma, bias, seed)"
                    ))
                }
            }
        }
        Ok(cfg)
    }

    /// Canonical spec string: all five knobs in fixed order. This is what
    /// the manifest records; `parse(render())` round-trips exactly.
    pub fn render(&self) -> String {
        format!(
            "tc={},drop={},sigma={},bias={},seed={}",
            self.transient_compile_p,
            self.profile_drop_p,
            self.profile_sigma,
            self.cost_bias,
            self.seed
        )
    }
}

/// Base bug probability per edit-complexity class. These rates are the main
/// lever that reproduces the paper's repair statistics (w/o short-term
/// memory: 96/98/94% success within 15 rounds — Table 2).
pub fn base_bug_rate(c: Complexity) -> f64 {
    match c {
        Complexity::Low => 0.05,
        Complexity::Medium => 0.13,
        Complexity::High => 0.24,
    }
}

/// Sample whether applying `method` introduces a fault.
///
/// `skill` in [0, 1] is the surrogate's coding reliability (1.0 = never
/// bugs); `graph_scale` grows bug risk on large L3 graphs (more code
/// touched per edit).
pub fn sample_fault(
    rng: &mut Rng,
    method: MethodId,
    skill: f64,
    graph_scale: f64,
) -> Option<Fault> {
    let p = (base_bug_rate(method.complexity()) * (1.5 - skill) * graph_scale).clamp(0.0, 0.95);
    if !rng.chance(p) {
        return None;
    }
    let kind = *rng.choose_weighted(
        &[
            FaultKind::CompileSyntax,
            FaultKind::CompileResource,
            FaultKind::WrongNumerics,
            FaultKind::Nan,
            FaultKind::Race,
        ],
        &[0.30, 0.12, 0.38, 0.12, 0.08],
    );
    let n_candidate_fixes = rng.range(3, 8) as u8;
    let true_fix = rng.range(0, n_candidate_fixes as u64) as u8;
    Some(Fault {
        kind,
        injected_by: method,
        signature: kind.signature(method),
        true_fix,
        n_candidate_fixes,
        hard: false,
    })
}

/// Outcome of applying candidate fix `fix_idx` to `fault`.
#[derive(Debug, Clone, PartialEq)]
pub enum RepairOutcome {
    /// Correct fix: the fault is cleared.
    Fixed,
    /// Wrong fix: fault persists.
    StillBroken,
    /// Wrong fix that also broke something else (regression — the cyclic
    /// repair fuel).
    Regressed(Fault),
}

/// Apply a candidate fix. `repair_skill` shrinks the regression rate.
pub fn attempt_fix(rng: &mut Rng, fault: &Fault, fix_idx: u8, repair_skill: f64) -> RepairOutcome {
    if fix_idx == fault.true_fix {
        return RepairOutcome::Fixed;
    }
    let hard_scale = if fault.hard { 1.4 } else { 1.0 };
    let p_regress = (0.45 * hard_scale * (1.3 - repair_skill)).clamp(0.02, 0.8);
    if rng.chance(p_regress) {
        // The botched fix introduces a sibling fault of a (possibly) new kind.
        let kind = *rng.choose(&[FaultKind::CompileSyntax, FaultKind::WrongNumerics]);
        let n = rng.range(2, 5) as u8;
        RepairOutcome::Regressed(Fault {
            kind,
            injected_by: fault.injected_by,
            signature: kind.signature(fault.injected_by),
            true_fix: rng.range(0, n as u64) as u8,
            n_candidate_fixes: n,
            hard: fault.hard,
        })
    } else {
        RepairOutcome::StillBroken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_complexity_bugs_more() {
        assert!(base_bug_rate(Complexity::High) > base_bug_rate(Complexity::Low));
    }

    #[test]
    fn skill_reduces_fault_rate() {
        let trials = 20_000;
        let count = |skill: f64| {
            let mut rng = Rng::new(7);
            (0..trials)
                .filter(|_| sample_fault(&mut rng, MethodId::TileSmem, skill, 1.0).is_some())
                .count()
        };
        let sloppy = count(0.3);
        let sharp = count(1.0);
        assert!(sharp < sloppy / 2, "sharp={sharp} sloppy={sloppy}");
    }

    #[test]
    fn true_fix_always_fixes() {
        let mut rng = Rng::new(1);
        let fault = loop {
            if let Some(f) = sample_fault(&mut rng, MethodId::TileSmem, 0.1, 2.0) {
                break f;
            }
        };
        assert_eq!(
            attempt_fix(&mut rng, &fault, fault.true_fix, 0.5),
            RepairOutcome::Fixed
        );
    }

    #[test]
    fn wrong_fix_never_silently_fixes() {
        let mut rng = Rng::new(2);
        let fault = Fault {
            kind: FaultKind::WrongNumerics,
            injected_by: MethodId::SplitK,
            signature: "sig".into(),
            true_fix: 0,
            n_candidate_fixes: 4,
            hard: false,
        };
        for _ in 0..200 {
            match attempt_fix(&mut rng, &fault, 2, 0.8) {
                RepairOutcome::Fixed => panic!("wrong fix fixed the fault"),
                _ => {}
            }
        }
    }

    #[test]
    fn enumerating_candidates_terminates() {
        // With memory (try each candidate once) the fault is always cleared
        // within n_candidate_fixes attempts.
        let mut rng = Rng::new(3);
        for seed in 0..50 {
            let mut r = Rng::new(seed);
            let fault = loop {
                if let Some(f) = sample_fault(&mut r, MethodId::FuseEpilogueReduction, 0.0, 2.0) {
                    break f;
                }
            };
            let mut fixed = false;
            for fix in 0..fault.n_candidate_fixes {
                if matches!(attempt_fix(&mut rng, &fault, fix, 1.0), RepairOutcome::Fixed) {
                    fixed = true;
                    break;
                }
            }
            assert!(fixed);
        }
    }

    #[test]
    fn signatures_name_the_method() {
        let sig = FaultKind::Nan.signature(MethodId::PrecisionDowncast);
        assert!(sig.contains("precision_downcast"));
    }

    #[test]
    fn transient_faults_are_compile_stage_and_fix_on_first_retry() {
        let f = Fault::transient(MethodId::TileSmem);
        assert!(f.kind.is_compile(), "transient failures surface at build time");
        assert!(f.kind.is_transient());
        assert_eq!(f.n_candidate_fixes, 1);
        let mut rng = Rng::new(9);
        assert_eq!(attempt_fix(&mut rng, &f, 0, 0.0), RepairOutcome::Fixed);
        // No injected fault kind is transient: the chaos layer is the only
        // producer.
        let mut r = Rng::new(4);
        for _ in 0..500 {
            if let Some(f) = sample_fault(&mut r, MethodId::TileSmem, 0.0, 2.0) {
                assert!(!f.kind.is_transient());
            }
        }
    }

    #[test]
    fn chaos_spec_round_trips_canonically() {
        let cfg = ChaosConfig::parse("tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7").unwrap();
        assert_eq!(cfg.transient_compile_p, 0.3);
        assert_eq!(cfg.profile_drop_p, 0.05);
        assert_eq!(cfg.profile_sigma, 0.2);
        assert_eq!(cfg.cost_bias, 0.1);
        assert_eq!(cfg.seed, 7);
        let rendered = cfg.render();
        assert_eq!(rendered, "tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7");
        assert_eq!(ChaosConfig::parse(&rendered).unwrap(), cfg);
        // Partial specs default the missing knobs to 0.
        let partial = ChaosConfig::parse("tc=0.5").unwrap();
        assert_eq!(partial.profile_drop_p, 0.0);
        assert_eq!(partial.seed, 0);
        assert_eq!(ChaosConfig::parse(&partial.render()).unwrap(), partial);
    }

    #[test]
    fn chaos_spec_rejects_garbage() {
        assert!(ChaosConfig::parse("").is_err());
        assert!(ChaosConfig::parse("tc").is_err());
        assert!(ChaosConfig::parse("tc=abc").is_err());
        assert!(ChaosConfig::parse("tc=1.5").is_err());
        assert!(ChaosConfig::parse("flub=0.1").is_err());
        assert!(ChaosConfig::parse("seed=-1").is_err());
    }
}
