//! Fault model: the LLM-surrogate's buggy edits.
//!
//! When the Optimizer/Repairer executes a plan, the edit may introduce a
//! fault (the paper's compilation failures and correctness violations that
//! drive the repair branch of Algorithm 1). Every fault carries a *signature*
//! (what the Compiler/Verifier reports) and a hidden `true_fix` among
//! `n_candidate_fixes` plausible repairs — diagnosis is the search for that
//! fix. A Diagnoser **with** short-term repair memory enumerates untried
//! candidates (expected ~F/2 rounds); one **without** samples with
//! replacement and can cycle through known-failing edits — exactly the
//! oscillation failure mode of §4.1.5.

use crate::kir::transforms::{Complexity, MethodId};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Kernel does not build: bad syntax / template instantiation.
    CompileSyntax,
    /// Kernel does not build: resource over-subscription from the edit.
    CompileResource,
    /// Builds, runs, wrong numbers (indexing / reduction order bug).
    WrongNumerics,
    /// Builds, runs, NaN/Inf (overflow in a downcast or missing guard).
    Nan,
    /// Builds, intermittently wrong (missing sync after staging edit).
    Race,
}

impl FaultKind {
    /// Compile-stage faults are reported by the Compiler; the rest by the
    /// Verifier.
    pub fn is_compile(&self) -> bool {
        matches!(self, FaultKind::CompileSyntax | FaultKind::CompileResource)
    }

    pub fn signature(&self, method: MethodId) -> String {
        let what = match self {
            FaultKind::CompileSyntax => "error: expected ';' in kernel body",
            FaultKind::CompileResource => "ptxas error: too much shared data",
            FaultKind::WrongNumerics => "verification failed: max abs err 3.2e+01",
            FaultKind::Nan => "verification failed: output contains NaN",
            FaultKind::Race => "verification failed intermittently (run-to-run variance)",
        };
        format!("{what} [after {}]", method.name())
    }
}

/// One injected defect attached to a kernel version.
#[derive(Debug, Clone, PartialEq)]
pub struct Fault {
    pub kind: FaultKind,
    pub injected_by: MethodId,
    pub signature: String,
    /// Index of the correct fix among the candidate set (hidden from agents).
    pub true_fix: u8,
    /// Number of plausible candidate fixes the Diagnoser can see.
    pub n_candidate_fixes: u8,
    /// Translation-stage defect in unfamiliar generated code: diagnosis is
    /// materially harder and botched fixes regress more.
    pub hard: bool,
}

/// Base bug probability per edit-complexity class. These rates are the main
/// lever that reproduces the paper's repair statistics (w/o short-term
/// memory: 96/98/94% success within 15 rounds — Table 2).
pub fn base_bug_rate(c: Complexity) -> f64 {
    match c {
        Complexity::Low => 0.05,
        Complexity::Medium => 0.13,
        Complexity::High => 0.24,
    }
}

/// Sample whether applying `method` introduces a fault.
///
/// `skill` in [0, 1] is the surrogate's coding reliability (1.0 = never
/// bugs); `graph_scale` grows bug risk on large L3 graphs (more code
/// touched per edit).
pub fn sample_fault(
    rng: &mut Rng,
    method: MethodId,
    skill: f64,
    graph_scale: f64,
) -> Option<Fault> {
    let p = (base_bug_rate(method.complexity()) * (1.5 - skill) * graph_scale).clamp(0.0, 0.95);
    if !rng.chance(p) {
        return None;
    }
    let kind = *rng.choose_weighted(
        &[
            FaultKind::CompileSyntax,
            FaultKind::CompileResource,
            FaultKind::WrongNumerics,
            FaultKind::Nan,
            FaultKind::Race,
        ],
        &[0.30, 0.12, 0.38, 0.12, 0.08],
    );
    let n_candidate_fixes = rng.range(3, 8) as u8;
    let true_fix = rng.range(0, n_candidate_fixes as u64) as u8;
    Some(Fault {
        kind,
        injected_by: method,
        signature: kind.signature(method),
        true_fix,
        n_candidate_fixes,
        hard: false,
    })
}

/// Outcome of applying candidate fix `fix_idx` to `fault`.
#[derive(Debug, Clone, PartialEq)]
pub enum RepairOutcome {
    /// Correct fix: the fault is cleared.
    Fixed,
    /// Wrong fix: fault persists.
    StillBroken,
    /// Wrong fix that also broke something else (regression — the cyclic
    /// repair fuel).
    Regressed(Fault),
}

/// Apply a candidate fix. `repair_skill` shrinks the regression rate.
pub fn attempt_fix(rng: &mut Rng, fault: &Fault, fix_idx: u8, repair_skill: f64) -> RepairOutcome {
    if fix_idx == fault.true_fix {
        return RepairOutcome::Fixed;
    }
    let hard_scale = if fault.hard { 1.4 } else { 1.0 };
    let p_regress = (0.45 * hard_scale * (1.3 - repair_skill)).clamp(0.02, 0.8);
    if rng.chance(p_regress) {
        // The botched fix introduces a sibling fault of a (possibly) new kind.
        let kind = *rng.choose(&[FaultKind::CompileSyntax, FaultKind::WrongNumerics]);
        let n = rng.range(2, 5) as u8;
        RepairOutcome::Regressed(Fault {
            kind,
            injected_by: fault.injected_by,
            signature: kind.signature(fault.injected_by),
            true_fix: rng.range(0, n as u64) as u8,
            n_candidate_fixes: n,
            hard: fault.hard,
        })
    } else {
        RepairOutcome::StillBroken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_complexity_bugs_more() {
        assert!(base_bug_rate(Complexity::High) > base_bug_rate(Complexity::Low));
    }

    #[test]
    fn skill_reduces_fault_rate() {
        let trials = 20_000;
        let count = |skill: f64| {
            let mut rng = Rng::new(7);
            (0..trials)
                .filter(|_| sample_fault(&mut rng, MethodId::TileSmem, skill, 1.0).is_some())
                .count()
        };
        let sloppy = count(0.3);
        let sharp = count(1.0);
        assert!(sharp < sloppy / 2, "sharp={sharp} sloppy={sloppy}");
    }

    #[test]
    fn true_fix_always_fixes() {
        let mut rng = Rng::new(1);
        let fault = loop {
            if let Some(f) = sample_fault(&mut rng, MethodId::TileSmem, 0.1, 2.0) {
                break f;
            }
        };
        assert_eq!(
            attempt_fix(&mut rng, &fault, fault.true_fix, 0.5),
            RepairOutcome::Fixed
        );
    }

    #[test]
    fn wrong_fix_never_silently_fixes() {
        let mut rng = Rng::new(2);
        let fault = Fault {
            kind: FaultKind::WrongNumerics,
            injected_by: MethodId::SplitK,
            signature: "sig".into(),
            true_fix: 0,
            n_candidate_fixes: 4,
            hard: false,
        };
        for _ in 0..200 {
            match attempt_fix(&mut rng, &fault, 2, 0.8) {
                RepairOutcome::Fixed => panic!("wrong fix fixed the fault"),
                _ => {}
            }
        }
    }

    #[test]
    fn enumerating_candidates_terminates() {
        // With memory (try each candidate once) the fault is always cleared
        // within n_candidate_fixes attempts.
        let mut rng = Rng::new(3);
        for seed in 0..50 {
            let mut r = Rng::new(seed);
            let fault = loop {
                if let Some(f) = sample_fault(&mut r, MethodId::FuseEpilogueReduction, 0.0, 2.0) {
                    break f;
                }
            };
            let mut fixed = false;
            for fix in 0..fault.n_candidate_fixes {
                if matches!(attempt_fix(&mut rng, &fault, fix, 1.0), RepairOutcome::Fixed) {
                    fixed = true;
                    break;
                }
            }
            assert!(fixed);
        }
    }

    #[test]
    fn signatures_name_the_method() {
        let sig = FaultKind::Nan.signature(MethodId::PrecisionDowncast);
        assert!(sig.contains("precision_downcast"));
    }
}
