//! NCU/NSYS-style signal synthesis: turns a [`TaskCost`] breakdown into the
//! *raw, tool-flavored* metric maps the Reviewer's Profiler emits.
//!
//! Deliberately messy: metric keys follow real Nsight Compute section naming
//! (including version-to-version renames), and the map also carries NCU's
//! own heuristic "hints" — the noisy, tool-suggested signals the paper says
//! memory-free optimizers over-attend to (§4.2). The long-term memory's
//! `field_mapping` is what normalizes this back into decision-ready fields.
//!
//! Every key the synthesizer can emit is a `&'static str` drawn from a fixed
//! vocabulary, so a profile costs two small `Vec`s and zero string
//! allocations — `synthesize` runs once per round in the inner loop and used
//! to dominate its allocation count.

use super::costmodel::{Bound, TaskCost};
use crate::kir::graph::KernelGraph;
use crate::kir::schedule::Schedule;

/// Raw profiling snapshot for one task run (all launched kernels).
#[derive(Debug, Clone, Default)]
pub struct RawProfile {
    /// NCU-like metrics for the *hot* kernel: (tool-specific key, value).
    pub ncu: Vec<(&'static str, f64)>,
    /// NSYS-like run features for the whole task.
    pub run: Vec<(&'static str, f64)>,
    /// NCU's heuristic rule hints (strings like "consider increasing
    /// occupancy") — noisy advice, NOT ground truth.
    pub hints: Vec<&'static str>,
    /// End-to-end latency in seconds.
    pub latency_s: f64,
}

/// Which NCU naming era to emit (field_mapping must handle both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ToolVersion {
    Ncu2023,
    Ncu2024,
}

fn key(v: ToolVersion, old: &'static str, new: &'static str) -> &'static str {
    match v {
        ToolVersion::Ncu2023 => old,
        ToolVersion::Ncu2024 => new,
    }
}

/// Synthesize a raw profile from the cost breakdown.
pub fn synthesize(
    graph: &KernelGraph,
    sched: &Schedule,
    cost: &TaskCost,
    version: ToolVersion,
) -> RawProfile {
    let hot = cost.hot_group();
    let g = &cost.groups[hot];

    let dram_pct =
        (g.mem_time_s / g.time_s.max(1e-12) * 100.0).min(100.0) * g.bw_eff_frac.max(0.05);
    let sm_pct = (g.compute_time_s / g.time_s.max(1e-12) * 100.0).min(100.0) * g.compute_eff_frac;
    let occ_pct = g.occupancy * 100.0;
    let cfg = &sched.cfg[hot];

    let mut ncu = vec![
        (
            key(
                version,
                "dram__throughput.avg.pct_of_peak_sustained_elapsed",
                "gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed",
            ),
            dram_pct,
        ),
        (
            key(
                version,
                "sm__throughput.avg.pct_of_peak_sustained_elapsed",
                "sm__pipe_tensor_op_hmma_cycles_active.avg.pct_of_peak_sustained_elapsed",
            ),
            sm_pct,
        ),
        (
            "sm__warps_active.avg.pct_of_peak_sustained_active",
            occ_pct,
        ),
        (
            "launch__shared_mem_per_block_dynamic",
            g.scratch_bytes as f64,
        ),
        (
            "launch__registers_per_thread",
            32.0 + 24.0 * (cfg.unroll as f64) + if cfg.mxu { 32.0 } else { 0.0 },
        ),
        ("launch__block_size", cfg.block_threads as f64),
        (
            "gpu__time_duration.sum",
            g.time_s * 1e9, // ns, like NCU
        ),
        (
            "l1tex__t_sectors_pipe_lsu_mem_global_op_ld.sum",
            (g.traffic_bytes + g.l2_traffic_bytes) / 32.0,
        ),
        (
            "lts__t_sector_hit_rate.pct",
            if g.l2_traffic_bytes > 0.0 {
                (g.l2_traffic_bytes / (g.traffic_bytes + g.l2_traffic_bytes) * 100.0).min(99.0)
            } else {
                35.0
            },
        ),
        (
            "smsp__sass_average_data_bytes_per_sector_mem_global_op_ld.pct",
            match cfg.layout {
                crate::kir::schedule::Layout::Strided => 25.0,
                crate::kir::schedule::Layout::Coalesced => 80.0,
                crate::kir::schedule::Layout::Tiled => 97.0,
            },
        ),
        (
            "sm__pipe_tensor_cycles_active.avg.pct_of_peak_sustained_elapsed",
            if g.uses_mxu { sm_pct } else { 0.0 },
        ),
        (
            "smsp__warp_issue_stalled_long_scoreboard_per_warp_active.pct",
            if matches!(g.bound, Bound::Memory) {
                55.0 * (1.0 - g.bw_eff_frac)
                    + if cfg.double_buffer { 5.0 } else { 25.0 }
            } else {
                8.0
            },
        ),
        (
            "smsp__warp_issue_stalled_bank_conflict_per_warp_active.pct",
            if cfg.staging && !cfg.smem_padding { 22.0 } else { 1.0 },
        ),
    ];
    ncu.sort_by(|a, b| a.0.cmp(b.0));

    let run = vec![
        ("kernel_launch_count", sched.num_kernels() as f64),
        ("total_time_us", cost.total_s * 1e6),
        ("launch_overhead_fraction", cost.launch_fraction()),
        ("num_ops", graph.len() as f64),
        (
            "hot_kernel_time_fraction",
            g.time_s / cost.total_s.max(1e-12),
        ),
    ];

    // NCU-style canned hints — intentionally generic and sometimes
    // misleading (e.g. always suggesting occupancy work on memory-bound
    // kernels). Baseline agents consume these; KernelSkill's deterministic
    // policy ignores them.
    let mut hints = Vec::new();
    if occ_pct < 60.0 {
        hints.push("Est. Speedup: increase occupancy by reducing block resources");
    }
    if dram_pct > 50.0 {
        hints.push(
            "Memory is more heavily utilized than compute: look at memory access patterns",
        );
    }
    if cfg.staging && !cfg.smem_padding {
        hints.push("Shared memory bank conflicts detected");
    }
    hints.push("This kernel grid is too small to fill the available resources");

    RawProfile {
        ncu,
        run,
        hints,
        latency_s: cost.total_s,
    }
}

impl RawProfile {
    pub fn ncu_get(&self, k: &str) -> Option<f64> {
        self.ncu.iter().find(|(n, _)| *n == k).map(|(_, v)| *v)
    }
    pub fn run_get(&self, k: &str) -> Option<f64> {
        self.run.iter().find(|(n, _)| *n == k).map(|(_, v)| *v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::costmodel::price;
    use crate::device::machine::DeviceSpec;
    use crate::kir::op::OpKind;

    fn profile(version: ToolVersion) -> RawProfile {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 512, 512, 512, vec![]);
        let s = Schedule::per_op_naive(&g);
        let c = price(&g, &s, &DeviceSpec::a100_like());
        synthesize(&g, &s, &c, version)
    }

    #[test]
    fn version_changes_key_names() {
        let old = profile(ToolVersion::Ncu2023);
        let new = profile(ToolVersion::Ncu2024);
        assert!(old
            .ncu_get("dram__throughput.avg.pct_of_peak_sustained_elapsed")
            .is_some());
        assert!(new
            .ncu_get("gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed")
            .is_some());
        assert!(new
            .ncu_get("dram__throughput.avg.pct_of_peak_sustained_elapsed")
            .is_none());
    }

    #[test]
    fn run_features_present() {
        let p = profile(ToolVersion::Ncu2023);
        assert_eq!(p.run_get("kernel_launch_count"), Some(1.0));
        assert!(p.run_get("total_time_us").unwrap() > 0.0);
        assert!(p.latency_s > 0.0);
    }

    #[test]
    fn hints_are_present_and_generic() {
        let p = profile(ToolVersion::Ncu2023);
        assert!(!p.hints.is_empty());
    }

    #[test]
    fn percentages_bounded() {
        let p = profile(ToolVersion::Ncu2023);
        for (k, v) in &p.ncu {
            if k.contains("pct") {
                assert!((0.0..=100.0).contains(v), "{k}={v}");
            }
        }
    }
}
