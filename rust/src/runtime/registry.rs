//! Artifact registry: parse `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into a typed index of tasks x variants.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Error, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct TaskEntry {
    pub inputs: Vec<InputSpec>,
    pub variants: BTreeMap<String, VariantEntry>,
}

#[derive(Debug, Clone)]
pub struct Registry {
    pub dir: PathBuf,
    pub tasks: BTreeMap<String, TaskEntry>,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| Error::msg(format!("manifest parse: {e}")))?;
        let tasks_json = json
            .get("tasks")
            .and_then(|t| t.as_obj())
            .context("manifest missing tasks")?;

        let mut tasks = BTreeMap::new();
        for (name, entry) in tasks_json {
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .context("task missing inputs")?
                .iter()
                .map(|spec| {
                    let shape = spec
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .context("input missing shape")?
                        .iter()
                        .map(|d| d.as_usize().context("bad dim"))
                        .collect::<Result<_>>()?;
                    let dtype = spec
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<_>>()?;
            let variants = entry
                .get("variants")
                .and_then(|v| v.as_obj())
                .context("task missing variants")?
                .iter()
                .map(|(vn, vv)| {
                    let file = vv
                        .get("file")
                        .and_then(|f| f.as_str())
                        .context("variant missing file")?
                        .to_string();
                    Ok((vn.clone(), VariantEntry { file }))
                })
                .collect::<Result<_>>()?;
            tasks.insert(name.clone(), TaskEntry { inputs, variants });
        }
        Ok(Registry { dir, tasks })
    }

    pub fn task(&self, name: &str) -> Result<&TaskEntry> {
        self.tasks
            .get(name)
            .with_context(|| format!("task {name} not in manifest"))
    }

    /// Artifact cache key "<task>/<variant>".
    pub fn key(task: &str, variant: &str) -> String {
        format!("{task}/{variant}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        let Ok(reg) = Registry::load("artifacts") else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        assert!(reg.tasks.contains_key("fused_epilogue"));
        let t = reg.task("fused_epilogue").unwrap();
        assert!(t.variants.contains_key("ref"));
        assert!(t.variants.contains_key("tiled_fused"));
        assert_eq!(t.inputs.len(), 3);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Registry::load("/nonexistent").is_err());
    }
}
