//! Real numeric verification: run a variant artifact and the reference
//! artifact on identical seeded inputs and compare — the Verifier's ground
//! truth for artifact-backed tasks (DESIGN.md §Three-layer).

use crate::util::error::Result;

use super::client::{Runtime, Tensor};
use super::registry::Registry;
use crate::util::rng::Rng;

/// Result of verifying one variant against the reference.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub task: String,
    pub variant: String,
    pub max_abs_err: f64,
    pub tolerance: f64,
    pub passed: bool,
    /// Median latency of the variant (seconds), if timed.
    pub latency_s: Option<f64>,
}

/// Generate seeded standard-normal inputs matching a task's specs.
pub fn seeded_inputs(reg: &Registry, task: &str, seed: u64) -> Result<Vec<Tensor>> {
    let entry = reg.task(task)?;
    let mut rng = Rng::new(seed);
    Ok(entry
        .inputs
        .iter()
        .map(|spec| {
            let n: usize = spec.shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            Tensor::new(spec.shape.clone(), data)
        })
        .collect())
}

/// Load (if needed) and verify `variant` of `task` against its `ref`.
pub fn verify_variant(
    rt: &mut Runtime,
    reg: &Registry,
    task: &str,
    variant: &str,
    seed: u64,
    tolerance: f64,
    time_it: bool,
) -> Result<VerifyReport> {
    let entry = reg.task(task)?;
    let ref_key = Registry::key(task, "ref");
    let var_key = Registry::key(task, variant);
    rt.load(&ref_key, &entry.variants["ref"].file)?;
    rt.load(&var_key, &entry.variants[variant].file)?;

    let inputs = seeded_inputs(reg, task, seed)?;
    let expected = rt.execute(&ref_key, &inputs)?;
    let got = rt.execute(&var_key, &inputs)?;
    let max_abs_err = got.max_abs_diff(&expected);
    let latency_s = if time_it {
        Some(rt.time_execution(&var_key, &inputs, 2, 5)?)
    } else {
        None
    };
    Ok(VerifyReport {
        task: task.to_string(),
        variant: variant.to_string(),
        max_abs_err,
        tolerance,
        passed: max_abs_err <= tolerance,
        latency_s,
    })
}

/// Verify every non-ref variant of every task in the registry.
pub fn verify_all(
    rt: &mut Runtime,
    reg: &Registry,
    seed: u64,
    tolerance: f64,
) -> Result<Vec<VerifyReport>> {
    let mut reports = Vec::new();
    let tasks: Vec<String> = reg.tasks.keys().cloned().collect();
    for task in tasks {
        let variants: Vec<String> = reg
            .task(&task)?
            .variants
            .keys()
            .filter(|v| *v != "ref")
            .cloned()
            .collect();
        for v in variants {
            reports.push(verify_variant(rt, reg, &task, &v, seed, tolerance, false)?);
        }
    }
    Ok(reports)
}
