//! Runtime: the rust side of the AOT bridge. Loads `artifacts/*.hlo.txt`
//! (lowered once by `python/compile/aot.py`), compiles via the PJRT C API,
//! and provides real execution, numeric verification, and timing for
//! artifact-backed tasks.

pub mod client;
pub mod registry;
pub mod verify;

pub use client::{Runtime, Tensor};
pub use registry::Registry;
pub use verify::{verify_all, verify_variant, VerifyReport};
