//! PJRT client wrapper: load AOT HLO-text artifacts, compile once, execute
//! from the rust hot path. Python never runs here.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id protos that jax >= 0.5 emits and
//! xla_extension 0.5.1 rejects (see /opt/xla-example/README.md).
//!
//! The real client links the `xla` crate, which the offline build image
//! does not vendor; it is therefore gated behind the `pjrt` cargo feature.
//! The default build compiles an API-identical stub whose constructor
//! returns an error, so everything downstream (verify, calibrate, the CLI
//! subcommands, the artifact tests) compiles and degrades gracefully.

use crate::util::error::Result;

/// A host tensor (f32, row-major) for artifact I/O.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    /// Max |a - b| against another tensor (verification metric).
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::time::Instant;

    use super::Tensor;
    use crate::util::error::{Context, Result};

    /// A compiled artifact ready to execute.
    pub struct Compiled {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT CPU client + executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: BTreeMap<String, Compiled>,
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                cache: BTreeMap::new(),
                artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one artifact file (cached by name).
        pub fn load(&mut self, name: &str, file: &str) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(
                name.to_string(),
                Compiled {
                    name: name.to_string(),
                    exe,
                },
            );
            Ok(())
        }

        pub fn is_loaded(&self, name: &str) -> bool {
            self.cache.contains_key(name)
        }

        /// Execute a loaded artifact on f32 inputs; returns the 1-tuple
        /// output. (aot.py lowers with return_tuple=True, so outputs unwrap
        /// via to_tuple1.)
        pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
            let compiled = self
                .cache
                .get(name)
                .with_context(|| format!("artifact {name} not loaded"))?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let lit = xla::Literal::vec1(&t.data);
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).context("reshaping input literal")
                })
                .collect::<Result<_>>()?;
            let result = compiled.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
            let shape = out.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = out.to_vec::<f32>()?;
            Ok(Tensor::new(dims, data))
        }

        /// Median-of-N wall-clock latency of one artifact (seconds).
        pub fn time_execution(
            &self,
            name: &str,
            inputs: &[Tensor],
            warmup: usize,
            iters: usize,
        ) -> Result<f64> {
            for _ in 0..warmup {
                self.execute(name, inputs)?;
            }
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = Instant::now();
                self.execute(name, inputs)?;
                times.push(t0.elapsed().as_secs_f64());
            }
            Ok(crate::util::stats::median(&times))
        }

        pub fn loaded_names(&self) -> Vec<&str> {
            self.cache.values().map(|c| c.name.as_str()).collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};

    use super::Tensor;
    use crate::util::error::{Error, Result};

    /// Stub runtime: the build has no `xla` crate. Construction fails with
    /// instructions; callers that guard on `Runtime::new` (the artifact
    /// tests, quickstart) skip cleanly.
    pub struct Runtime {
        _artifacts_dir: PathBuf,
    }

    fn unavailable() -> Error {
        Error::msg(
            "PJRT runtime unavailable: this binary was built without the \
             `pjrt` cargo feature (the offline image does not vendor the \
             `xla` crate). Rebuild with `cargo build --features pjrt` in an \
             environment that provides it.",
        )
    }

    impl Runtime {
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
            let _ = artifacts_dir.as_ref();
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn load(&mut self, _name: &str, _file: &str) -> Result<()> {
            Err(unavailable())
        }

        pub fn is_loaded(&self, _name: &str) -> bool {
            false
        }

        pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Tensor> {
            Err(unavailable())
        }

        pub fn time_execution(
            &self,
            _name: &str,
            _inputs: &[Tensor],
            _warmup: usize,
            _iters: usize,
        ) -> Result<f64> {
            Err(unavailable())
        }

        pub fn loaded_names(&self) -> Vec<&str> {
            Vec::new()
        }
    }
}

pub use imp::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_max_abs_diff() {
        let a = Tensor::new(vec![2, 2], vec![0.0, 1.0, 2.0, 3.0]);
        let b = Tensor::new(vec![2, 2], vec![0.5, 1.0, 2.0, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_with_instructions() {
        let e = Runtime::new("artifacts").err().expect("stub must fail");
        assert!(e.to_string().contains("pjrt"));
    }
}
