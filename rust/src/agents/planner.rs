//! Planner agent (§4.1.6): turns retrieved candidate methods + short-term
//! memory into one concrete optimization plan per round.
//!
//! This module also hosts the *selection modes* of every baseline — they all
//! share the same loop substrate and differ exactly here (plus in their
//! policy profiles and budgets), mirroring how the paper positions them.

use super::policy::{PolicyProfile, SelectionMode};
use crate::device::metrics::RawProfile;
use crate::kir::features::CodeFeatures;
use crate::kir::transforms::MethodId;
use crate::memory::long_term::retrieval::RetrievalResult;
use crate::memory::short_term::OptMemory;
use crate::util::rng::Rng;

/// A concrete, stepwise optimization plan for the Optimizer.
#[derive(Debug, Clone)]
pub struct OptimizationPlan {
    pub method: MethodId,
    pub steps: Vec<String>,
    pub rationale: String,
    /// Whether the plan carries method-knowledge implementation cues
    /// (llm_assist): cue-backed plans are executed more faithfully by the
    /// Optimizer (companion knobs land).
    pub with_cues: bool,
}

/// Everything a selection mode may look at this round.
pub struct PlanContext<'a> {
    /// Methods whose IR preconditions hold on the base kernel right now.
    pub applicable: &'a [MethodId],
    /// Long-term-memory retrieval (None when LT memory is ablated).
    pub retrieval: Option<&'a RetrievalResult>,
    /// Short-term optimization memory (None when ST memory is ablated).
    pub opt_memory: Option<&'a OptMemory>,
    pub features: &'a CodeFeatures,
    pub profile: &'a RawProfile,
    /// Method applied in the immediately previous round (repeat guard for
    /// memory-less strategies).
    pub last_method: Option<MethodId>,
    /// Rounds already spent (MacroPlan step pointer).
    pub rounds_done: u32,
    /// Per-run insight: whether this run's model holds the right mental
    /// model of the kernel (drawn once per task from planning_skill). An
    /// LLM that misdiagnosed the bottleneck stays misdiagnosed across
    /// rounds — more budget does not fix implicit selection (§3).
    pub insightful: bool,
}

/// What a plain LLM *instinctively* reaches for: locally simple, visible
/// edits first — fusion, vectorization, knob tweaks — before structural
/// GEMM work. This IS the §3 failure mode (the memory-free optimizer fused
/// the epilogue while the naive GEMM stayed naive).
pub fn llm_instinct(f: &CodeFeatures, applicable: &[MethodId]) -> Option<MethodId> {
    use MethodId::*;
    let prefs = [
        (f.fusion_opportunities > 0, FuseElementwise),
        (f.kernel_launches > 4, HorizontalFuse),
        (!f.vectorized_loads, VectorizeLoads),
        (f.strided_access, CoalesceAccesses),
        (!f.unrolled, UnrollInner),
        (true, LaunchTune),
    ];
    prefs
        .iter()
        .find(|(cond, m)| *cond && applicable.contains(m))
        .map(|(_, m)| *m)
}

/// What a knowledgeable engineer would pick from code features alone — the
/// grounded ranking STARK's strategic search consults.
pub fn oracle_heuristic(f: &CodeFeatures, applicable: &[MethodId]) -> Option<MethodId> {
    use MethodId::*;
    let prefs = [
        (f.structured_operand, SpecializeStructure),
        (f.naive_gemm_loop, TileSmem),
        (f.smem_tiling && !f.tensor_core, UseTensorCore),
        (f.strided_access, CoalesceAccesses),
        (
            f.fusion_opportunities > 0
                && !matches!(
                    f.reduction_pattern,
                    crate::kir::features::ReductionPattern::None
                ),
            FuseEpilogueReduction,
        ),
        (f.fusion_opportunities > 0, FuseElementwise),
        (!f.vectorized_loads, VectorizeLoads),
        (f.smem_tiling && !f.double_buffered, DoubleBuffer),
        (f.bank_conflict_risk, PadScratch),
        (f.kernel_launches > 4, HorizontalFuse),
        (!f.unrolled, UnrollInner),
        (true, LaunchTune),
    ];
    prefs
        .iter()
        .find(|(cond, m)| *cond && applicable.contains(m))
        .map(|(_, m)| *m)
}

/// Free-choice weights: the §3/§4.2 failure modes made concrete — fusion
/// bias and over-attention to NCU's occupancy/launch hints.
fn free_choice(ctx: &PlanContext, policy: &PolicyProfile, rng: &mut Rng) -> Option<MethodId> {
    let candidates: Vec<MethodId> = ctx
        .applicable
        .iter()
        .copied()
        .filter(|m| match ctx.opt_memory {
            Some(mem) => !mem.tried_on_base(*m),
            None => ctx.last_method != Some(*m),
        })
        .collect();
    if candidates.is_empty() {
        return None;
    }
    // The judgment branch: an insightful run picks the truly best next
    // method; otherwise its instinct is myopic (fusion/polish first — the
    // §3/§4.2 failure mode).
    if ctx.insightful {
        if let Some(m) = oracle_heuristic(ctx.features, &candidates) {
            return Some(m);
        }
    } else if rng.chance(0.5) {
        if let Some(m) = llm_instinct(ctx.features, &candidates) {
            return Some(m);
        }
    }
    // Otherwise: biased sampling.
    use MethodId::*;
    let weights: Vec<f64> = candidates
        .iter()
        .map(|m| {
            let mut w = 1.0;
            if matches!(m, FuseElementwise | FuseEpilogueReduction | HorizontalFuse) {
                w *= 1.0 + 4.0 * policy.fusion_bias;
            }
            if matches!(m, IncreaseOccupancy | LaunchTune | UnrollInner) {
                // NCU's canned hints forever suggest occupancy work.
                w *= 1.0 + 4.0 * policy.hint_following;
            }
            // Risk aversion: models shy away from deep structural rewrites
            // (whole-kernel restructures) when free-choosing.
            w *= match m.complexity() {
                crate::kir::transforms::Complexity::High => 0.3,
                crate::kir::transforms::Complexity::Medium => 0.7,
                crate::kir::transforms::Complexity::Low => 1.0,
            };
            w
        })
        .collect();
    Some(*rng.choose_weighted(&candidates, &weights))
}

/// CudaForge's Judge: reacts to raw profile signals + hints, no memory.
fn judge_hints(ctx: &PlanContext, rng: &mut Rng) -> Option<MethodId> {
    use MethodId::*;
    let p = ctx.profile;
    let get = |k: &str| p.ncu_get(k).unwrap_or(0.0);
    let tensor = p
        .ncu
        .iter()
        .find(|(k, _)| k.contains("pipe_tensor_cycles"))
        .map(|(_, v)| *v)
        .unwrap_or(0.0);
    let f = ctx.features;
    let ordered: Vec<MethodId> = if f.smem_tiling && tensor < 10.0 && rng.chance(0.7) {
        vec![UseTensorCore, DoubleBuffer, VectorizeLoads]
    } else if f.smem_tiling && !f.double_buffered && rng.chance(0.6) {
        // The judge reads exposed copy latency off the stall counters.
        vec![DoubleBuffer, VectorizeLoads, PadScratch]
    } else if f.naive_gemm_loop && rng.chance(0.6) {
        // The judge recognizes a naive GEMM from metrics most of the time.
        vec![TileSmem]
    } else if get("smsp__warp_issue_stalled_bank_conflict_per_warp_active.pct") > 8.0 {
        vec![PadScratch]
    } else if f.strided_access && rng.chance(0.6) {
        vec![CoalesceAccesses, VectorizeLoads]
    } else if f.fusion_opportunities > 0 {
        vec![FuseElementwise, FuseEpilogueReduction]
    } else {
        // Falls for the canned hints (occupancy/launch).
        vec![IncreaseOccupancy, LaunchTune, UnrollInner, VectorizeLoads]
    };
    ordered
        .into_iter()
        .find(|m| ctx.applicable.contains(m) && ctx.last_method != Some(*m))
}

/// PRAGMA's flat profiling->action map: real profiling grounding, but no
/// priority resolution, headroom tiers, code-feature gates, or vetoes —
/// rules fire in written order.
fn flat_rules(ctx: &PlanContext) -> Option<MethodId> {
    use MethodId::*;
    let p = ctx.profile;
    let get = |k: &str| p.ncu_get(k).unwrap_or(0.0);
    let occup = get("sm__warps_active.avg.pct_of_peak_sustained_active");
    let dram_old = get("dram__throughput.avg.pct_of_peak_sustained_elapsed");
    let dram_new = get("gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed");
    let dram = dram_old.max(dram_new);
    let stall = get("smsp__warp_issue_stalled_long_scoreboard_per_warp_active.pct");
    // Flat order: occupancy first (the classic mis-prioritization), then
    // bandwidth, then compute.
    let ordered: Vec<MethodId> = if occup < 40.0 {
        vec![IncreaseOccupancy, LaunchTune, SplitK]
    } else if dram > 55.0 || stall > 30.0 {
        // The flat map treats every memory signal as an access problem —
        // it has no rule distinguishing a naive GEMM's re-streaming.
        vec![VectorizeLoads, CoalesceAccesses, CacheBlocking, FuseElementwise, AsyncPrefetch]
    } else {
        vec![UseTensorCore, UnrollInner, FuseElementwise, PadScratch]
    };
    ordered
        .into_iter()
        .find(|m| ctx.applicable.contains(m) && ctx.last_method != Some(*m))
}

/// QiMeng's macro plan: a static stage list picked from the kernel's shape
/// at the first round, executed step by step.
pub fn macro_plan_sequence(f: &CodeFeatures) -> Vec<MethodId> {
    use MethodId::*;
    if f.naive_gemm_loop || f.tensor_core || f.smem_tiling {
        // GEMM-centric macro plan: excellent for L1 dense ops. Macro
        // thinking recognizes operand structure and plans for it (late —
        // after the generic stages).
        vec![
            TileSmem,
            UseTensorCore,
            VectorizeLoads,
            SpecializeStructure,
            DoubleBuffer,
            PadScratch,
            UnrollInner,
            LaunchTune,
        ]
    } else if !matches!(
        f.reduction_pattern,
        crate::kir::features::ReductionPattern::None
    ) {
        vec![WarpReduceShuffle, VectorizeLoads, CoalesceAccesses, UnrollInner, LaunchTune]
    } else if f.kernel_launches > 3 {
        // Multi-op graphs: the macro plan fuses first and only then fixes
        // kernels — the ordering that breaks down on L3.
        vec![
            FuseElementwise,
            FuseElementwise,
            HorizontalFuse,
            TileSmem,
            VectorizeLoads,
            UnrollInner,
        ]
    } else {
        vec![CoalesceAccesses, VectorizeLoads, CacheBlocking, UnrollInner, LaunchTune]
    }
}

fn macro_plan(ctx: &PlanContext) -> Option<MethodId> {
    let seq = macro_plan_sequence(ctx.features);
    // Execute the next not-yet-done applicable step.
    let step = ctx.rounds_done as usize;
    seq.iter()
        .copied()
        .cycle()
        .skip(step % seq.len().max(1))
        .take(seq.len())
        .find(|m| ctx.applicable.contains(m) && ctx.last_method != Some(*m))
}

/// Produce this round's plan under the given selection mode.
pub fn plan(
    mode: &SelectionMode,
    ctx: &PlanContext,
    policy: &PolicyProfile,
    rng: &mut Rng,
) -> Option<OptimizationPlan> {
    if ctx.applicable.is_empty() {
        return None;
    }
    let method = match mode {
        SelectionMode::DecisionPolicy => {
            let from_memory = ctx.retrieval.and_then(|r| {
                r.allowed_methods
                    .iter()
                    .copied()
                    .find(|m| {
                        ctx.applicable.contains(m)
                            && ctx
                                .opt_memory
                                .map(|mem| !mem.tried_on_base(*m))
                                .unwrap_or(true)
                    })
            });
            // Paper §6: when no case matches, fall back to LLM-only
            // evidence-based selection.
            match from_memory {
                Some(m) => Some(m),
                None => free_choice(ctx, policy, rng),
            }
        }
        SelectionMode::FreeChoice => free_choice(ctx, policy, rng),
        SelectionMode::FixedOrdering(order) => {
            // The trained policy progresses through its learned stage list
            // (its multi-turn context is an implicit trajectory memory).
            let n = order.len().max(1);
            order
                .iter()
                .copied()
                .cycle()
                .skip(ctx.rounds_done as usize % n)
                .take(n)
                .find(|m| ctx.applicable.contains(m) && ctx.last_method != Some(*m))
        }
        SelectionMode::MacroPlan => macro_plan(ctx),
        SelectionMode::JudgeHints => judge_hints(ctx, rng),
        SelectionMode::FlatRules => flat_rules(ctx),
        SelectionMode::StrategicSearch => {
            // Grounded instruction: consult the engineer heuristic first,
            // fall back to (memory-filtered) free choice.
            let filtered: Vec<MethodId> = ctx
                .applicable
                .iter()
                .copied()
                .filter(|m| ctx.opt_memory.map(|mem| !mem.tried_on_base(*m)).unwrap_or(true))
                .collect();
            if filtered.is_empty() {
                None
            } else if rng.chance(0.6) {
                oracle_heuristic(ctx.features, &filtered)
                    .or_else(|| free_choice(ctx, policy, rng))
            } else {
                free_choice(ctx, policy, rng)
            }
        }
    }?;

    // Steps + rationale: from method knowledge when the long-term memory is
    // in play (the paper's interpretability claim), generic otherwise.
    let with_cues = matches!(ctx.retrieval, Some(r) if r.allowed_methods.contains(&method));
    let (steps, rationale) = match ctx.retrieval {
        Some(r) if r.allowed_methods.contains(&method) => {
            let k = crate::memory::long_term::kb_content::knowledge_for(method);
            (
                k.map(|k| {
                    k.cues
                        .split(". ")
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                })
                .unwrap_or_default(),
                format!(
                    "case {}: {}",
                    r.matched_case.unwrap_or("<fallback>"),
                    r.case_why.unwrap_or("")
                ),
            )
        }
        _ => (
            vec![format!("apply {}", method.name())],
            format!("selected {} from model judgment", method.name()),
        ),
    };

    Some(OptimizationPlan {
        method,
        steps,
        rationale,
        with_cues,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::level2::appendix_d_graph;
    use crate::device::costmodel::price;
    use crate::device::machine::DeviceSpec;
    use crate::device::metrics::{synthesize, ToolVersion};
    use crate::kir::features::ground_truth;
    use crate::kir::schedule::Schedule;
    use crate::kir::transforms::{self, ALL_METHODS};

    fn setup() -> (
        crate::kir::graph::KernelGraph,
        Schedule,
        CodeFeatures,
        RawProfile,
        Vec<MethodId>,
    ) {
        let g = appendix_d_graph(256, 512, 512);
        let s = Schedule::per_op_naive(&g);
        let f = ground_truth(&g, &s);
        let cost = price(&g, &s, &DeviceSpec::a100_like());
        let p = synthesize(&g, &s, &cost, ToolVersion::Ncu2023);
        let applicable: Vec<MethodId> = ALL_METHODS
            .iter()
            .copied()
            .filter(|m| transforms::applicable(*m, &g, &s).is_ok())
            .collect();
        (g, s, f, p, applicable)
    }

    fn ctx<'a>(
        f: &'a CodeFeatures,
        p: &'a RawProfile,
        applicable: &'a [MethodId],
    ) -> PlanContext<'a> {
        PlanContext {
            applicable,
            retrieval: None,
            opt_memory: None,
            features: f,
            profile: p,
            last_method: None,
            rounds_done: 0,
            insightful: false,
        }
    }

    #[test]
    fn oracle_heuristic_fixes_the_gemm_first() {
        let (_, _, f, _, applicable) = setup();
        assert_eq!(
            oracle_heuristic(&f, &applicable),
            Some(MethodId::TileSmem)
        );
    }

    #[test]
    fn fusion_biased_policy_overfuses() {
        let (_, _, f, p, applicable) = setup();
        let mut policy = PolicyProfile::chatgpt51();
        policy.planning_skill = 0.0;
        policy.fusion_bias = 1.0;
        policy.hint_following = 0.0;
        let mut rng = Rng::new(11);
        let mut fusion_picks = 0;
        for _ in 0..200 {
            let c = ctx(&f, &p, &applicable);
            let m = plan(&SelectionMode::FreeChoice, &c, &policy, &mut rng)
                .unwrap()
                .method;
            if matches!(
                m,
                MethodId::FuseElementwise
                    | MethodId::FuseEpilogueReduction
                    | MethodId::HorizontalFuse
            ) {
                fusion_picks += 1;
            }
        }
        // The §3 failure mode: fusion dominates even though the GEMM is the
        // real bottleneck.
        assert!(fusion_picks > 90, "fusion_picks={fusion_picks}");
    }

    #[test]
    fn fixed_ordering_ignores_profile() {
        let (_, _, f, p, applicable) = setup();
        let order = vec![MethodId::VectorizeLoads, MethodId::TileSmem];
        let c = ctx(&f, &p, &applicable);
        let mut rng = Rng::new(1);
        let m = plan(
            &SelectionMode::FixedOrdering(order),
            &c,
            &PolicyProfile::trained_32b(),
            &mut rng,
        );
        // VectorizeLoads is inapplicable on the strided naive seed, so the
        // ordering falls through to TileSmem.
        assert_eq!(m.unwrap().method, MethodId::TileSmem);
    }

    #[test]
    fn flat_rules_mis_prioritize_occupancy() {
        // PRAGMA's flat map checks occupancy before the GEMM bottleneck.
        let (_, _, f, mut p, applicable) = setup();
        for (k, v) in p.ncu.iter_mut() {
            if *k == "sm__warps_active.avg.pct_of_peak_sustained_active" {
                *v = 20.0;
            }
        }
        let c = ctx(&f, &p, &applicable);
        let m = flat_rules(&c).unwrap();
        assert!(
            matches!(m, MethodId::IncreaseOccupancy | MethodId::LaunchTune | MethodId::SplitK),
            "{m:?}"
        );
    }

    #[test]
    fn macro_plan_is_gemm_centric_for_gemm_tasks() {
        let (_, _, f, _, _) = setup();
        let seq = macro_plan_sequence(&f);
        assert_eq!(seq[0], MethodId::TileSmem);
    }

    #[test]
    fn plan_none_when_nothing_applicable() {
        let (_, _, f, p, _) = setup();
        let c = ctx(&f, &p, &[]);
        let mut rng = Rng::new(1);
        assert!(
            plan(&SelectionMode::FreeChoice, &c, &PolicyProfile::chatgpt51(), &mut rng).is_none()
        );
    }
}
