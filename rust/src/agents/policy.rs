//! The LLM surrogate: a parameterized stochastic policy standing in for the
//! paper's ChatGPT-5.1 agent calls (DESIGN.md §Substitutions).
//!
//! Everything an LLM *would* do in the pipeline is reduced to a handful of
//! quality parameters; everything the paper's contribution does (the
//! deterministic decision policy + memories) stays exact. Baselines differ
//! in these parameters AND in their selection mode (`SelectionMode`).

use crate::kir::transforms::MethodId;

/// Quality parameters of a simulated agent stack.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyProfile {
    /// Probability scale of NOT introducing a bug per edit (1.0 = never).
    pub coding_skill: f64,
    /// Repair competence: shrinks regression probability on wrong fixes.
    pub repair_skill: f64,
    /// Accuracy of LLM-extracted (non-rule-based) code features.
    pub feature_accuracy: f64,
    /// Free-choice bias toward fusion edits (the §3 failure mode).
    pub fusion_bias: f64,
    /// Free-choice over-attention to NCU's canned hints (§4.2 failure mode:
    /// hints always push occupancy/launch knobs).
    pub hint_following: f64,
    /// Free-choice probability of identifying the genuinely best method.
    pub planning_skill: f64,
}

impl PolicyProfile {
    /// The paper's base model (ChatGPT-5.1): strong coder, good judgment.
    pub fn chatgpt51() -> Self {
        PolicyProfile {
            coding_skill: 0.85,
            repair_skill: 0.85,
            feature_accuracy: 0.92,
            fusion_bias: 0.3,
            hint_following: 0.25,
            planning_skill: 0.22,
        }
    }

    /// A trained-from-scratch kernel model (Kevin-32B-like): decent coder,
    /// no runtime judgment (selection is baked in, see FixedOrdering).
    pub fn trained_32b() -> Self {
        PolicyProfile {
            coding_skill: 0.62,
            repair_skill: 0.5,
            feature_accuracy: 0.7,
            fusion_bias: 0.35,
            hint_following: 0.0,
            planning_skill: 0.3,
        }
    }
}

/// How a strategy turns (evidence, candidates) into one method per round.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectionMode {
    /// KernelSkill: the deterministic long-term-memory decision policy.
    /// With a warm skill store the retrieved method order is additionally
    /// reranked by learned, device-partitioned, confidence-weighted
    /// outcome stats (see `memory::long_term::skill_store`), so the same
    /// evidence can rank methods differently on A100-like vs TPU-like
    /// hardware once the store has seen both.
    DecisionPolicy,
    /// Generic agentic loop (Astra / ablations): LLM free choice over the
    /// applicable methods, biased by fusion_bias / hint_following.
    FreeChoice,
    /// Training-based (Kevin): a fixed learned preference ordering applied
    /// regardless of profiling feedback.
    FixedOrdering(Vec<MethodId>),
    /// QiMeng: a macro plan chosen once from the task category, then
    /// executed step by step ("macro thinking, micro coding").
    MacroPlan,
    /// CudaForge: a Judge that reads raw NCU hints and GPU specs.
    JudgeHints,
    /// PRAGMA: a flat profiling->action rule map (no headroom tiers, no
    /// code-feature gates, no veto rules, no priority resolution).
    FlatRules,
    /// STARK: strategic search with grounded instruction — strong free
    /// choice plus within-task memory and a longer budget.
    StrategicSearch,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_ordered() {
        let gpt = PolicyProfile::chatgpt51();
        let kevin = PolicyProfile::trained_32b();
        assert!(gpt.coding_skill > kevin.coding_skill);
        assert!(gpt.repair_skill > kevin.repair_skill);
    }
}
