//! Diagnoser agent (§4.1.5): root-cause a failure and propose a repair plan,
//! conditioned on the short-term repair memory when available.
//!
//! With memory, the Diagnoser enumerates candidate fixes it has not yet seen
//! fail on this error signature; without it, each round samples
//! independently — which is how the cyclic-repair oscillation (fix A, fix B,
//! fix A, ...) arises in the ablation.

use super::policy::PolicyProfile;
use crate::device::faults::Fault;
use crate::memory::short_term::RepairMemory;
use crate::util::rng::Rng;

/// A repair plan: which candidate fix to apply to which fault.
#[derive(Debug, Clone)]
pub struct RepairPlan {
    pub error_signature: String,
    pub fix_idx: u8,
    pub rationale: String,
}

/// Propose a fix for the first outstanding fault.
pub fn diagnose(
    fault: &Fault,
    memory: Option<&RepairMemory>,
    policy: &PolicyProfile,
    rng: &mut Rng,
) -> RepairPlan {
    let n = fault.n_candidate_fixes;
    // Translation-stage defects live in unfamiliar generated code: even a
    // good diagnoser ranks their candidate fixes poorly.
    let skill_eff = policy.repair_skill * if fault.hard { 0.55 } else { 1.0 };
    let fix_idx = match memory {
        Some(mem) => {
            let failed = mem.failed_fixes_for(&fault.signature);
            let untried: Vec<u8> = (0..n).filter(|i| !failed.contains(i)).collect();
            if untried.is_empty() {
                // Everything plausible failed: re-roll (rare; the fault's
                // candidate set is small).
                rng.range(0, n as u64) as u8
            } else {
                // A competent diagnoser ranks candidates well: with prob
                // repair_skill it picks the most promising untried candidate
                // (biased toward the true fix when visible in the evidence).
                if rng.chance(skill_eff) && untried.contains(&fault.true_fix) {
                    fault.true_fix
                } else {
                    *rng.choose(&untried)
                }
            }
        }
        None => {
            // Memory-less: condition only on the latest feedback; past
            // attempts are invisible, so repeats happen.
            if rng.chance(skill_eff * 0.6) {
                fault.true_fix
            } else {
                rng.range(0, n as u64) as u8
            }
        }
    };
    RepairPlan {
        error_signature: fault.signature.clone(),
        fix_idx,
        rationale: format!(
            "candidate fix {} of {} for '{}'",
            fix_idx, n, fault.signature
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::faults::FaultKind;
    use crate::kir::transforms::MethodId;
    use crate::memory::short_term::RepairAttempt;

    fn fault() -> Fault {
        Fault {
            kind: FaultKind::WrongNumerics,
            injected_by: MethodId::TileSmem,
            signature: "verification failed: max abs err".into(),
            true_fix: 2,
            n_candidate_fixes: 4,
            hard: false,
        }
    }

    #[test]
    fn with_memory_never_repeats_failed_fix() {
        let f = fault();
        let mut mem = RepairMemory::new();
        mem.open_chain(1);
        for idx in [0u8, 1, 3] {
            mem.record(RepairAttempt {
                error_signature: f.signature.clone(),
                fix_idx: idx,
                fixed: false,
                kernel_version: idx as u32 + 2,
                round: idx as u32 + 1,
            });
        }
        let mut p = PolicyProfile::chatgpt51();
        p.repair_skill = 0.0; // force the uniform-untried branch
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let plan = diagnose(&f, Some(&mem), &p, &mut rng);
            assert_eq!(plan.fix_idx, 2, "only the true fix remains untried");
        }
    }

    #[test]
    fn without_memory_repeats_happen() {
        let f = fault();
        let mut p = PolicyProfile::chatgpt51();
        p.repair_skill = 0.0;
        let mut rng = Rng::new(2);
        let picks: Vec<u8> = (0..100).map(|_| diagnose(&f, None, &p, &mut rng).fix_idx).collect();
        // Uniform sampling must hit some index at least twice in a row
        // somewhere — the oscillation fuel.
        assert!(picks.windows(2).any(|w| w[0] == w[1]));
    }

    #[test]
    fn skilled_diagnoser_finds_true_fix_faster() {
        let f = fault();
        let hit_rate = |skill: f64| {
            let mut p = PolicyProfile::chatgpt51();
            p.repair_skill = skill;
            let mut rng = Rng::new(3);
            (0..1000)
                .filter(|_| diagnose(&f, None, &p, &mut rng).fix_idx == f.true_fix)
                .count()
        };
        assert!(hit_rate(0.9) > hit_rate(0.1) + 200);
    }
}
