//! Repairer agent (§4.1.7): apply a repair plan to the latest kernel.

use super::diagnoser::RepairPlan;
use super::policy::PolicyProfile;
use super::KernelState;
use crate::device::faults::{self, RepairOutcome};
use crate::util::rng::Rng;

/// Result of one repair round.
#[derive(Debug, Clone)]
pub struct RepairResult {
    pub state: KernelState,
    /// Did the targeted fault get cleared?
    pub fixed: bool,
    /// Did the attempt introduce a regression fault?
    pub regressed: bool,
}

/// Apply `plan` to the first matching fault of `latest`.
pub fn execute(
    latest: &KernelState,
    plan: &RepairPlan,
    policy: &PolicyProfile,
    version: u32,
    rng: &mut Rng,
) -> RepairResult {
    let mut state = latest.clone();
    state.version = version;
    let Some(pos) = state
        .faults
        .iter()
        .position(|f| f.signature == plan.error_signature)
    else {
        // The fault it diagnosed is gone (stale plan): no-op edit.
        return RepairResult {
            state,
            fixed: false,
            regressed: false,
        };
    };
    let fault = state.faults[pos].clone();
    match faults::attempt_fix(rng, &fault, plan.fix_idx, policy.repair_skill) {
        RepairOutcome::Fixed => {
            state.faults.remove(pos);
            RepairResult {
                state,
                fixed: true,
                regressed: false,
            }
        }
        RepairOutcome::StillBroken => RepairResult {
            state,
            fixed: false,
            regressed: false,
        },
        RepairOutcome::Regressed(new_fault) => {
            state.faults.push(new_fault);
            RepairResult {
                state,
                fixed: false,
                regressed: true,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::faults::{Fault, FaultKind};
    use crate::kir::schedule::Schedule;
    use crate::kir::transforms::MethodId;

    fn broken_state() -> KernelState {
        let mut g = crate::kir::graph::KernelGraph::new();
        g.push(crate::kir::op::OpKind::MatMul, 64, 64, 64, vec![]);
        let mut s = KernelState::new(Schedule::per_op_naive(&g), 1);
        s.faults.push(Fault {
            kind: FaultKind::CompileSyntax,
            injected_by: MethodId::TileSmem,
            signature: "error: expected ';'".into(),
            true_fix: 1,
            n_candidate_fixes: 3,
            hard: false,
        });
        s
    }

    #[test]
    fn correct_fix_clears_fault() {
        let s = broken_state();
        let plan = RepairPlan {
            error_signature: "error: expected ';'".into(),
            fix_idx: 1,
            rationale: String::new(),
        };
        let mut rng = Rng::new(1);
        let r = execute(&s, &plan, &PolicyProfile::chatgpt51(), 2, &mut rng);
        assert!(r.fixed);
        assert!(r.state.is_clean());
        assert_eq!(r.state.version, 2);
    }

    #[test]
    fn wrong_fix_leaves_fault() {
        let s = broken_state();
        let plan = RepairPlan {
            error_signature: "error: expected ';'".into(),
            fix_idx: 0,
            rationale: String::new(),
        };
        let mut rng = Rng::new(2);
        let r = execute(&s, &plan, &PolicyProfile::chatgpt51(), 2, &mut rng);
        assert!(!r.fixed);
        assert!(!r.state.is_clean());
    }

    #[test]
    fn stale_plan_is_noop() {
        let s = broken_state();
        let plan = RepairPlan {
            error_signature: "some other error".into(),
            fix_idx: 0,
            rationale: String::new(),
        };
        let mut rng = Rng::new(3);
        let r = execute(&s, &plan, &PolicyProfile::chatgpt51(), 2, &mut rng);
        assert!(!r.fixed);
        assert_eq!(r.state.faults.len(), 1);
    }
}
