//! Optimizer agent (§4.1.7): execute an optimization plan as a concrete
//! schedule edit, possibly introducing a fault (the surrogate's buggy edit).

use super::planner::OptimizationPlan;
use super::policy::PolicyProfile;
use super::KernelState;
use crate::bench_suite::Task;
use crate::device::faults;
use crate::kir::transforms;
use crate::util::rng::Rng;

/// Apply `plan` to `base` focusing the hot group, producing the round's
/// candidate kernel.
pub fn execute(
    task: &Task,
    base: &KernelState,
    plan: &OptimizationPlan,
    hot_group: usize,
    policy: &PolicyProfile,
    version: u32,
    rng: &mut Rng,
) -> KernelState {
    let mut sched = base.sched.clone();
    transforms::apply_at(plan.method, &task.graph, &mut sched, hot_group);
    // Companion knobs: a faithful implementation of the method also lands
    // its implementation cues. Cue-backed plans (long-term memory) land
    // them reliably; without cues the surrogate's rewrite is sloppier —
    // this is the concrete mechanism behind the llm_assist store's value.
    let p_comp = if plan.with_cues {
        0.55 + 0.45 * policy.coding_skill.min(1.0)
    } else {
        0.35 * policy.coding_skill.min(1.0)
    };
    for &comp in transforms::companions(plan.method) {
        let hg = hot_group.min(sched.num_kernels() - 1);
        if transforms::applicable_at(comp, &task.graph, &sched, hg).is_ok() && rng.chance(p_comp) {
            transforms::apply_at(comp, &task.graph, &mut sched, hg);
        }
    }
    let mut state = KernelState::new(sched, version);
    // Base kernels in the optimization branch are clean by construction
    // (Algorithm 1 only optimizes verified kernels), but the edit itself may
    // introduce a defect.
    if let Some(f) = faults::sample_fault(rng, plan.method, policy.coding_skill, task.fault_scale())
    {
        // Strict-tolerance tasks turn borderline numeric edits into
        // verification failures more often.
        state.faults.push(f);
    } else if task.strict_tolerance
        && matches!(
            plan.method,
            transforms::MethodId::PrecisionDowncast | transforms::MethodId::UseTensorCore
        )
        && rng.chance(0.35)
    {
        state.faults.push(crate::device::faults::Fault {
            kind: crate::device::faults::FaultKind::WrongNumerics,
            injected_by: plan.method,
            signature: crate::device::faults::FaultKind::WrongNumerics.signature(plan.method),
            true_fix: 0,
            n_candidate_fixes: 2,
            hard: false,
        });
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::planner::OptimizationPlan;
    use crate::bench_suite;
    use crate::kir::schedule::Schedule;
    use crate::kir::transforms::MethodId;

    fn plan_for(m: MethodId) -> OptimizationPlan {
        OptimizationPlan {
            method: m,
            steps: vec![],
            rationale: String::new(),
            with_cues: true,
        }
    }

    #[test]
    fn execute_applies_the_transform() {
        let t = bench_suite::level_suite(42, 2).remove(0);
        let base = KernelState::new(Schedule::per_op_naive(&t.graph), 0);
        let mut p = PolicyProfile::chatgpt51();
        p.coding_skill = 1.5; // suppress faults for determinism of this test
        let mut rng = Rng::new(1);
        let out = execute(&t, &base, &plan_for(MethodId::TileSmem), 0, &p, 1, &mut rng);
        assert!(out.sched.cfg[0].staging);
        assert_eq!(out.version, 1);
        assert!(out.sched.validate(&t.graph).is_ok());
    }

    #[test]
    fn sloppy_policy_injects_faults_sometimes() {
        let t = bench_suite::level_suite(42, 3).remove(0);
        let base = KernelState::new(Schedule::per_op_naive(&t.graph), 0);
        let mut p = PolicyProfile::chatgpt51();
        p.coding_skill = 0.0;
        let mut rng = Rng::new(2);
        let faults = (0..100)
            .filter(|i| {
                !execute(&t, &base, &plan_for(MethodId::TileSmem), 0, &p, *i, &mut rng).is_clean()
            })
            .count();
        assert!(faults > 20, "faults={faults}");
    }

    #[test]
    fn strict_tasks_risk_numeric_faults_on_downcast() {
        let mut t = bench_suite::level_suite(42, 1).remove(0);
        t.strict_tolerance = true;
        let base = KernelState::new(Schedule::per_op_naive(&t.graph), 0);
        let mut p = PolicyProfile::chatgpt51();
        p.coding_skill = 1.5; // isolate the strict-tolerance path
        let mut rng = Rng::new(3);
        let faults = (0..200)
            .filter(|i| {
                !execute(
                    &t,
                    &base,
                    &plan_for(MethodId::PrecisionDowncast),
                    0,
                    &p,
                    *i,
                    &mut rng,
                )
                .is_clean()
            })
            .count();
        assert!(faults > 30, "faults={faults}");
    }
}
