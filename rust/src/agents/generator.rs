//! Generator agent (§4.1.2): translate the reference program into seed
//! kernels — correctness-first, one kernel per operator, no speed work.

use super::policy::PolicyProfile;
use super::KernelState;
use crate::bench_suite::Task;
use crate::device::faults;
use crate::kir::schedule::{Layout, Schedule};
use crate::kir::transforms::MethodId;
use crate::util::rng::Rng;

/// Produce `n` seed kernels. Seeds are per-op naive schedules with small
/// stylistic variations (what different samples of the same prompt produce);
/// translation itself can introduce bugs on big graphs.
pub fn generate_seeds(
    task: &Task,
    n: usize,
    policy: &PolicyProfile,
    rng: &mut Rng,
) -> Vec<KernelState> {
    let mut seeds = Vec::with_capacity(n);
    for i in 0..n {
        let mut sched = Schedule::per_op_naive(&task.graph);
        // Sample-to-sample variation: some seeds come out with saner
        // indexing (coalesced) or slightly different block geometry.
        for cfg in &mut sched.cfg {
            if rng.chance(0.35) {
                cfg.layout = Layout::Coalesced;
            }
            if rng.chance(0.25) {
                cfg.vector_width = 2;
            }
            if rng.chance(0.3) {
                cfg.block_threads = *rng.choose(&[128, 256, 512]);
            }
        }
        let mut state = KernelState::new(sched, i as u32);
        // Translation bugs: driven by the task's translation risk, amplified
        // for weaker coders. Whole-model L3 translations are the nightmare
        // case (Kevin's Table-1 collapse).
        let skill_scale = (1.5 - policy.coding_skill).powi(2) * 2.4;
        let p_bug = (task.translation_risk * skill_scale).clamp(0.0, 0.97);
        if rng.chance(p_bug) {
            // A broken translation usually has several distinct defects;
            // nightmare tasks stack more of them (each needs its own repair
            // chain — where weak, memory-less repair loops bleed out).
            let mut n_faults = 1;
            for _ in 0..3 {
                if rng.chance(task.translation_risk) {
                    n_faults += 1;
                }
            }
            for _ in 0..n_faults {
                let mut f = None;
                for _ in 0..16 {
                    f = faults::sample_fault(rng, MethodId::LaunchTune, 0.0, 2.0);
                    if f.is_some() {
                        break;
                    }
                }
                if let Some(mut f) = f {
                    f.hard = true;
                    state.faults.push(f);
                }
            }
        }
        seeds.push(state);
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;

    #[test]
    fn seeds_are_valid_schedules() {
        let tasks = bench_suite::level_suite(42, 1);
        let mut rng = Rng::new(1);
        let seeds = generate_seeds(&tasks[0], 3, &PolicyProfile::chatgpt51(), &mut rng);
        assert_eq!(seeds.len(), 3);
        for s in &seeds {
            assert!(s.sched.validate(&tasks[0].graph).is_ok());
        }
    }

    #[test]
    fn big_graphs_seed_more_bugs() {
        let l1 = bench_suite::level_suite(42, 1);
        let l3 = bench_suite::level_suite(42, 3);
        let p = PolicyProfile::chatgpt51();
        let count_bugs = |tasks: &[bench_suite::Task]| {
            let mut rng = Rng::new(9);
            let mut bugs = 0;
            for t in tasks.iter().take(30) {
                for s in generate_seeds(t, 3, &p, &mut rng) {
                    if !s.is_clean() {
                        bugs += 1;
                    }
                }
            }
            bugs
        };
        assert!(count_bugs(&l3) > count_bugs(&l1));
    }

    #[test]
    fn deterministic_per_seed() {
        let tasks = bench_suite::level_suite(42, 2);
        let p = PolicyProfile::chatgpt51();
        let a = generate_seeds(&tasks[3], 3, &p, &mut Rng::new(5));
        let b = generate_seeds(&tasks[3], 3, &p, &mut Rng::new(5));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sched, y.sched);
            assert_eq!(x.faults.len(), y.faults.len());
        }
    }
}
