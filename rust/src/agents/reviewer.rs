//! Reviewer module (§4.1.4): Compiler + Verifier + Profiler.
//!
//! * Compiler: structural legality (`kir::legality`) plus injected
//!   compile-stage faults from buggy edits.
//! * Verifier: injected runtime faults; artifact-backed tasks additionally
//!   run a *real* PJRT numeric check through the hook the coordinator
//!   installs (`runtime::verify`).
//! * Profiler: cost model -> NCU/NSYS-flavored signals with small
//!   deterministic measurement noise.

use super::KernelState;
use crate::bench_suite::{eager, Task};
use crate::device::costmodel;
use crate::device::faults::ChaosConfig;
use crate::device::machine::DeviceSpec;
use crate::device::metrics::{self, RawProfile, ToolVersion};
use crate::kir::legality::{self, CompileError};
use crate::util::rng::Rng;

/// The three feedback channels of one review (Algorithm 1's
/// (boolc, feedbackc), (boolv, feedbackv), (speedup, feedbackp)).
#[derive(Debug, Clone)]
pub struct Review {
    pub compiles: bool,
    pub compile_errors: Vec<CompileError>,
    /// First injected-fault signature surfaced by the Compiler, if any.
    pub compile_fault_sig: Option<String>,
    pub correct: bool,
    /// Verifier message when incorrect.
    pub verify_msg: Option<String>,
    /// Profiling snapshot — only present when the kernel runs correctly.
    pub profile: Option<RawProfile>,
    /// Speedup vs Torch Eager — only when correct.
    pub speedup: Option<f64>,
    pub latency_s: Option<f64>,
    /// Index of the hottest fusion group (the kernel NCU was pointed at).
    pub hot_group: usize,
}

impl Review {
    /// A usable kernel: builds, verifies, and was actually measured. The
    /// speedup check is redundant on a healthy harness (correct implies
    /// measured) but keeps every consumer panic-free when the chaos layer
    /// tampers with measurements.
    pub fn ok(&self) -> bool {
        self.compiles && self.correct && self.speedup.is_some()
    }
}

/// Run the full Reviewer over one kernel state.
pub fn review(
    task: &Task,
    state: &KernelState,
    dev: &DeviceSpec,
    tool: ToolVersion,
    rng: &mut Rng,
) -> Review {
    review_with_eager(task, state, dev, tool, rng, None)
}

/// Reviewer with precomputed task constants (the loop computes the eager
/// latency and the custom floor once per task instead of re-pricing them
/// every round — §Perf opts 3-4).
pub fn review_with_eager(
    task: &Task,
    state: &KernelState,
    dev: &DeviceSpec,
    tool: ToolVersion,
    rng: &mut Rng,
    consts: Option<(f64, f64)>,
) -> Review {
    // ---- Compiler ----
    let compile_errors = legality::check(&task.graph, &state.sched, dev);
    let compile_fault_sig = state.compile_fault().map(|f| f.signature.clone());
    let compiles = compile_errors.is_empty() && compile_fault_sig.is_none();
    if !compiles {
        return Review {
            compiles,
            compile_errors,
            compile_fault_sig,
            correct: false,
            verify_msg: None,
            profile: None,
            speedup: None,
            latency_s: None,
            hot_group: 0,
        };
    }

    // ---- Verifier ----
    if let Some(f) = state.runtime_fault() {
        return Review {
            compiles: true,
            compile_errors: Vec::new(),
            compile_fault_sig: None,
            correct: false,
            verify_msg: Some(f.signature.clone()),
            profile: None,
            speedup: None,
            latency_s: None,
            hot_group: 0,
        };
    }

    // ---- Profiler ----
    let cost = costmodel::price(&task.graph, &state.sched, dev);
    let mut profile = metrics::synthesize(&task.graph, &state.sched, &cost, tool);
    // Deterministic measurement noise: +/- ~1.5% on latency, matching the
    // paper's warmup+100-iteration CUDA-event protocol stability.
    let noise = 1.0 + 0.015 * (rng.f64() * 2.0 - 1.0);
    profile.latency_s *= noise;
    // §Perf opt 4: reuse the cost already computed above instead of
    // re-pricing inside custom_time_s, and take the task-constant floor
    // from the cache.
    let (eager_s, floor_s) = consts.unwrap_or_else(|| {
        (eager::eager_time_s(task, dev), eager::custom_floor_s(task, dev))
    });
    let mut t = cost.total_s;
    if task.graph.structured_operands && !state.sched.specialized {
        t *= task.eager_waste;
    }
    let latency = t.max(floor_s) * noise;
    let speedup = eager_s / latency;

    let hot_group = cost.hot_group();
    Review {
        compiles: true,
        compile_errors: Vec::new(),
        compile_fault_sig: None,
        correct: true,
        verify_msg: None,
        profile: Some(profile),
        speedup: Some(speedup),
        latency_s: Some(latency),
        hot_group,
    }
}

/// Reviewer under environment chaos: the flaky profiler widens (or drops)
/// the measurement and the lying cost model skews the planner-visible
/// counters. Kernel semantics — compile/verify verdicts, the repair branch's
/// fault signatures — are untouched: chaos corrupts what the harness
/// *measures*, never what the kernel *is*. All chaos randomness comes from
/// `chaos_rng`, a stream separate from the cell's own `rng`, so a chaos
/// config with every knob at 0 reviews byte-identically to no chaos.
#[allow(clippy::too_many_arguments)]
pub fn review_chaotic(
    task: &Task,
    state: &KernelState,
    dev: &DeviceSpec,
    tool: ToolVersion,
    rng: &mut Rng,
    consts: Option<(f64, f64)>,
    chaos: Option<(&ChaosConfig, &mut Rng)>,
) -> Review {
    let mut r = review_with_eager(task, state, dev, tool, rng, consts);
    let Some((cfg, chaos_rng)) = chaos else {
        return r;
    };
    if !r.ok() {
        return r;
    }
    // Flaky profiler, noise half: the "measurement" picks up chaos-scale
    // variance on top of the intrinsic +/-1.5%.
    if cfg.profile_sigma > 0.0 {
        let n = (1.0 + cfg.profile_sigma * (chaos_rng.f64() * 2.0 - 1.0)).max(0.05);
        if let Some(l) = r.latency_s.as_mut() {
            *l *= n;
        }
        if let Some(s) = r.speedup.as_mut() {
            *s /= n;
        }
        if let Some(p) = r.profile.as_mut() {
            p.latency_s *= n;
        }
    }
    // Lying cost model: every NCU counter the Planner normalizes is skewed
    // by one shared relative bias (percent keys stay bounded).
    if cfg.cost_bias > 0.0 {
        if let Some(p) = r.profile.as_mut() {
            let skew = 1.0 + cfg.cost_bias * (chaos_rng.f64() * 2.0 - 1.0);
            for (k, v) in p.ncu.iter_mut() {
                *v *= skew;
                if k.contains("pct") {
                    *v = v.min(100.0);
                }
            }
        }
    }
    // Flaky profiler, drop half: the snapshot vanishes entirely; timing
    // survives (the CUDA-event latency comes from a different path than the
    // NCU replay), so the kernel is still usable — degraded, not dead. This
    // is exactly the state the loop's missing-profile warn+converge path
    // was built for.
    if cfg.profile_drop_p > 0.0 && chaos_rng.chance(cfg.profile_drop_p) {
        r.profile = None;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::KernelState;
    use crate::bench_suite;
    use crate::device::faults::{Fault, FaultKind};
    use crate::kir::schedule::Schedule;
    use crate::kir::transforms::MethodId;

    fn task() -> Task {
        bench_suite::level_suite(42, 2).remove(0)
    }

    fn clean_state(t: &Task) -> KernelState {
        KernelState::new(Schedule::per_op_naive(&t.graph), 0)
    }

    #[test]
    fn clean_kernel_reviews_ok() {
        let t = task();
        let s = clean_state(&t);
        let mut rng = Rng::new(1);
        let r = review(&t, &s, &DeviceSpec::a100_like(), ToolVersion::Ncu2023, &mut rng);
        assert!(r.ok());
        assert!(r.profile.is_some());
        assert!(r.speedup.unwrap() > 0.0);
    }

    #[test]
    fn compile_fault_blocks_verification() {
        let t = task();
        let mut s = clean_state(&t);
        s.faults.push(Fault {
            kind: FaultKind::CompileSyntax,
            injected_by: MethodId::TileSmem,
            signature: "error: expected ';'".into(),
            true_fix: 0,
            n_candidate_fixes: 3,
            hard: false,
        });
        let mut rng = Rng::new(1);
        let r = review(&t, &s, &DeviceSpec::a100_like(), ToolVersion::Ncu2023, &mut rng);
        assert!(!r.compiles);
        assert!(!r.correct);
        assert!(r.profile.is_none());
        assert_eq!(r.compile_fault_sig.as_deref(), Some("error: expected ';'"));
    }

    #[test]
    fn runtime_fault_fails_verification_only() {
        let t = task();
        let mut s = clean_state(&t);
        s.faults.push(Fault {
            kind: FaultKind::WrongNumerics,
            injected_by: MethodId::SplitK,
            signature: "max abs err 3.2e+01".into(),
            true_fix: 1,
            n_candidate_fixes: 3,
            hard: false,
        });
        let mut rng = Rng::new(1);
        let r = review(&t, &s, &DeviceSpec::a100_like(), ToolVersion::Ncu2023, &mut rng);
        assert!(r.compiles);
        assert!(!r.correct);
        assert!(r.verify_msg.is_some());
        assert!(r.speedup.is_none());
    }

    #[test]
    fn structurally_illegal_schedule_fails_compile() {
        let t = task();
        let mut s = clean_state(&t);
        s.sched.cfg[0].mxu = true; // unstaged MXU: legality error
        let mut rng = Rng::new(1);
        let r = review(&t, &s, &DeviceSpec::a100_like(), ToolVersion::Ncu2023, &mut rng);
        assert!(!r.compiles);
        assert!(!r.compile_errors.is_empty());
    }

    #[test]
    fn chaos_with_zero_knobs_is_byte_identical() {
        let t = task();
        let s = clean_state(&t);
        let dev = DeviceSpec::a100_like();
        let cfg = ChaosConfig::parse("seed=7").unwrap();
        let a = review(&t, &s, &dev, ToolVersion::Ncu2023, &mut Rng::new(7));
        let b = review_chaotic(
            &t,
            &s,
            &dev,
            ToolVersion::Ncu2023,
            &mut Rng::new(7),
            None,
            Some((&cfg, &mut Rng::new(99))),
        );
        assert_eq!(a.speedup, b.speedup);
        assert_eq!(a.latency_s, b.latency_s);
        assert_eq!(
            a.profile.as_ref().map(|p| p.ncu.clone()),
            b.profile.as_ref().map(|p| p.ncu.clone())
        );
    }

    #[test]
    fn chaos_drop_removes_profile_but_keeps_speedup() {
        let t = task();
        let s = clean_state(&t);
        let dev = DeviceSpec::a100_like();
        let cfg = ChaosConfig::parse("drop=1,seed=7").unwrap();
        let r = review_chaotic(
            &t,
            &s,
            &dev,
            ToolVersion::Ncu2023,
            &mut Rng::new(7),
            None,
            Some((&cfg, &mut Rng::new(99))),
        );
        assert!(r.ok(), "a dropped profile still leaves a usable kernel");
        assert!(r.profile.is_none());
        assert!(r.speedup.is_some() && r.latency_s.is_some());
    }

    #[test]
    fn chaos_bias_keeps_percent_counters_bounded_and_is_seeded() {
        let t = task();
        let s = clean_state(&t);
        let dev = DeviceSpec::a100_like();
        let cfg = ChaosConfig::parse("sigma=0.5,bias=1,seed=3").unwrap();
        let run = |crng_seed: u64| {
            review_chaotic(
                &t,
                &s,
                &dev,
                ToolVersion::Ncu2023,
                &mut Rng::new(7),
                None,
                Some((&cfg, &mut Rng::new(crng_seed))),
            )
        };
        let a = run(5);
        let b = run(5);
        assert_eq!(a.speedup, b.speedup, "chaos is a deterministic stream");
        let p = a.profile.expect("bias does not drop the profile");
        for (k, v) in &p.ncu {
            assert!(*v >= 0.0, "{k} went negative: {v}");
            if k.contains("pct") {
                assert!(*v <= 100.0, "{k} escaped bounds: {v}");
            }
        }
        // Semantics untouched: only measurements move.
        assert!(a.compiles && a.correct);
        assert!(b.speedup.unwrap() > 0.0);
    }

    #[test]
    fn measurement_noise_is_small_and_seeded() {
        let t = task();
        let s = clean_state(&t);
        let dev = DeviceSpec::a100_like();
        let a = review(&t, &s, &dev, ToolVersion::Ncu2023, &mut Rng::new(7));
        let b = review(&t, &s, &dev, ToolVersion::Ncu2023, &mut Rng::new(7));
        let c = review(&t, &s, &dev, ToolVersion::Ncu2023, &mut Rng::new(8));
        assert_eq!(a.speedup, b.speedup);
        let rel = (a.speedup.unwrap() - c.speedup.unwrap()).abs() / a.speedup.unwrap();
        assert!(rel < 0.05, "noise too big: {rel}");
    }
}
