//! Feature Extractor agent (§4.1.3): the hybrid rule-based / LLM-based
//! static-feature pipeline.
//!
//! Rule-based features come straight off the structured kernel (exact);
//! LLM-based features (the `LLM_BASED` mask) are extracted by the surrogate
//! with accuracy `feature_accuracy` — occasionally mis-read, which is
//! exactly why the decision policy gates on *combinations* of evidence.

use super::policy::PolicyProfile;
use crate::kir::features::{self, CodeFeatures, OccupancyLimiter, ReductionPattern, LLM_BASED};
use crate::kir::graph::KernelGraph;
use crate::kir::schedule::Schedule;
use crate::util::rng::Rng;

/// Extract the 18 static features with the hybrid mechanism, focused on
/// the profiler's hot group.
pub fn extract(
    graph: &KernelGraph,
    sched: &Schedule,
    focus_group: usize,
    policy: &PolicyProfile,
    rng: &mut Rng,
) -> CodeFeatures {
    let truth = features::ground_truth_at(graph, sched, focus_group);
    let mut f = truth.clone();
    // Corrupt each LLM-based feature independently with prob (1 - accuracy).
    let miss = |rng: &mut Rng, acc: f64| rng.chance(1.0 - acc);
    let acc = policy.feature_accuracy;
    if LLM_BASED[0] && miss(rng, acc) {
        f.naive_gemm_loop = !f.naive_gemm_loop;
    }
    if LLM_BASED[4] && miss(rng, acc) {
        f.coalesced_access = !f.coalesced_access;
    }
    if LLM_BASED[5] && miss(rng, acc) {
        f.bank_conflict_risk = !f.bank_conflict_risk;
    }
    if LLM_BASED[6] && miss(rng, acc) {
        f.fusion_opportunities = f.fusion_opportunities.saturating_sub(1);
    }
    if LLM_BASED[12] && miss(rng, acc) {
        f.register_pressure = (f.register_pressure + 1) % 3;
    }
    if LLM_BASED[13] && miss(rng, acc) {
        f.occupancy_limiter = OccupancyLimiter::None;
    }
    if LLM_BASED[14] && miss(rng, acc) {
        f.strided_access = !f.strided_access;
    }
    if LLM_BASED[16] && miss(rng, acc) {
        f.divergence_risk = !f.divergence_risk;
    }
    // Feature 19 (structured operand) is semantic recognition — LLM-based,
    // and only ever missed in the false-negative direction (an agent does
    // not hallucinate structure that is not there).
    if f.structured_operand && miss(rng, acc) {
        f.structured_operand = false;
    }
    f
}

/// Accuracy of an extraction vs ground truth over the LLM-based features
/// (used in tests and the calibration harness).
pub fn llm_feature_agreement(a: &CodeFeatures, b: &CodeFeatures) -> f64 {
    let mut total = 0.0;
    let mut agree = 0.0;
    let mut check = |is_llm: bool, same: bool| {
        if is_llm {
            total += 1.0;
            if same {
                agree += 1.0;
            }
        }
    };
    check(LLM_BASED[0], a.naive_gemm_loop == b.naive_gemm_loop);
    check(LLM_BASED[4], a.coalesced_access == b.coalesced_access);
    check(LLM_BASED[5], a.bank_conflict_risk == b.bank_conflict_risk);
    check(LLM_BASED[6], a.fusion_opportunities == b.fusion_opportunities);
    check(LLM_BASED[12], a.register_pressure == b.register_pressure);
    check(LLM_BASED[13], a.occupancy_limiter == b.occupancy_limiter);
    check(LLM_BASED[14], a.strided_access == b.strided_access);
    check(LLM_BASED[16], a.divergence_risk == b.divergence_risk);
    if total == 0.0 {
        1.0
    } else {
        agree / total
    }
}

/// Sanity helper used by tests: rule-based features must always be exact.
pub fn rule_based_exact(a: &CodeFeatures, b: &CodeFeatures) -> bool {
    a.smem_tiling == b.smem_tiling
        && a.tensor_core == b.tensor_core
        && a.vectorized_loads == b.vectorized_loads
        && a.unfused_ew_chain == b.unfused_ew_chain
        && a.reduction_pattern == b.reduction_pattern
        && a.mixed_precision == b.mixed_precision
        && a.double_buffered == b.double_buffered
        && a.unrolled == b.unrolled
        && a.uses_atomics == b.uses_atomics
        && a.kernel_launches == b.kernel_launches
}

#[allow(unused)]
fn _pattern_exhaustiveness(r: ReductionPattern) {
    // Compile-time reminder: extend corruption logic when patterns grow.
    match r {
        ReductionPattern::None
        | ReductionPattern::Row
        | ReductionPattern::Col
        | ReductionPattern::Full => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::level2::appendix_d_graph;

    fn setup() -> (KernelGraph, Schedule) {
        let g = appendix_d_graph(256, 512, 512);
        let s = Schedule::per_op_naive(&g);
        (g, s)
    }

    #[test]
    fn perfect_accuracy_reproduces_truth() {
        let (g, s) = setup();
        let mut p = PolicyProfile::chatgpt51();
        p.feature_accuracy = 1.0;
        let mut rng = Rng::new(3);
        let f = extract(&g, &s, 0, &p, &mut rng);
        assert_eq!(f, features::ground_truth(&g, &s));
    }

    #[test]
    fn rule_based_features_never_corrupted() {
        let (g, s) = setup();
        let mut p = PolicyProfile::chatgpt51();
        p.feature_accuracy = 0.0; // worst case LLM
        let truth = features::ground_truth(&g, &s);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let f = extract(&g, &s, 0, &p, &mut rng);
            assert!(rule_based_exact(&f, &truth));
        }
    }

    #[test]
    fn agreement_tracks_accuracy() {
        let (g, s) = setup();
        let truth = features::ground_truth(&g, &s);
        let measure = |acc: f64| {
            let mut p = PolicyProfile::chatgpt51();
            p.feature_accuracy = acc;
            let mut rng = Rng::new(5);
            let mut sum = 0.0;
            for _ in 0..300 {
                sum += llm_feature_agreement(&extract(&g, &s, 0, &p, &mut rng), &truth);
            }
            sum / 300.0
        };
        let high = measure(0.95);
        let low = measure(0.5);
        assert!(high > 0.9, "high={high}");
        assert!(low < high - 0.2, "low={low} high={high}");
    }
}
