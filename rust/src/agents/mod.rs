//! The multi-agent roster (§4.1): Generator, Feature Extractor, Reviewer
//! (Compiler + Verifier + Profiler), Planner, Optimizer, Diagnoser,
//! Repairer — plus the LLM-surrogate policy core they all draw from.

pub mod diagnoser;
pub mod feature_extractor;
pub mod generator;
pub mod optimizer;
pub mod planner;
pub mod policy;
pub mod repairer;
pub mod reviewer;

use crate::device::faults::Fault;
use crate::kir::schedule::Schedule;

/// One candidate kernel in the refinement loop: a schedule plus any latent
/// defects the surrogate's edits introduced.
#[derive(Debug, Clone)]
pub struct KernelState {
    pub sched: Schedule,
    pub faults: Vec<Fault>,
    /// Monotone version counter within a task run (Figure 2/3 numbering).
    pub version: u32,
}

impl KernelState {
    pub fn new(sched: Schedule, version: u32) -> Self {
        KernelState {
            sched,
            faults: Vec::new(),
            version,
        }
    }

    pub fn is_clean(&self) -> bool {
        self.faults.is_empty()
    }

    /// First compile-stage fault, if any (the Compiler reports these).
    pub fn compile_fault(&self) -> Option<&Fault> {
        self.faults.iter().find(|f| f.kind.is_compile())
    }

    /// First runtime fault (the Verifier reports these).
    pub fn runtime_fault(&self) -> Option<&Fault> {
        self.faults.iter().find(|f| !f.kind.is_compile())
    }
}
