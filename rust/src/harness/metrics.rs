//! Evaluation metrics (§5.1): Success, Speedup (vs Torch Eager), Fast_p.

use crate::coordinator::TaskResult;

/// Aggregate statistics for one (strategy, level) cell.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub n: usize,
    pub success: f64,
    /// Mean speedup; failed tasks contribute 0 (how Kevin's 0.32x L3
    /// average coexists with 46% success in Table 1).
    pub speedup: f64,
    /// fast_1: fraction at least as fast as Torch Eager.
    pub fast1: f64,
    pub mean_rounds: f64,
    /// Mean speedup divided by the refinement budget (§5.4's per-round
    /// efficiency comparison).
    pub speedup_per_round: f64,
}

/// Compute a cell from task results (already filtered to one level).
pub fn cell(results: &[&TaskResult], budget_rounds: u32) -> Cell {
    let n = results.len();
    if n == 0 {
        return Cell::default();
    }
    let succ = results.iter().filter(|r| r.success).count() as f64 / n as f64;
    let speedup = results.iter().map(|r| r.best_speedup).sum::<f64>() / n as f64;
    let fast1 = results.iter().filter(|r| r.best_speedup >= 1.0).count() as f64 / n as f64;
    let mean_rounds = results.iter().map(|r| r.rounds_used as f64).sum::<f64>() / n as f64;
    Cell {
        n,
        success: succ,
        speedup,
        fast1,
        mean_rounds,
        speedup_per_round: speedup / budget_rounds.max(1) as f64,
    }
}

/// fast_p for an arbitrary threshold (KernelBench's general metric).
pub fn fast_p(results: &[&TaskResult], p: f64) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().filter(|r| r.best_speedup >= p).count() as f64 / results.len() as f64
}

/// Split suite results by level. Four buckets: L1-L3 (the paper tables)
/// plus the generated Level-4 fused-pipeline workload; out-of-range levels
/// clamp into the last bucket.
pub fn by_level(results: &[TaskResult]) -> [Vec<&TaskResult>; 4] {
    let mut out: [Vec<&TaskResult>; 4] = [vec![], vec![], vec![], vec![]];
    for r in results {
        let idx = (r.level as usize).saturating_sub(1).min(3);
        out[idx].push(r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::graph::KernelGraph;
    use crate::kir::schedule::Schedule;

    fn result(level: u8, success: bool, speedup: f64) -> TaskResult {
        let mut g = KernelGraph::new();
        g.push(crate::kir::op::OpKind::MatMul, 8, 8, 8, vec![]);
        TaskResult {
            task_id: "t".into(),
            level,
            strategy: "x",
            success,
            best_speedup: speedup,
            seed_speedup: None,
            rounds_used: 10,
            rounds: vec![],
            promotions: 0,
            repair_attempts: 0,
            longest_repair_chain: 0,
            best_sched: Schedule::per_op_naive(&g),
            skill_obs: vec![],
        }
    }

    #[test]
    fn cell_counts_failures_as_zero() {
        let rs = vec![result(1, true, 2.0), result(1, false, 0.0)];
        let refs: Vec<&TaskResult> = rs.iter().collect();
        let c = cell(&refs, 15);
        assert_eq!(c.success, 0.5);
        assert_eq!(c.speedup, 1.0);
        assert_eq!(c.fast1, 0.5);
        assert!((c.speedup_per_round - 1.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn fast_p_thresholds() {
        let rs = vec![result(1, true, 0.5), result(1, true, 1.5), result(1, true, 3.0)];
        let refs: Vec<&TaskResult> = rs.iter().collect();
        assert!((fast_p(&refs, 1.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((fast_p(&refs, 2.0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn by_level_partitions() {
        let rs = vec![
            result(1, true, 1.0),
            result(2, true, 1.0),
            result(3, true, 1.0),
            result(2, true, 1.0),
            result(4, true, 1.0),
            result(9, true, 1.0), // out of range clamps into the L4 bucket
        ];
        let split = by_level(&rs);
        assert_eq!(split[0].len(), 1);
        assert_eq!(split[1].len(), 2);
        assert_eq!(split[2].len(), 1);
        assert_eq!(split[3].len(), 2);
    }
}
