//! Experiment drivers: one function per paper table/figure (DESIGN.md's
//! experiment index E1-E5). Each returns the rendered table plus raw rows
//! so benches and the CLI can share the implementation.

use super::metrics::{by_level, cell};
use super::tables::{self, Row};
use crate::baselines::{self, Strategy};
use crate::bench_suite;
use crate::coordinator::{self, Branch, LoopConfig};
use crate::util::pool;

/// Shared experiment configuration.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Suite-generation seed (task population).
    pub suite_seed: u64,
    /// Run seeds (repetitions averaged together).
    pub run_seeds: Vec<u64>,
    pub workers: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            suite_seed: 42,
            run_seeds: vec![0],
            workers: pool::default_workers(),
        }
    }
}

/// Run one roster over the full suite, producing per-level rows.
pub fn run_roster(roster: &[Strategy], cfg: &ExpConfig) -> Vec<Row> {
    let tasks = bench_suite::full_suite(cfg.suite_seed);
    let loop_cfg = LoopConfig::default();
    roster
        .iter()
        .map(|strategy| {
            let suite = coordinator::run_suite(
                &tasks,
                strategy,
                &loop_cfg,
                &cfg.run_seeds,
                cfg.workers,
            );
            let split = by_level(&suite.results);
            Row {
                method: strategy.name.to_string(),
                cells: [
                    cell(&split[0], strategy.rounds),
                    cell(&split[1], strategy.rounds),
                    cell(&split[2], strategy.rounds),
                ],
            }
        })
        .collect()
}

/// E1 — Table 1: Success + Speedup, 7 methods x 3 levels.
pub fn table1(cfg: &ExpConfig) -> (String, Vec<Row>) {
    let rows = run_roster(&baselines::table1_roster(), cfg);
    (tables::table1(&rows), rows)
}

/// E2 — Table 2: memory ablations with Fast1.
pub fn table2(cfg: &ExpConfig) -> (String, Vec<Row>) {
    let rows = run_roster(&baselines::table2_roster(), cfg);
    (tables::table2(&rows), rows)
}

/// E3 — Table 3: Fast1 for the Table-1 roster (same runs, different view).
pub fn table3(cfg: &ExpConfig) -> (String, Vec<Row>) {
    let rows = run_roster(&baselines::table1_roster(), cfg);
    (tables::table3(&rows), rows)
}

/// §5.4 — per-round refinement efficiency (KernelSkill vs STARK).
pub fn per_round_efficiency(cfg: &ExpConfig) -> (String, Vec<Row>) {
    let rows = run_roster(&[baselines::stark(), baselines::kernelskill()], cfg);
    (tables::per_round(&rows), rows)
}

/// E4 — Figures 2-3: trajectory traces on a representative task, rendering
/// the repair chain and the optimization rounds with base promotions.
pub fn trajectory_figures(cfg: &ExpConfig) -> String {
    let tasks = bench_suite::level_suite(cfg.suite_seed, 2);
    let task = tasks
        .iter()
        .find(|t| t.id.contains("fused_epilogue"))
        .expect("appendix-D task present");
    let mut out = String::new();
    let loop_cfg = LoopConfig::default();
    let r = coordinator::run_task(task, &baselines::kernelskill(), &loop_cfg);
    out.push_str(&format!(
        "Task {} — KernelSkill trajectory (seed {:.3?}x -> best {:.3}x, {} promotions, {} repair attempts, longest chain {})\n",
        task.id, r.seed_speedup, r.best_speedup, r.promotions, r.repair_attempts, r.longest_repair_chain
    ));
    for rec in &r.rounds {
        let what = match &rec.branch {
            Branch::Optimize(m) => format!("optimize[{}]", m.name()),
            Branch::Repair(fix) => format!("repair[fix {fix}]"),
            Branch::Revert => "revert".to_string(),
            Branch::Converged => "converged".to_string(),
        };
        out.push_str(&format!(
            "  round {:>2}: {:<28} compiled={} correct={} speedup={}\n",
            rec.round,
            what,
            rec.compiled,
            rec.correct,
            rec.speedup
                .map(|s| format!("{s:.3}x"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    // Aggregate chain statistics across a level (the Figure-2 claim:
    // short-term memory bounds repair chains).
    let l3 = bench_suite::level_suite(cfg.suite_seed, 3);
    for strategy in [baselines::kernelskill(), baselines::wo_short_term()] {
        let suite = coordinator::run_suite(&l3, &strategy, &loop_cfg, &cfg.run_seeds, cfg.workers);
        let chains: Vec<f64> = suite
            .results
            .iter()
            .map(|r| r.longest_repair_chain as f64)
            .collect();
        let repairs: Vec<f64> = suite
            .results
            .iter()
            .map(|r| r.repair_attempts as f64)
            .collect();
        out.push_str(&format!(
            "{:<24}: mean repair attempts {:.2}, mean longest chain {:.2}, max chain {:.0} (L3)\n",
            strategy.name,
            crate::util::stats::mean(&repairs),
            crate::util::stats::mean(&chains),
            chains.iter().fold(0.0f64, |a, &b| a.max(b)),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            suite_seed: 42,
            run_seeds: vec![0],
            workers: 4,
        }
    }

    #[test]
    fn trajectory_renders() {
        // Uses only one task + L3 chains; moderately fast.
        let out = trajectory_figures(&tiny_cfg());
        assert!(out.contains("KernelSkill trajectory"));
        assert!(out.contains("round"));
        assert!(out.contains("mean repair attempts"));
    }
}
