//! Experiment drivers: one function per paper table/figure (DESIGN.md's
//! experiment index E1-E5). Each returns the rendered table plus raw rows
//! so benches and the CLI can share the implementation.

use std::path::{Path, PathBuf};

use super::metrics::{by_level, cell};
use super::tables::{self, Row};
use crate::baselines::{self, Strategy};
use crate::bench_suite;
use crate::coordinator::{self, Branch, LoopConfig, RunDir, SuiteOptions, TaskResult};
use crate::memory::long_term::SkillStore;
use crate::util::pool;

/// Shared experiment configuration.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Suite-generation seed (task population).
    pub suite_seed: u64,
    /// Run seeds (repetitions averaged together).
    pub run_seeds: Vec<u64>,
    pub workers: usize,
    /// Checkpoint directory: every finished cell streams to
    /// `<run_dir>/results.jsonl` and `--resume` skips completed cells.
    pub run_dir: Option<PathBuf>,
    pub resume: bool,
    /// Persistent long-term memory directory (`skills.json` + `kb.json`).
    pub memory_dir: Option<PathBuf>,
    /// Shard the cell matrix across this many independent processes
    /// (`--shards`); 1 = unsharded.
    pub shards: usize,
    /// This process's slice, in `0..shards` (`--shard-index`).
    pub shard_index: usize,
    /// Elastic lease batch count (`--batch-count`); 0 = not batch-sliced.
    /// Mutually exclusive with sharding (the scheduler validates).
    pub batch_count: usize,
    /// This process's batch, in `0..batch_count` (`--batch-index`).
    pub batch_index: usize,
    /// Shared live memory-exchange directory (`--exchange-dir`); None =
    /// exchange off.
    pub exchange_dir: Option<PathBuf>,
    /// Cells per exchange epoch (`--exchange-epoch`); 0 picks the default
    /// when `exchange_dir` is set.
    pub exchange_epoch: usize,
    /// Adaptive (doubling) exchange-epoch schedule (`--exchange-adaptive`).
    /// Part of the experiment identity — recorded in the manifest and
    /// checked at merge time.
    pub exchange_adaptive: bool,
    /// Device preset to price against (`--device`); None = the default
    /// (A100-like). Part of the experiment identity: it is recorded in the
    /// run manifest and keys the skill-store partition observations land
    /// in, so resume and merge refuse to mix presets.
    pub device: Option<crate::device::machine::DeviceSpec>,
    /// Memoize per-task-run retrieval lookups (`--no-retrieval-cache`
    /// turns it off). Byte-identical either way — the flag exists for A/B
    /// timing and for bisecting a suspected cache bug, not for changing
    /// results.
    pub retrieval_cache: bool,
    /// Environment-fault injection (`--chaos`); None = clean environment.
    /// Part of the experiment identity: the canonical spec is recorded in
    /// the run manifest, so resume refuses a different chaos and merge
    /// refuses to mix chaotic and clean shards.
    pub chaos: Option<crate::device::faults::ChaosConfig>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            suite_seed: 42,
            run_seeds: vec![0],
            workers: pool::default_workers(),
            run_dir: None,
            resume: false,
            memory_dir: None,
            shards: 1,
            shard_index: 0,
            batch_count: 0,
            batch_index: 0,
            exchange_dir: None,
            exchange_epoch: 0,
            exchange_adaptive: false,
            device: None,
            retrieval_cache: true,
            chaos: None,
        }
    }
}

impl ExpConfig {
    pub fn loop_cfg(&self) -> LoopConfig {
        let mut cfg = LoopConfig {
            memory_dir: self.memory_dir.clone(),
            retrieval_cache: self.retrieval_cache,
            chaos: self.chaos.clone(),
            ..LoopConfig::default()
        };
        if let Some(dev) = &self.device {
            cfg.dev = dev.clone();
        }
        cfg
    }

    pub fn suite_opts(&self) -> SuiteOptions {
        SuiteOptions {
            run_dir: self.run_dir.clone(),
            resume: self.resume,
            stop_after: None,
            // Plain `--shards 1` stays the unsharded fast path; an
            // out-of-range index still reaches the scheduler's validation.
            shard: if self.shards != 1 || self.shard_index != 0 {
                Some(coordinator::Shard {
                    index: self.shard_index,
                    count: self.shards,
                })
            } else {
                None
            },
            batch: if self.batch_count != 0 {
                Some(coordinator::Batch {
                    index: self.batch_index,
                    count: self.batch_count,
                })
            } else {
                None
            },
            exchange: self.exchange_dir.as_ref().map(|dir| {
                let mut ex = coordinator::ExchangeOptions::new(
                    dir.clone(),
                    if self.exchange_epoch == 0 {
                        coordinator::DEFAULT_EXCHANGE_EPOCH
                    } else {
                        self.exchange_epoch
                    },
                );
                ex.adaptive = self.exchange_adaptive;
                ex
            }),
        }
    }
}

/// Build a per-level row for one strategy's results.
fn row_for(name: &str, budget_rounds: u32, results: &[TaskResult]) -> Row {
    let split = by_level(results);
    Row {
        method: name.to_string(),
        cells: [
            cell(&split[0], budget_rounds),
            cell(&split[1], budget_rounds),
            cell(&split[2], budget_rounds),
        ],
    }
}

/// Run one roster over the full suite, producing per-level rows.
///
/// Errors are user-facing (dirty run dir without `--resume`, mismatched
/// matrix manifest, checkpoint IO) and propagate so the CLI can print them
/// cleanly instead of panicking.
pub fn run_roster(roster: &[Strategy], cfg: &ExpConfig) -> Result<Vec<Row>, String> {
    let tasks = bench_suite::full_suite(cfg.suite_seed);
    let loop_cfg = cfg.loop_cfg();
    let opts = cfg.suite_opts();
    roster
        .iter()
        .map(|strategy| {
            let suite = coordinator::run_suite_with(
                &tasks,
                strategy,
                &loop_cfg,
                &cfg.run_seeds,
                cfg.workers,
                &opts,
            )
            .map_err(|e| format!("suite run failed for {}: {e}", strategy.name))?;
            Ok(row_for(strategy.name, strategy.rounds, &suite.results))
        })
        .collect()
}

/// E1 — Table 1: Success + Speedup, 7 methods x 3 levels.
pub fn table1(cfg: &ExpConfig) -> Result<(String, Vec<Row>), String> {
    let rows = run_roster(&baselines::table1_roster(), cfg)?;
    Ok((tables::table1(&rows), rows))
}

/// E2 — Table 2: memory ablations with Fast1.
pub fn table2(cfg: &ExpConfig) -> Result<(String, Vec<Row>), String> {
    let rows = run_roster(&baselines::table2_roster(), cfg)?;
    Ok((tables::table2(&rows), rows))
}

/// E3 — Table 3: Fast1 for the Table-1 roster (same runs, different view).
pub fn table3(cfg: &ExpConfig) -> Result<(String, Vec<Row>), String> {
    let rows = run_roster(&baselines::table1_roster(), cfg)?;
    Ok((tables::table3(&rows), rows))
}

/// §5.4 — per-round refinement efficiency (KernelSkill vs STARK).
pub fn per_round_efficiency(cfg: &ExpConfig) -> Result<(String, Vec<Row>), String> {
    let rows = run_roster(&[baselines::stark(), baselines::kernelskill()], cfg)?;
    Ok((tables::per_round(&rows), rows))
}

/// E4 — Figures 2-3: trajectory traces on a representative task, rendering
/// the repair chain and the optimization rounds with base promotions.
pub fn trajectory_figures(cfg: &ExpConfig) -> String {
    let tasks = bench_suite::level_suite(cfg.suite_seed, 2);
    let task = tasks
        .iter()
        .find(|t| t.id.contains("fused_epilogue"))
        .expect("appendix-D task present");
    let mut out = String::new();
    let loop_cfg = LoopConfig::default();
    let r = coordinator::run_task(task, &baselines::kernelskill(), &loop_cfg);
    out.push_str(&format!(
        "Task {} — KernelSkill trajectory (seed {:.3?}x -> best {:.3}x, {} promotions, {} repair attempts, longest chain {})\n",
        task.id,
        r.seed_speedup,
        r.best_speedup,
        r.promotions,
        r.repair_attempts,
        r.longest_repair_chain
    ));
    for rec in &r.rounds {
        let what = match &rec.branch {
            Branch::Optimize(m) => format!("optimize[{}]", m.name()),
            Branch::Repair(fix) => format!("repair[fix {fix}]"),
            Branch::Revert => "revert".to_string(),
            Branch::Converged => "converged".to_string(),
        };
        out.push_str(&format!(
            "  round {:>2}: {:<28} compiled={} correct={} speedup={}\n",
            rec.round,
            what,
            rec.compiled,
            rec.correct,
            rec.speedup
                .map(|s| format!("{s:.3}x"))
                .unwrap_or_else(|| "-".into()),
        ));
    }
    // Aggregate chain statistics across a level (the Figure-2 claim:
    // short-term memory bounds repair chains).
    let l3 = bench_suite::level_suite(cfg.suite_seed, 3);
    for strategy in [baselines::kernelskill(), baselines::wo_short_term()] {
        let suite = coordinator::run_suite(&l3, &strategy, &loop_cfg, &cfg.run_seeds, cfg.workers);
        let chains: Vec<f64> = suite
            .results
            .iter()
            .map(|r| r.longest_repair_chain as f64)
            .collect();
        let repairs: Vec<f64> = suite
            .results
            .iter()
            .map(|r| r.repair_attempts as f64)
            .collect();
        out.push_str(&format!(
            "{:<24}: mean repair attempts {:.2}, mean longest chain {:.2}, max chain {:.0} (L3)\n",
            strategy.name,
            crate::util::stats::mean(&repairs),
            crate::util::stats::mean(&chains),
            chains.iter().fold(0.0f64, |a, &b| a.max(b)),
        ));
    }
    out
}

// ------------------------------------------------------------------------
// Streamed-result readers: rebuild tables straight from a run directory's
// JSONL checkpoint, without re-running anything.
// ------------------------------------------------------------------------

/// Group a run directory's streamed cells into per-strategy result lists.
/// Cells arrive sorted by (strategy, task, seed) key — the checkpoint
/// loader's map order — not in completion order.
pub fn results_from_run_dir(path: &Path) -> Result<Vec<(String, Vec<TaskResult>)>, String> {
    if !path.is_dir() {
        return Err(format!("{} is not a run directory", path.display()));
    }
    let rd = RunDir::open(path).map_err(|e| format!("opening run dir: {e}"))?;
    if !rd.has_results() {
        return Err(format!("{} has no results.jsonl yet", path.display()));
    }
    let cells = rd.load().map_err(|e| format!("loading checkpoint: {e}"))?;
    let mut out: Vec<(String, Vec<TaskResult>)> = Vec::new();
    for (key, result) in cells {
        match out.iter_mut().find(|(name, _)| *name == key.strategy) {
            Some((_, list)) => list.push(result),
            None => out.push((key.strategy.clone(), vec![result])),
        }
    }
    Ok(out)
}

/// Per-level table rows from already-grouped results (budget rounds
/// resolved from the strategy roster; unknown strategies fall back to the
/// paper's 15).
pub fn rows_from_results(grouped: &[(String, Vec<TaskResult>)]) -> Vec<Row> {
    grouped
        .iter()
        .map(|(name, results)| {
            let budget = baselines::by_name(name).map(|s| s.rounds).unwrap_or(15);
            row_for(name, budget, results)
        })
        .collect()
}

/// Per-level table rows straight from a run directory.
pub fn rows_from_run_dir(path: &Path) -> Result<Vec<Row>, String> {
    Ok(rows_from_results(&results_from_run_dir(path)?))
}

/// Render a run directory's streamed results as the ablation-style table
/// (Success / Fast1 / Speedup per level) plus completion counts.
///
/// The rendering is a pure function of the directory's *cells* — the path
/// itself never appears — so two dirs holding the same results render
/// byte-identically. The CI `shard-smoke` job diffs a merged shard run
/// against a single-process run on exactly this property.
pub fn report_run_dir(path: &Path) -> Result<String, String> {
    let grouped = results_from_run_dir(path)?;
    let rows = rows_from_results(&grouped);
    let mut out = String::new();
    out.push_str("Run report — streamed results\n");
    for (name, results) in &grouped {
        out.push_str(&format!("  {:<24} {} cells completed\n", name, results.len()));
    }
    out.push('\n');
    out.push_str(&tables::table2(&rows));
    Ok(out)
}

// ------------------------------------------------------------------------
// Bench-smoke: the CI end-to-end proof that orchestration v2 works.
// ------------------------------------------------------------------------

/// Assert two cells agree exactly (f64 equality is intended: checkpointed
/// aggregates must be byte-identical to uninterrupted ones).
fn cells_identical(a: &super::metrics::Cell, b: &super::metrics::Cell) -> bool {
    a.n == b.n
        && a.success == b.success
        && a.speedup == b.speedup
        && a.fast1 == b.fast1
        && a.mean_rounds == b.mean_rounds
        && a.speedup_per_round == b.speedup_per_round
}

/// Tiny end-to-end suite exercising the whole orchestration stack:
/// 2 tasks × 1 seed, checkpointed, killed after one cell, resumed, verified
/// against an uninterrupted in-memory run, reloaded from disk, and run with
/// persistent memory. Returns a human-readable summary; any mismatch is an
/// error (CI fails).
pub fn smoke(root: &Path) -> Result<String, String> {
    let strategy = baselines::kernelskill();
    let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(2).collect();
    let seeds = [0u64];
    let cfg = LoopConfig::default();
    let mut log = String::new();

    // Reference: uninterrupted, fully in-memory.
    let reference = coordinator::run_suite(&tasks, &strategy, &cfg, &seeds, 2);
    let ref_rows = row_for(strategy.name, strategy.rounds, &reference.results);
    log.push_str(&format!(
        "reference run: {} cells, L1 speedup {:.3}\n",
        reference.results.len(),
        ref_rows.cells[0].speedup
    ));

    // Interrupted + resumed, streaming to a run dir.
    let run_dir = root.join("smoke-run");
    let _ = std::fs::remove_dir_all(&run_dir);
    let mut opts = SuiteOptions::in_dir(&run_dir);
    opts.stop_after = Some(1);
    let partial = coordinator::run_suite_with(&tasks, &strategy, &cfg, &seeds, 2, &opts)?;
    if partial.results.len() != 1 {
        return Err(format!(
            "stop_after=1 should complete exactly one cell, got {}",
            partial.results.len()
        ));
    }
    log.push_str("interrupted after 1 cell; checkpoint written\n");

    let resumed = coordinator::run_suite_with(
        &tasks,
        &strategy,
        &cfg,
        &seeds,
        2,
        &SuiteOptions::resumed(&run_dir),
    )?;
    let res_rows = row_for(strategy.name, strategy.rounds, &resumed.results);
    if !cells_identical(&ref_rows.cells[0], &res_rows.cells[0]) {
        return Err(format!(
            "resumed aggregates differ from uninterrupted: {:?} vs {:?}",
            res_rows.cells[0], ref_rows.cells[0]
        ));
    }
    log.push_str("resumed run reproduces uninterrupted aggregates exactly\n");

    // Reload the streamed JSONL and re-derive the same aggregates.
    let rows = rows_from_run_dir(&run_dir)?;
    let from_disk = rows
        .iter()
        .find(|r| r.method == strategy.name)
        .ok_or("run dir lost the strategy row")?;
    if !cells_identical(&from_disk.cells[0], &ref_rows.cells[0]) {
        return Err("aggregates reloaded from results.jsonl differ".to_string());
    }
    log.push_str("results.jsonl round-trips to identical aggregates\n");

    // Persistent memory: run with a memory dir, check the store landed.
    let mem_dir = root.join("smoke-memory");
    let _ = std::fs::remove_dir_all(&mem_dir);
    let mut mem_cfg = cfg.clone();
    mem_cfg.memory_dir = Some(mem_dir.clone());
    coordinator::run_suite_with(
        &tasks,
        &strategy,
        &mem_cfg,
        &seeds,
        2,
        &SuiteOptions::default(),
    )?;
    let store = SkillStore::load(&mem_dir.join("skills.json"))?;
    if store.observations == 0 {
        return Err("persistent skill store recorded no observations".to_string());
    }
    if !mem_dir.join("kb.json").exists() {
        return Err("curated KB export missing from memory dir".to_string());
    }
    log.push_str(&format!(
        "persistent memory: {} observations across {} cases (generation {})\n",
        store.observations,
        store.case_count(),
        store.generation
    ));

    let _ = std::fs::remove_dir_all(&run_dir);
    let _ = std::fs::remove_dir_all(&mem_dir);
    log.push_str("smoke ok\n");
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            suite_seed: 42,
            run_seeds: vec![0],
            workers: 4,
            ..ExpConfig::default()
        }
    }

    #[test]
    fn trajectory_renders() {
        // Uses only one task + L3 chains; moderately fast.
        let out = trajectory_figures(&tiny_cfg());
        assert!(out.contains("KernelSkill trajectory"));
        assert!(out.contains("round"));
        assert!(out.contains("mean repair attempts"));
    }

    #[test]
    fn smoke_passes() {
        let root = std::env::temp_dir().join(format!("ks-smoke-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let out = smoke(&root).unwrap();
        assert!(out.contains("smoke ok"));
        let _ = std::fs::remove_dir_all(&root);
    }
}
