//! Micro-benchmark harness (criterion is unavailable offline): timed runs
//! with warmup, iteration control, and mean/median/p95 reporting. Used by
//! `rust/benches/*` (harness = false) and the §Perf pass.

use std::time::Instant;

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            human(self.mean_s),
            human(self.median_s),
            human(self.p95_s),
            human(self.min_s),
        )
    }
}

fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&times),
        median_s: stats::median(&times),
        p95_s: stats::percentile(&times, 95.0),
        min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

/// Time a whole-run closure once (for end-to-end experiment benches where
/// a single run is already minutes of work).
pub fn time_once<F: FnOnce() -> T, T>(name: &str, f: F) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed().as_secs_f64();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_s: dt,
            median_s: dt,
            p95_s: dt,
            min_s: dt,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 10, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s <= r.p95_s + 1e-12);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn human_units() {
        assert!(human(2.0).ends_with(" s"));
        assert!(human(2e-3).ends_with(" ms"));
        assert!(human(2e-6).ends_with(" us"));
        assert!(human(2e-9).ends_with(" ns"));
    }
}
