//! ASCII table printers matching the paper's Tables 1-3 row format.

use super::metrics::Cell;

/// One table row: method name + 3 level cells.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: String,
    pub cells: [Cell; 3],
}

/// Render Table 1 (Success + Speedup).
pub fn table1(rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<24} | {:^17} | {:^17} | {:^17}\n",
        "Method", "Level 1", "Level 2", "Level 3"
    ));
    s.push_str(&format!(
        "{:<24} | {:>8} {:>8} | {:>8} {:>8} | {:>8} {:>8}\n",
        "", "Success", "Speedup", "Success", "Speedup", "Success", "Speedup"
    ));
    s.push_str(&"-".repeat(84));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<24} | {:>8.2} {:>8.2} | {:>8.2} {:>8.2} | {:>8.2} {:>8.2}\n",
            r.method,
            r.cells[0].success,
            r.cells[0].speedup,
            r.cells[1].success,
            r.cells[1].speedup,
            r.cells[2].success,
            r.cells[2].speedup,
        ));
    }
    s
}

/// Render Table 2 (ablation: Success / Fast1 / Speedup per level).
pub fn table2(rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<24} | {:^26} | {:^26} | {:^26}\n",
        "Method", "Level 1", "Level 2", "Level 3"
    ));
    s.push_str(&format!(
        "{:<24} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8} | {:>8} {:>8} {:>8}\n",
        "", "Success", "Fast1", "Speedup", "Success", "Fast1", "Speedup", "Success", "Fast1",
        "Speedup"
    ));
    s.push_str(&"-".repeat(112));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<24} | {:>8.2} {:>8.2} {:>8.2} | {:>8.2} {:>8.2} {:>8.2} | {:>8.2} {:>8.2} {:>8.2}\n",
            r.method,
            r.cells[0].success,
            r.cells[0].fast1,
            r.cells[0].speedup,
            r.cells[1].success,
            r.cells[1].fast1,
            r.cells[1].speedup,
            r.cells[2].success,
            r.cells[2].fast1,
            r.cells[2].speedup,
        ));
    }
    s
}

/// Render Table 3 (Fast1 only).
pub fn table3(rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<24} | {:>8} | {:>8} | {:>8}\n",
        "Method", "Level 1", "Level 2", "Level 3"
    ));
    s.push_str(&"-".repeat(58));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<24} | {:>8.2} | {:>8.2} | {:>8.2}\n",
            r.method, r.cells[0].fast1, r.cells[1].fast1, r.cells[2].fast1,
        ));
    }
    s
}

/// Render the §5.4 per-round-efficiency comparison.
pub fn per_round(rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<24} | {:>10} | {:>10} | {:>10}   (mean speedup / refinement rounds)\n",
        "Method", "Level 1", "Level 2", "Level 3"
    ));
    s.push_str(&"-".repeat(70));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<24} | {:>10.3} | {:>10.3} | {:>10.3}\n",
            r.method,
            r.cells[0].speedup_per_round,
            r.cells[1].speedup_per_round,
            r.cells[2].speedup_per_round,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(name: &str) -> Row {
        let c = Cell {
            success: 1.0,
            speedup: 2.5,
            fast1: 0.8,
            speedup_per_round: 0.17,
            ..Cell::default()
        };
        Row {
            method: name.into(),
            cells: [c.clone(), c.clone(), c],
        }
    }

    #[test]
    fn tables_render_all_rows() {
        let rows = vec![row("KernelSkill"), row("STARK")];
        for render in [table1(&rows), table2(&rows), table3(&rows), per_round(&rows)] {
            assert!(render.contains("KernelSkill"));
            assert!(render.contains("STARK"));
            assert!(render.contains("Level 3"));
        }
        assert!(table1(&rows).contains("2.50"));
        assert!(table3(&rows).contains("0.80"));
    }
}
