//! Experiment harness: metrics, table rendering, per-table drivers, the
//! micro-bench harness, and cost-model calibration against real PJRT runs.

pub mod bench;
pub mod calibrate;
pub mod experiments;
pub mod metrics;
pub mod tables;
