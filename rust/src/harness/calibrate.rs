//! Cost-model calibration against real PJRT executions of the artifact
//! variants: the analytical device model must *order* schedule variants the
//! same way real numerics plumbing measures them on the schedule-structure
//! axis it models (fused < unfused in traffic; more launches = more cost).
//!
//! Absolute CPU milliseconds are NOT a GPU proxy (interpret-lowered HLO on
//! a CPU backend); what we check is internal consistency of the bridge and
//! record real latencies for EXPERIMENTS.md.

use crate::util::error::Result;

use crate::runtime::{Registry, Runtime};

#[derive(Debug, Clone)]
pub struct CalibrationRow {
    pub task: String,
    pub variant: String,
    pub real_latency_s: f64,
    pub max_abs_err: f64,
}

/// Measure every artifact variant: numeric error vs ref + real latency.
pub fn calibrate(seed: u64) -> Result<Vec<CalibrationRow>> {
    let reg = Registry::load("artifacts")?;
    let mut rt = Runtime::new("artifacts")?;
    let mut rows = Vec::new();
    let tasks: Vec<String> = reg.tasks.keys().cloned().collect();
    for task in tasks {
        let variants: Vec<String> = reg.task(&task)?.variants.keys().cloned().collect();
        for variant in variants {
            let report = crate::runtime::verify_variant(
                &mut rt, &reg, &task, &variant, seed, 1e-3, true,
            )?;
            rows.push(CalibrationRow {
                task: task.clone(),
                variant: variant.clone(),
                real_latency_s: report.latency_s.unwrap_or(0.0),
                max_abs_err: report.max_abs_err,
            });
        }
    }
    Ok(rows)
}

pub fn render(rows: &[CalibrationRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:<14} {:>14} {:>12}\n",
        "task", "variant", "latency", "max_abs_err"
    ));
    s.push_str(&"-".repeat(64));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:<14} {:>11.3} ms {:>12.2e}\n",
            r.task,
            r.variant,
            r.real_latency_s * 1e3,
            r.max_abs_err
        ));
    }
    s
}
