//! KernelSkill — a memory-augmented multi-agent framework for GPU kernel
//! optimization, reproduced as a three-layer Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) implements the paper's contribution: the multi-agent
//! closed loop (Algorithm 1), the dual-level memory (long-term expert
//! knowledge + short-term trajectory state), six baselines, the
//! KernelBenchSim task suite, and the experiment harness. Layers 1/2 (Pallas
//! kernels + JAX models under `python/`) are AOT-compiled to HLO text and
//! executed through `runtime` via PJRT — Python never runs at request time.

pub mod agents;
pub mod baselines;
pub mod bench_suite;
pub mod coordinator;
pub mod device;
pub mod harness;
pub mod kir;
pub mod memory;
pub mod runtime;
pub mod util;
