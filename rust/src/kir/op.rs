//! Operator taxonomy for KernelBenchSim tasks.
//!
//! Each [`Op`] carries enough shape information for the cost model to compute
//! FLOPs and ideal memory traffic, and for the legality checker / decision
//! table to reason about fusion and schedule preconditions. The taxonomy
//! mirrors the operator families KernelBench draws from (GEMM, conv,
//! reductions, normalizations, elementwise chains, data movement, attention
//! sub-ops).

/// Elementwise operator flavor (cost-equivalent; kept for trace readability).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    Add,
    Mul,
    Scale,
    Clamp,
    Relu,
    Gelu,
    Mish,
    Sigmoid,
    Tanh,
    Bias,
    Residual,
}

/// Reduction pattern — determines fusion legality and schedule choices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RedKind {
    Row,       // e.g. logsumexp(dim=1), row-sum
    Col,       // cross-row; transposed access risk
    Full,      // scalar output
    ArgMinMax, // index-producing
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NormKind {
    Softmax,
    LayerNorm,
    RmsNorm,
    BatchNorm,
    GroupNorm,
}

/// Operator kind. Shape fields use the GEMM (m, n, k) convention; non-GEMM
/// ops use (rows=m, cols=n) with k = 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matmul (m, k) x (k, n). Convs are represented as implicit GEMM
    /// (im2col dims), matching how both cuDNN and MXU pipelines lower them.
    MatMul,
    Conv,
    Elementwise(EwKind),
    Reduction(RedKind),
    Norm(NormKind),
    Transpose,
    Gather,
    Scatter,
    Pool,
    Scan,
    Embedding,
}

pub type OpId = usize;

/// One operator node in a task graph.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub kind: OpKind,
    /// GEMM convention: (m, k) x (k, n); elementwise/reductions use m x n.
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Graph predecessors (data dependencies).
    pub inputs: Vec<OpId>,
    /// Element size in bytes of the op's working dtype (4 = f32).
    pub dtype_bytes: u64,
}

impl Op {
    pub fn new(id: OpId, kind: OpKind, m: u64, n: u64, k: u64, inputs: Vec<OpId>) -> Op {
        Op {
            id,
            kind,
            m,
            n,
            k,
            inputs,
            dtype_bytes: 4,
        }
    }

    /// Floating-point operations performed by this op.
    pub fn flops(&self) -> f64 {
        let (m, n, k) = (self.m as f64, self.n as f64, self.k as f64);
        match self.kind {
            OpKind::MatMul | OpKind::Conv => 2.0 * m * n * k,
            OpKind::Elementwise(_) => m * n,
            // max+exp+sum+div style multi-pass arithmetic.
            OpKind::Reduction(_) => 2.0 * m * n,
            OpKind::Norm(_) => 6.0 * m * n,
            OpKind::Transpose | OpKind::Gather | OpKind::Scatter | OpKind::Embedding => 0.0,
            OpKind::Pool => m * n,
            OpKind::Scan => 2.0 * m * n,
        }
    }

    /// Ideal (perfect-reuse) HBM traffic in bytes: each operand read once,
    /// output written once.
    pub fn ideal_bytes(&self) -> f64 {
        let b = self.dtype_bytes as f64;
        let (m, n, k) = (self.m as f64, self.n as f64, self.k as f64);
        match self.kind {
            OpKind::MatMul | OpKind::Conv => b * (m * k + k * n + m * n),
            OpKind::Elementwise(_) => b * 2.0 * m * n,
            OpKind::Reduction(RedKind::Full) => b * (m * n + 1.0),
            OpKind::Reduction(_) => b * (m * n + m.max(n)),
            OpKind::Norm(_) => b * 2.0 * m * n,
            OpKind::Transpose => b * 2.0 * m * n,
            OpKind::Gather | OpKind::Scatter | OpKind::Embedding => b * 2.0 * m * n,
            OpKind::Pool => b * (m * n + m * n / 4.0),
            OpKind::Scan => b * 2.0 * m * n,
        }
    }

    /// Output tensor size in bytes (what a downstream unfused kernel re-reads).
    pub fn output_bytes(&self) -> f64 {
        let b = self.dtype_bytes as f64;
        let (m, n) = (self.m as f64, self.n as f64);
        match self.kind {
            OpKind::Reduction(RedKind::Full) => b,
            OpKind::Reduction(RedKind::Row) => b * m,
            OpKind::Reduction(RedKind::Col) => b * n,
            OpKind::Reduction(RedKind::ArgMinMax) => b * m,
            OpKind::Pool => b * m * n / 4.0,
            _ => b * m * n,
        }
    }

    /// Is this op a dense-contraction (GEMM-shaped) op?
    pub fn is_gemm_like(&self) -> bool {
        matches!(self.kind, OpKind::MatMul | OpKind::Conv)
    }

    /// Is this op memory-movement-only (no arithmetic intensity)?
    pub fn is_data_movement(&self) -> bool {
        matches!(
            self.kind,
            OpKind::Transpose | OpKind::Gather | OpKind::Scatter | OpKind::Embedding
        )
    }

    /// Arithmetic intensity (flops per ideal byte).
    pub fn intensity(&self) -> f64 {
        let b = self.ideal_bytes();
        if b == 0.0 {
            0.0
        } else {
            self.flops() / b
        }
    }

    /// Short label for traces/tables.
    pub fn label(&self) -> String {
        match self.kind {
            OpKind::MatMul => format!("matmul[{}x{}x{}]", self.m, self.n, self.k),
            OpKind::Conv => format!("conv[{}x{}x{}]", self.m, self.n, self.k),
            OpKind::Elementwise(e) => format!("ew:{e:?}[{}x{}]", self.m, self.n),
            OpKind::Reduction(r) => format!("red:{r:?}[{}x{}]", self.m, self.n),
            OpKind::Norm(nk) => format!("norm:{nk:?}[{}x{}]", self.m, self.n),
            k => format!("{k:?}[{}x{}]", self.m, self.n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_and_bytes() {
        let op = Op::new(0, OpKind::MatMul, 256, 512, 512, vec![]);
        assert_eq!(op.flops(), 2.0 * 256.0 * 512.0 * 512.0);
        assert_eq!(
            op.ideal_bytes(),
            4.0 * (256.0 * 512.0 + 512.0 * 512.0 + 256.0 * 512.0)
        );
        assert!(op.is_gemm_like());
        assert!(op.intensity() > 50.0);
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let op = Op::new(0, OpKind::Elementwise(EwKind::Relu), 1024, 1024, 1, vec![]);
        assert!(op.intensity() < 1.0);
        assert!(!op.is_gemm_like());
    }

    #[test]
    fn row_reduction_output_is_column() {
        let op = Op::new(0, OpKind::Reduction(RedKind::Row), 256, 512, 1, vec![]);
        assert_eq!(op.output_bytes(), 4.0 * 256.0);
    }

    #[test]
    fn transpose_has_zero_flops() {
        let op = Op::new(0, OpKind::Transpose, 128, 128, 1, vec![]);
        assert_eq!(op.flops(), 0.0);
        assert!(op.is_data_movement());
    }
}
