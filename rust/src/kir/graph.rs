//! Task graph: a DAG of [`Op`]s — what a KernelBench PyTorch reference
//! program looks like after operator capture.

use super::op::{Op, OpId, OpKind, RedKind};

#[derive(Debug, Clone, Default)]
pub struct KernelGraph {
    pub ops: Vec<Op>,
    /// Task-level annotation: an operand has exploitable structure
    /// (diagonal/triangular/banded/symmetric) that the eager reference
    /// densifies. Unlocks the SpecializeStructure method.
    pub structured_operands: bool,
    /// Consumer adjacency, maintained by `push` (perf: the cost model and
    /// feature extraction walk consumers on every review — §Perf opt 1).
    consumer_lists: Vec<Vec<OpId>>,
}

impl KernelGraph {
    pub fn new() -> Self {
        KernelGraph::default()
    }

    /// Append an op whose inputs are earlier op ids; returns its id.
    pub fn push(&mut self, kind: OpKind, m: u64, n: u64, k: u64, inputs: Vec<OpId>) -> OpId {
        let id = self.ops.len();
        for &i in &inputs {
            assert!(i < id, "input {i} must precede op {id}");
            self.consumer_lists[i].push(id);
        }
        self.consumer_lists.push(Vec::new());
        self.ops.push(Op::new(id, kind, m, n, k, inputs));
        id
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id]
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Direct consumers of `id` (O(1): maintained by `push`).
    pub fn consumers(&self, id: OpId) -> &[OpId] {
        &self.consumer_lists[id]
    }

    /// Number of consumers of `id` (O(1)).
    pub fn consumer_count(&self, id: OpId) -> usize {
        self.consumer_lists[id].len()
    }

    /// Total FLOPs across the graph.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops()).sum()
    }

    /// Ideal traffic if the whole graph were one perfectly-fused kernel:
    /// external inputs read once + final outputs written once. Intermediate
    /// tensors never touch HBM. This is the fusion roofline.
    pub fn fused_ideal_bytes(&self) -> f64 {
        let mut total = 0.0;
        for op in &self.ops {
            // Bytes for operands that are *external* (not produced in-graph):
            // approximate as ideal_bytes minus the output write minus re-read
            // of in-graph producers' outputs.
            let in_graph_input_bytes: f64 = op
                .inputs
                .iter()
                .map(|&i| self.ops[i].output_bytes())
                .sum();
            let external = (op.ideal_bytes() - op.output_bytes() - in_graph_input_bytes).max(0.0);
            total += external;
        }
        // Final outputs: ops with no consumers.
        for op in &self.ops {
            if self.consumers(op.id).is_empty() {
                total += op.output_bytes();
            }
        }
        total
    }

    /// The op with the largest FLOP share (the "dominant bottleneck" the
    /// paper's motivating example is about), if any.
    pub fn dominant_op(&self) -> Option<&Op> {
        self.ops
            .iter()
            .max_by(|a, b| a.flops().partial_cmp(&b.flops()).unwrap())
    }

    /// FLOP fraction of the dominant op (1.0 for single-op graphs).
    pub fn dominant_flop_fraction(&self) -> f64 {
        let total = self.total_flops();
        if total == 0.0 {
            return 0.0;
        }
        self.dominant_op().map(|o| o.flops() / total).unwrap_or(0.0)
    }

    pub fn gemm_ops(&self) -> Vec<OpId> {
        self.ops
            .iter()
            .filter(|o| o.is_gemm_like())
            .map(|o| o.id)
            .collect()
    }

    pub fn has_row_reduction(&self) -> bool {
        self.ops.iter().any(|o| {
            matches!(
                o.kind,
                OpKind::Reduction(RedKind::Row) | OpKind::Norm(_)
            )
        })
    }

    /// Validate DAG invariants (used by proptest).
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            if op.id != i {
                return Err(format!("op {i} has id {}", op.id));
            }
            for &inp in &op.inputs {
                if inp >= i {
                    return Err(format!("op {i} depends on later op {inp}"));
                }
            }
            if op.m == 0 || op.n == 0 || op.k == 0 {
                return Err(format!("op {i} has zero dim"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::EwKind;

    fn epilogue_graph() -> KernelGraph {
        // The Appendix-D chain: matmul -> scale -> residual -> clamp ->
        // row-logsumexp -> mish.
        let mut g = KernelGraph::new();
        let mm = g.push(OpKind::MatMul, 256, 512, 512, vec![]);
        let sc = g.push(OpKind::Elementwise(EwKind::Scale), 256, 512, 1, vec![mm]);
        let rs = g.push(OpKind::Elementwise(EwKind::Residual), 256, 512, 1, vec![sc]);
        let cl = g.push(OpKind::Elementwise(EwKind::Clamp), 256, 512, 1, vec![rs]);
        let red = g.push(OpKind::Reduction(RedKind::Row), 256, 512, 1, vec![cl]);
        let _ = g.push(OpKind::Elementwise(EwKind::Mish), 256, 1, 1, vec![red]);
        g
    }

    #[test]
    fn dag_validates() {
        assert!(epilogue_graph().validate().is_ok());
    }

    #[test]
    fn dominant_op_is_the_gemm() {
        let g = epilogue_graph();
        assert!(g.dominant_op().unwrap().is_gemm_like());
        assert!(g.dominant_flop_fraction() > 0.98);
    }

    #[test]
    fn consumers_follow_edges() {
        let g = epilogue_graph();
        assert_eq!(g.consumers(0), &[1]);
        assert!(g.consumers(5).is_empty());
    }

    #[test]
    fn fused_ideal_less_than_unfused_sum() {
        let g = epilogue_graph();
        let unfused: f64 = g.ops.iter().map(|o| o.ideal_bytes()).sum();
        assert!(g.fused_ideal_bytes() < unfused);
    }

    #[test]
    #[should_panic]
    fn forward_edge_panics() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 8, 8, 8, vec![3]);
    }
}
