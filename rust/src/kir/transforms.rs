//! The optimization-method vocabulary: every `allowed_methods` entry in the
//! long-term memory's decision table is one of these IR rewrites.
//!
//! Each method has (a) an applicability precondition over the structured
//! kernel — the same preconditions the paper encodes as `gate_when`
//! predicates and code-feature gates, (b) a deterministic `apply` that edits
//! the schedule, and (c) a complexity class that drives the fault model
//! (riskier edits are more likely to produce buggy kernels when executed by
//! the LLM-surrogate Optimizer).

use super::graph::KernelGraph;
use super::op::OpKind;
use super::schedule::{GroupSchedule, Layout, Precision, Schedule};

/// Edit-complexity class: scales the surrogate's bug probability.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Complexity {
    Low,
    Medium,
    High,
}

/// Every optimization method the system can select. This is the shared
/// vocabulary between the decision table, the Planner, and the Optimizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MethodId {
    /// Shared-memory / VMEM tiling of a GEMM group (+K blocking, staging).
    TileSmem,
    /// Enable the tensor-core / MXU math path (implies TF32/BF16 accum).
    UseTensorCore,
    /// Widen global loads to vector width 4.
    VectorizeLoads,
    /// Fix strided access: reorder indexing to coalesced layout.
    CoalesceAccesses,
    /// Swizzle staged operands into a tiled scratch layout.
    TiledLayout,
    /// Fuse an elementwise consumer into its producer's kernel.
    FuseElementwise,
    /// Fuse a row-reduction/normalization epilogue (and its elementwise
    /// tail) into the producer kernel — the coupled multi-step edit.
    FuseEpilogueReduction,
    /// Merge independent small kernels to cut launch overhead.
    HorizontalFuse,
    /// Double-buffer the HBM<->scratch pipeline (cp.async analog).
    DoubleBuffer,
    /// Unroll the inner loop (factor 4).
    UnrollInner,
    /// Pad scratchpad rows to kill bank conflicts.
    PadScratch,
    /// Shrink tiles/registers to raise occupancy.
    IncreaseOccupancy,
    /// Split the K dimension across blocks (small-M GEMMs).
    SplitK,
    /// Downcast the math path to TF32 (keeps f32 accumulate).
    PrecisionDowncast,
    /// Retune block thread count.
    LaunchTune,
    /// Split an op back out of an over-fused group.
    KernelFission,
    /// Recompute cheap values instead of spilling registers.
    RecomputeCheap,
    /// Warp-shuffle (lane-reduce) the reduction tree.
    WarpReduceShuffle,
    /// Software prefetch for memory-bound non-GEMM groups.
    AsyncPrefetch,
    /// L2/cache blocking for large memory-bound ops.
    CacheBlocking,
    /// Exploit operand structure (diagonal/triangular/banded): skip the
    /// dense work the eager reference materializes. The heavy-tailed
    /// Level-1 wins live behind this method.
    SpecializeStructure,
}

pub const ALL_METHODS: [MethodId; 21] = [
    MethodId::SpecializeStructure,
    MethodId::TileSmem,
    MethodId::UseTensorCore,
    MethodId::VectorizeLoads,
    MethodId::CoalesceAccesses,
    MethodId::TiledLayout,
    MethodId::FuseElementwise,
    MethodId::FuseEpilogueReduction,
    MethodId::HorizontalFuse,
    MethodId::DoubleBuffer,
    MethodId::UnrollInner,
    MethodId::PadScratch,
    MethodId::IncreaseOccupancy,
    MethodId::SplitK,
    MethodId::PrecisionDowncast,
    MethodId::LaunchTune,
    MethodId::KernelFission,
    MethodId::RecomputeCheap,
    MethodId::WarpReduceShuffle,
    MethodId::AsyncPrefetch,
    MethodId::CacheBlocking,
];

impl MethodId {
    pub fn name(&self) -> &'static str {
        match self {
            MethodId::TileSmem => "tile_smem",
            MethodId::UseTensorCore => "use_tensor_core",
            MethodId::VectorizeLoads => "vectorize_loads",
            MethodId::CoalesceAccesses => "coalesce_accesses",
            MethodId::TiledLayout => "tiled_layout",
            MethodId::FuseElementwise => "fuse_elementwise",
            MethodId::FuseEpilogueReduction => "fuse_epilogue_reduction",
            MethodId::HorizontalFuse => "horizontal_fuse",
            MethodId::DoubleBuffer => "double_buffer",
            MethodId::UnrollInner => "unroll_inner",
            MethodId::PadScratch => "pad_scratch",
            MethodId::IncreaseOccupancy => "increase_occupancy",
            MethodId::SplitK => "split_k",
            MethodId::PrecisionDowncast => "precision_downcast",
            MethodId::LaunchTune => "launch_tune",
            MethodId::KernelFission => "kernel_fission",
            MethodId::RecomputeCheap => "recompute_cheap",
            MethodId::WarpReduceShuffle => "warp_reduce_shuffle",
            MethodId::AsyncPrefetch => "async_prefetch",
            MethodId::CacheBlocking => "cache_blocking",
            MethodId::SpecializeStructure => "specialize_structure",
        }
    }

    pub fn from_name(name: &str) -> Option<MethodId> {
        ALL_METHODS.iter().copied().find(|m| m.name() == name)
    }

    pub fn complexity(&self) -> Complexity {
        match self {
            MethodId::VectorizeLoads
            | MethodId::UnrollInner
            | MethodId::PadScratch
            | MethodId::LaunchTune
            | MethodId::PrecisionDowncast
            | MethodId::IncreaseOccupancy => Complexity::Low,
            MethodId::CoalesceAccesses
            | MethodId::DoubleBuffer
            | MethodId::FuseElementwise
            | MethodId::HorizontalFuse
            | MethodId::KernelFission
            | MethodId::RecomputeCheap
            | MethodId::AsyncPrefetch
            | MethodId::CacheBlocking
            | MethodId::UseTensorCore => Complexity::Medium,
            MethodId::TileSmem
            | MethodId::TiledLayout
            | MethodId::FuseEpilogueReduction
            | MethodId::SplitK
            | MethodId::SpecializeStructure
            | MethodId::WarpReduceShuffle => Complexity::High,
        }
    }
}

/// Where a method wants to act.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetGroup {
    /// The group containing the dominant (largest-FLOP) op.
    Dominant,
    /// A group whose schedule/ops satisfy the method's shape (first match).
    FirstEligible,
}

/// Why a method is not applicable right now (also used as gate explanations
/// in retrieval audit trails).
pub type Inapplicable = &'static str;

/// Group containing the dominant (largest-FLOP) op — the default focus.
pub fn dominant_group(graph: &KernelGraph, sched: &Schedule) -> usize {
    let dom = graph.dominant_op().map(|o| o.id).unwrap_or(0);
    sched.group_of(dom).unwrap_or(0)
}

fn group_has_gemm(graph: &KernelGraph, sched: &Schedule, g: usize) -> bool {
    sched.groups[g].iter().any(|&o| graph.op(o).is_gemm_like())
}

/// The GEMM-shaped op in group `g`, if any.
fn group_gemm<'a>(
    graph: &'a KernelGraph,
    sched: &Schedule,
    g: usize,
) -> Option<&'a crate::kir::op::Op> {
    sched.groups[g]
        .iter()
        .map(|&o| graph.op(o))
        .find(|o| o.is_gemm_like())
}

/// The largest-FLOP op in group `g` (tile-size reference).
fn group_biggest<'a>(graph: &'a KernelGraph, sched: &Schedule, g: usize) -> &'a crate::kir::op::Op {
    sched.groups[g]
        .iter()
        .map(|&o| graph.op(o))
        .max_by(|a, b| a.flops().partial_cmp(&b.flops()).unwrap())
        .unwrap()
}

/// One-pass op-id -> group-index map (`usize::MAX` = not scheduled). The
/// fusion-edge scans below run per round in the inner loop; with the map
/// they cost O(ops) instead of one O(groups x group-size) `group_of` walk
/// per operand.
fn op_group_map(graph: &KernelGraph, sched: &Schedule) -> Vec<usize> {
    let mut map = vec![usize::MAX; graph.len()];
    for (g, group) in sched.groups.iter().enumerate() {
        for &o in group {
            if let Some(slot) = map.get_mut(o) {
                *slot = g;
            }
        }
    }
    map
}

/// Find (producer_group, consumer_group) for an elementwise fusion edge.
fn ew_fusion_edge(graph: &KernelGraph, sched: &Schedule) -> Option<(usize, usize)> {
    let groups = op_group_map(graph, sched);
    let lookup = |id: usize| groups.get(id).copied().filter(|&g| g != usize::MAX);
    for op in &graph.ops {
        if !matches!(op.kind, OpKind::Elementwise(_)) {
            continue;
        }
        for &inp in &op.inputs {
            let (gp, gc) = (lookup(inp)?, lookup(op.id)?);
            if gp != gc {
                return Some((gp, gc));
            }
        }
    }
    None
}

/// Find a reduction/norm consumer split from its producer group.
fn reduction_fusion_edge(graph: &KernelGraph, sched: &Schedule) -> Option<(usize, usize)> {
    let groups = op_group_map(graph, sched);
    let lookup = |id: usize| groups.get(id).copied().filter(|&g| g != usize::MAX);
    for op in &graph.ops {
        if !matches!(op.kind, OpKind::Reduction(_) | OpKind::Norm(_)) {
            continue;
        }
        for &inp in &op.inputs {
            let (gp, gc) = (lookup(inp)?, lookup(op.id)?);
            if gp != gc {
                return Some((gp, gc));
            }
        }
    }
    None
}

/// Check whether `method` can be applied with the dominant group as focus.
pub fn applicable(
    method: MethodId,
    graph: &KernelGraph,
    sched: &Schedule,
) -> Result<(), Inapplicable> {
    applicable_at(method, graph, sched, dominant_group(graph, sched))
}

/// Check whether `method` can be applied focusing group `dg` (the
/// profiler's hot kernel). Per-group knob methods are considered applicable
/// when the focus group — or, failing that, any group — satisfies the local
/// precondition (the Optimizer's whole-program rewrite reaches them all).
pub fn applicable_at(
    method: MethodId,
    graph: &KernelGraph,
    sched: &Schedule,
    dg: usize,
) -> Result<(), Inapplicable> {
    let dg = dg.min(sched.num_kernels() - 1);
    match method {
        // Graph-structure methods have global preconditions.
        MethodId::CoalesceAccesses => {
            if sched.cfg.iter().any(|c| matches!(c.layout, Layout::Strided)) {
                Ok(())
            } else {
                Err("already coalesced")
            }
        }
        MethodId::FuseElementwise => ew_fusion_edge(graph, sched)
            .map(|_| ())
            .ok_or("no elementwise fusion edge"),
        MethodId::FuseEpilogueReduction => reduction_fusion_edge(graph, sched)
            .map(|_| ())
            .ok_or("no reduction epilogue to fuse"),
        MethodId::HorizontalFuse => {
            if sched.num_kernels() < 4 {
                Err("too few kernels to batch")
            } else {
                Ok(())
            }
        }
        MethodId::KernelFission => {
            if sched.groups.iter().all(|g| g.len() <= 1) {
                Err("nothing fused to split")
            } else {
                Ok(())
            }
        }
        MethodId::SpecializeStructure => {
            if !graph.structured_operands {
                Err("no exploitable operand structure")
            } else if sched.specialized {
                Err("already specialized")
            } else {
                Ok(())
            }
        }
        MethodId::RecomputeCheap => {
            let f = super::features::ground_truth_at(graph, sched, dg);
            if f.register_pressure < 2 {
                Err("no spill pressure to trade")
            } else {
                Ok(())
            }
        }
        // Per-group knob methods: focus group first, any group as fallback.
        _ => {
            if group_eligible(method, graph, sched, dg).is_ok() {
                return Ok(());
            }
            let any = (0..sched.num_kernels())
                .any(|g| group_eligible(method, graph, sched, g).is_ok());
            if any {
                Ok(())
            } else {
                group_eligible(method, graph, sched, dg)
            }
        }
    }
}

/// Local (per-group) precondition for the knob methods.
fn group_eligible(
    method: MethodId,
    graph: &KernelGraph,
    sched: &Schedule,
    g: usize,
) -> Result<(), Inapplicable> {
    let cfg = &sched.cfg[g];
    match method {
        MethodId::TileSmem => {
            if !group_has_gemm(graph, sched, g) {
                return Err("no GEMM in group");
            }
            if cfg.staging && cfg.tile_k > 0 {
                return Err("already tiled");
            }
            Ok(())
        }
        MethodId::UseTensorCore => {
            if !group_has_gemm(graph, sched, g) {
                return Err("no GEMM to run on MXU");
            }
            if cfg.mxu {
                return Err("already on tensor core path");
            }
            if !cfg.staging {
                return Err("tensor core requires staged operands");
            }
            let op = group_gemm(graph, sched, g).unwrap();
            if op.m % 8 != 0 || op.n % 8 != 0 || op.k % 8 != 0 {
                return Err("dims not multiple of 8");
            }
            Ok(())
        }
        MethodId::VectorizeLoads => {
            if cfg.vector_width >= 4 {
                return Err("already vectorized");
            }
            if matches!(cfg.layout, Layout::Strided) {
                return Err("strided access cannot vectorize");
            }
            Ok(())
        }
        MethodId::TiledLayout => {
            if !cfg.staging {
                return Err("tiled layout needs staging");
            }
            if matches!(cfg.layout, Layout::Tiled) {
                return Err("already tiled layout");
            }
            Ok(())
        }
        MethodId::DoubleBuffer => {
            if !cfg.staging {
                return Err("double buffering needs staging");
            }
            if cfg.double_buffer {
                return Err("already double buffered");
            }
            Ok(())
        }
        MethodId::UnrollInner => {
            if cfg.unroll > 1 {
                Err("already unrolled")
            } else {
                Ok(())
            }
        }
        MethodId::PadScratch => {
            if !cfg.staging {
                return Err("no scratch to pad");
            }
            if cfg.smem_padding {
                return Err("already padded");
            }
            Ok(())
        }
        MethodId::IncreaseOccupancy => {
            if cfg.tile_m <= 32 && cfg.tile_n <= 32 {
                Err("tiles already small")
            } else {
                Ok(())
            }
        }
        MethodId::SplitK => {
            if !group_has_gemm(graph, sched, g) {
                return Err("split-K needs a GEMM");
            }
            let op = group_gemm(graph, sched, g).unwrap();
            if op.k < 4 * op.m.max(op.n) {
                return Err("K not dominant enough for split-K");
            }
            if cfg.split_k > 1 {
                return Err("already split");
            }
            Ok(())
        }
        MethodId::PrecisionDowncast => {
            if matches!(cfg.precision, Precision::F32) {
                Ok(())
            } else {
                Err("already downcast")
            }
        }
        MethodId::LaunchTune => Ok(()),
        MethodId::WarpReduceShuffle => {
            let has_red = sched.groups[g].iter().any(|&o| {
                matches!(graph.op(o).kind, OpKind::Reduction(_) | OpKind::Norm(_))
            });
            if !has_red {
                return Err("no reduction in group");
            }
            if cfg.vector_width >= 4 && cfg.unroll > 1 {
                return Err("reduction already optimized");
            }
            Ok(())
        }
        MethodId::AsyncPrefetch => {
            if cfg.double_buffer {
                return Err("pipeline already hidden");
            }
            if group_has_gemm(graph, sched, g) && cfg.staging {
                return Err("use double_buffer on staged GEMM instead");
            }
            Ok(())
        }
        MethodId::CacheBlocking => {
            if group_has_gemm(graph, sched, g) {
                return Err("use tile_smem for GEMM groups");
            }
            if cfg.tile_m >= 64 && cfg.tile_n >= 128 {
                return Err("already cache blocked");
            }
            Ok(())
        }
        // Graph-structure methods are handled in applicable_at.
        _ => Err("not a per-group knob"),
    }
}

/// Apply `method` with the dominant group as focus.
pub fn apply(method: MethodId, graph: &KernelGraph, sched: &mut Schedule) {
    apply_at(method, graph, sched, dominant_group(graph, sched))
}

/// Apply `method` across the whole program (the Optimizer rewrites every
/// kernel the plan's cue touches), with `dg` as the profiler's focus group.
/// Always produces a *structurally* valid schedule; device legality is
/// checked separately.
pub fn apply_at(method: MethodId, graph: &KernelGraph, sched: &mut Schedule, dg: usize) {
    let dg = dg.min(sched.num_kernels() - 1);
    match method {
        // ---- graph-structure edits ----
        MethodId::CoalesceAccesses => {
            for c in &mut sched.cfg {
                if matches!(c.layout, Layout::Strided) {
                    c.layout = Layout::Coalesced;
                }
            }
        }
        MethodId::FuseElementwise => {
            // Inline every elementwise consumer into its producer kernel.
            while let Some((gp, gc)) = ew_fusion_edge(graph, sched) {
                sched.merge_groups(gp, gc);
            }
        }
        MethodId::FuseEpilogueReduction => {
            // Fuse every reduction epilogue, then its elementwise tails —
            // the coupled multi-step edit.
            while let Some((gp, gc)) = reduction_fusion_edge(graph, sched) {
                sched.merge_groups(gp, gc);
            }
            while let Some((gp, gc)) = ew_fusion_edge(graph, sched) {
                sched.merge_groups(gp, gc);
            }
        }
        MethodId::HorizontalFuse => {
            // Batch tiny kernels together until few remain.
            loop {
                if sched.num_kernels() < 3 {
                    break;
                }
                let mut idx: Vec<usize> = (0..sched.num_kernels()).collect();
                idx.sort_by_key(|&i| {
                    sched.groups[i]
                        .iter()
                        .map(|&o| graph.op(o).flops() as u64)
                        .sum::<u64>()
                });
                let small = |i: usize| {
                    sched.groups[i]
                        .iter()
                        .map(|&o| graph.op(o).flops())
                        .sum::<f64>()
                        < 1e7
                };
                if small(idx[0]) && small(idx[1]) {
                    sched.merge_groups(idx[0], idx[1]);
                } else {
                    break;
                }
            }
        }
        MethodId::KernelFission => {
            if let Some(g) = (0..sched.num_kernels()).max_by_key(|&i| sched.groups[i].len()) {
                if sched.groups[g].len() > 1 {
                    let op = *sched.groups[g].last().unwrap();
                    sched.split_op(op);
                }
            }
        }
        MethodId::SpecializeStructure => {
            sched.specialized = true;
        }
        MethodId::RecomputeCheap => {
            let c = &mut sched.cfg[dg];
            if c.unroll > 1 {
                c.unroll = 2;
            }
        }
        MethodId::SplitK => {
            // Targeted: only the focus group's GEMM gets split.
            if group_eligible(MethodId::SplitK, graph, sched, dg).is_ok() {
                sched.cfg[dg].split_k = 4;
            } else if let Some(g) = (0..sched.num_kernels())
                .find(|&g| group_eligible(MethodId::SplitK, graph, sched, g).is_ok())
            {
                sched.cfg[g].split_k = 4;
            }
        }
        // ---- per-group knobs: rewrite every eligible group ----
        _ => {
            for g in 0..sched.num_kernels() {
                if group_eligible(method, graph, sched, g).is_err() {
                    continue;
                }
                apply_knob(method, graph, sched, g);
            }
        }
    }
}

/// Apply one knob method to one eligible group.
fn apply_knob(method: MethodId, graph: &KernelGraph, sched: &mut Schedule, g: usize) {
    match method {
        MethodId::TileSmem => {
            let (m, n) = {
                let op = group_biggest(graph, sched, g);
                (op.m, op.n)
            };
            let (tm, tn) = gemm_tiles(m, n);
            let c = &mut sched.cfg[g];
            c.tile_m = tm;
            c.tile_n = tn;
            c.tile_k = 32;
            c.staging = true;
            c.layout = Layout::Coalesced;
        }
        MethodId::UseTensorCore => {
            let c = &mut sched.cfg[g];
            c.mxu = true;
            if matches!(c.precision, Precision::F32) {
                c.precision = Precision::Tf32;
            }
        }
        MethodId::VectorizeLoads => sched.cfg[g].vector_width = 4,
        MethodId::TiledLayout => sched.cfg[g].layout = Layout::Tiled,
        MethodId::DoubleBuffer => sched.cfg[g].double_buffer = true,
        MethodId::UnrollInner => sched.cfg[g].unroll = 4,
        MethodId::PadScratch => sched.cfg[g].smem_padding = true,
        MethodId::IncreaseOccupancy => {
            let c = &mut sched.cfg[g];
            c.tile_m = (c.tile_m / 2).max(16);
            c.tile_n = (c.tile_n / 2).max(16);
            if c.unroll > 2 {
                c.unroll = 2;
            }
        }
        MethodId::PrecisionDowncast => sched.cfg[g].precision = Precision::Tf32,
        MethodId::LaunchTune => {
            let c = &mut sched.cfg[g];
            c.block_threads = if c.block_threads >= 256 { 128 } else { 256 };
        }
        MethodId::WarpReduceShuffle => {
            let c = &mut sched.cfg[g];
            c.vector_width = 4;
            c.unroll = 4;
            if matches!(c.layout, Layout::Strided) {
                c.layout = Layout::Coalesced;
            }
        }
        MethodId::AsyncPrefetch => {
            let c = &mut sched.cfg[g];
            c.staging = true;
            c.double_buffer = true;
        }
        MethodId::CacheBlocking => {
            let (m, n) = {
                let op = group_biggest(graph, sched, g);
                (op.m, op.n)
            };
            let c = &mut sched.cfg[g];
            c.tile_m = pick_tile(m, 64);
            c.tile_n = pick_tile(n, 256);
        }
        _ => unreachable!("not a knob method: {method:?}"),
    }
}

/// Parallelism-aware GEMM tile choice (what a library autotuner does):
/// prefer 128x128 tiles, shrink until the grid has enough blocks to fill
/// the device (~128 blocks), floor at 32.
pub fn gemm_tiles(m: u64, n: u64) -> (u64, u64) {
    let mut tm = pick_tile(m, 128);
    let mut tn = pick_tile(n, 128);
    let blocks = |tm: u64, tn: u64| {
        ((m + tm - 1) / tm) * ((n + tn - 1) / tn)
    };
    while blocks(tm, tn) < 128 && (tm > 32 || tn > 32) {
        if tm >= tn && tm > 32 {
            tm /= 2;
        } else if tn > 32 {
            tn /= 2;
        } else {
            break;
        }
    }
    (tm.max(16), tn.max(16))
}


/// Companion knobs a *competent implementation* of a method includes "for
/// free" (the llm_assist cues: a well-written tiled GEMM arrives vectorized
/// and padded, a tensor-core rewrite unrolls its fragment loop, ...). The
/// Optimizer applies these alongside the primary method — which is what
/// makes per-round gains chunky enough to clear the rt/at promotion
/// thresholds, as in the paper's whole-kernel rewrites.
pub fn companions(method: MethodId) -> &'static [MethodId] {
    match method {
        MethodId::TileSmem => &[MethodId::VectorizeLoads, MethodId::PadScratch],
        MethodId::UseTensorCore => &[MethodId::UnrollInner],
        MethodId::CoalesceAccesses => &[MethodId::VectorizeLoads],
        MethodId::FuseEpilogueReduction => &[MethodId::WarpReduceShuffle],
        MethodId::AsyncPrefetch => &[MethodId::VectorizeLoads],
        MethodId::CacheBlocking => &[MethodId::VectorizeLoads],
        _ => &[],
    }
}

/// Tile size for a dimension: the preferred tile, shrunk only when the
/// whole dimension is smaller. Ragged tails are handled by predicated
/// ceil-div grids (as real libraries do), so the tile need not divide dim.
fn pick_tile(dim: u64, pref: u64) -> u64 {
    if dim >= pref {
        pref
    } else {
        // Round the (small) dimension up to an 8-aligned tile.
        ((dim + 7) / 8 * 8).max(8)
    }
}

/// Reference naive-to-library distance: how many of the headline GEMM knobs
/// are still unset (used in tests and trace summaries).
pub fn gemm_knobs_remaining(cfg: &GroupSchedule) -> u32 {
    let mut n = 0;
    if !cfg.staging || cfg.tile_k == 0 {
        n += 1;
    }
    if !cfg.mxu {
        n += 1;
    }
    if cfg.vector_width < 4 {
        n += 1;
    }
    if !cfg.double_buffer {
        n += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::{EwKind, RedKind};

    fn epilogue() -> (KernelGraph, Schedule) {
        let mut g = KernelGraph::new();
        let mm = g.push(OpKind::MatMul, 256, 512, 512, vec![]);
        let sc = g.push(OpKind::Elementwise(EwKind::Scale), 256, 512, 1, vec![mm]);
        let cl = g.push(OpKind::Elementwise(EwKind::Clamp), 256, 512, 1, vec![sc]);
        let rd = g.push(OpKind::Reduction(RedKind::Row), 256, 512, 1, vec![cl]);
        let _ = g.push(OpKind::Elementwise(EwKind::Mish), 256, 1, 1, vec![rd]);
        let s = Schedule::per_op_naive(&g);
        (g, s)
    }

    #[test]
    fn tile_smem_applies_once() {
        let (g, mut s) = epilogue();
        assert!(applicable(MethodId::TileSmem, &g, &s).is_ok());
        apply(MethodId::TileSmem, &g, &mut s);
        assert!(s.cfg[0].staging);
        assert!(s.cfg[0].tile_k > 0);
        assert!(applicable(MethodId::TileSmem, &g, &s).is_err());
        assert!(s.validate(&g).is_ok());
    }

    #[test]
    fn tensor_core_gated_on_staging() {
        let (g, mut s) = epilogue();
        assert!(applicable(MethodId::UseTensorCore, &g, &s).is_err());
        apply(MethodId::TileSmem, &g, &mut s);
        assert!(applicable(MethodId::UseTensorCore, &g, &s).is_ok());
        apply(MethodId::UseTensorCore, &g, &mut s);
        assert!(s.cfg[0].mxu);
        assert_eq!(s.cfg[0].precision, Precision::Tf32);
    }

    #[test]
    fn fuse_elementwise_is_exhaustive() {
        let (g, mut s) = epilogue();
        assert!(applicable(MethodId::FuseElementwise, &g, &s).is_ok());
        apply(MethodId::FuseElementwise, &g, &mut s);
        // Every elementwise consumer is inlined into its producer kernel
        // (whole-program rewrite): only the reduction boundary remains.
        assert!(s.num_kernels() <= 2, "{}", s.num_kernels());
        assert!(s.validate(&g).is_ok());
        assert!(applicable(MethodId::FuseElementwise, &g, &s).is_err());
    }

    #[test]
    fn epilogue_fusion_is_coupled() {
        let (g, mut s) = epilogue();
        apply(MethodId::FuseEpilogueReduction, &g, &mut s);
        assert!(s.num_kernels() < 4, "coupled fusion merges several groups");
        assert!(s.validate(&g).is_ok());
    }

    #[test]
    fn every_method_applied_keeps_schedule_valid() {
        // Drive each method through an applicability-respecting apply.
        for &m in ALL_METHODS.iter() {
            let (g, mut s) = epilogue();
            // Make preconditions reachable for staged-only methods.
            apply(MethodId::TileSmem, &g, &mut s);
            if applicable(m, &g, &s).is_ok() {
                apply(m, &g, &mut s);
                assert!(s.validate(&g).is_ok(), "{m:?} broke the schedule");
            }
        }
    }

    #[test]
    fn vectorize_blocked_by_strided_layout() {
        let (g, s) = epilogue();
        assert!(matches!(s.cfg[0].layout, Layout::Strided));
        assert_eq!(
            applicable(MethodId::VectorizeLoads, &g, &s),
            Err("strided access cannot vectorize")
        );
    }

    #[test]
    fn pick_tile_prefers_full_tiles() {
        assert_eq!(pick_tile(512, 128), 128);
        assert_eq!(pick_tile(1464, 128), 128); // ragged dims keep full tiles
        assert_eq!(pick_tile(96, 128), 96); // small dims shrink the tile
        assert_eq!(pick_tile(5, 128), 8); // floor at 8
    }

    #[test]
    fn name_roundtrip() {
        for &m in ALL_METHODS.iter() {
            assert_eq!(MethodId::from_name(m.name()), Some(m));
        }
    }

    #[test]
    fn complexity_classes_cover_all() {
        let lows = ALL_METHODS.iter().filter(|m| m.complexity() == Complexity::Low).count();
        let highs = ALL_METHODS.iter().filter(|m| m.complexity() == Complexity::High).count();
        assert!(lows >= 3 && highs >= 3);
    }
}
