//! Kernel IR — the structured stand-in for CUDA kernel source.
//!
//! A task is a [`graph::KernelGraph`] (what the PyTorch reference computes);
//! a candidate kernel is a [`schedule::Schedule`] over that graph (how it is
//! realized as launched kernels). Optimization methods are IR rewrites
//! (`transforms`), static code features (§4.1.3) are extracted from the pair
//! (`features`), and compilation is legality checking (`legality`).

pub mod features;
pub mod graph;
pub mod legality;
pub mod op;
pub mod schedule;
pub mod transforms;
