//! The 18 static code features (§4.1.3) extracted from a kernel's structure.
//!
//! The paper extracts these from CUDA source by rule-based pattern matching
//! plus LLM inference for syntactically-diverse features. Here the kernel
//! "source" is the (graph, schedule) pair; `ground_truth` computes the exact
//! feature values, and `agents::feature_extractor` layers the paper's hybrid
//! extraction on top (deterministic for RULE_BASED features, noisy surrogate
//! inference for LLM_BASED ones).

use super::graph::KernelGraph;
use super::op::{OpKind, RedKind};
use super::schedule::{Layout, Precision, Schedule};

/// Reduction pattern summary over the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReductionPattern {
    None,
    Row,
    Col,
    Full,
}

/// What bounds a further occupancy increase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OccupancyLimiter {
    None,
    Scratchpad,
    Registers,
    Blocks,
}

/// The 18-feature vector. Field order mirrors the paper's feature table.
#[derive(Debug, Clone, PartialEq)]
pub struct CodeFeatures {
    /// 1. GEMM implemented as a naive global-memory loop (no K blocking).
    pub naive_gemm_loop: bool,
    /// 2. Shared-memory / VMEM operand tiling present.
    pub smem_tiling: bool,
    /// 3. Tensor-core / MXU math path in use.
    pub tensor_core: bool,
    /// 4. Vectorized global loads (width > 1).
    pub vectorized_loads: bool,
    /// 5. Global accesses coalesced / lane-aligned.
    pub coalesced_access: bool,
    /// 6. Scratchpad bank-conflict risk (staging without padding).
    pub bank_conflict_risk: bool,
    /// 7. Count of producer-consumer pairs in *different* kernels that a
    ///    legal fusion could merge.
    pub fusion_opportunities: u32,
    /// 8. Longest chain of unfused adjacent elementwise kernels.
    pub unfused_ew_chain: u32,
    /// 9. Reduction pattern present in the task.
    pub reduction_pattern: ReductionPattern,
    /// 10. Mixed-precision path (anything other than pure F32).
    pub mixed_precision: bool,
    /// 11. Double-buffered pipeline present on the dominant group.
    pub double_buffered: bool,
    /// 12. Inner loops unrolled (factor > 1) on the dominant group.
    pub unrolled: bool,
    /// 13. Register-pressure class 0..=2 (low/med/high) of the dominant group.
    pub register_pressure: u8,
    /// 14. Occupancy limiter of the dominant group.
    pub occupancy_limiter: OccupancyLimiter,
    /// 15. Strided (transposed) access pattern present anywhere.
    pub strided_access: bool,
    /// 16. Atomics required (scatter / cross-block reductions).
    pub uses_atomics: bool,
    /// 17. Branch-divergence risk (data-dependent ops: argminmax, gather).
    pub divergence_risk: bool,
    /// 18. Number of kernel launches (fusion groups).
    pub kernel_launches: u32,
    /// 19. Exploitable operand structure not yet specialized on. (The
    ///     feature set "can be expanded as we observe new kernel patterns" —
    ///     §4.1.3; recognizing a diagonal operand is semantic, so this is an
    ///     LLM-extracted feature.)
    pub structured_operand: bool,
}

/// Which features the paper extracts by rules vs by LLM inference.
/// Index = feature number - 1.
pub const LLM_BASED: [bool; 18] = [
    true,  // 1 naive_gemm_loop: "semantically equivalent but diverse indexing"
    false, // 2 smem_tiling: explicit API usage
    false, // 3 tensor_core: intrinsic usage
    false, // 4 vectorized_loads: fixed idiom
    true,  // 5 coalesced_access: diverse indexing logic
    true,  // 6 bank_conflict_risk: implicit layout assumption
    true,  // 7 fusion_opportunities: semantic
    false, // 8 unfused_ew_chain: structural
    false, // 9 reduction_pattern: structural
    false, // 10 mixed_precision: lexical
    false, // 11 double_buffered: idiom
    false, // 12 unrolled: pragma/idiom
    true,  // 13 register_pressure: semantic estimate
    true,  // 14 occupancy_limiter: semantic estimate
    true,  // 15 strided_access: diverse indexing
    false, // 16 uses_atomics: lexical
    true,  // 17 divergence_risk: semantic
    false, // 18 kernel_launches: count
];

/// Exact feature extraction from the structured kernel (the "oracle" the
/// hybrid extractor is benchmarked against), focused on the group
/// containing the dominant op.
pub fn ground_truth(graph: &KernelGraph, sched: &Schedule) -> CodeFeatures {
    let dom_op = graph.dominant_op().map(|o| o.id).unwrap_or(0);
    let dom_group = sched.group_of(dom_op).unwrap_or(0);
    ground_truth_at(graph, sched, dom_group)
}

/// Exact feature extraction focused on `focus_group` (the profiler's hot
/// kernel — what the paper's Feature Extractor actually inspects).
pub fn ground_truth_at(graph: &KernelGraph, sched: &Schedule, focus_group: usize) -> CodeFeatures {
    let dom_group = focus_group.min(sched.cfg.len() - 1);
    let dom = &sched.cfg[dom_group];
    let dom_has_gemm = sched.groups[dom_group]
        .iter()
        .any(|&o| graph.op(o).is_gemm_like());

    let has_gemm = !graph.gemm_ops().is_empty();
    let naive_gemm_loop = has_gemm && dom_has_gemm && (dom.tile_k == 0 || !dom.staging);

    // Perf (§Perf opt 2): one op->group map instead of repeated O(groups)
    // `group_of` scans in the per-edge loops below.
    let mut op_group = vec![0usize; graph.len()];
    for (gi, group) in sched.groups.iter().enumerate() {
        for &o in group {
            op_group[o] = gi;
        }
    }

    // Fusion opportunities: producer/consumer pairs split across groups
    // where the consumer is elementwise-or-reduction (legal fusion shapes).
    let mut fusion_opportunities = 0u32;
    for op in &graph.ops {
        for &inp in &op.inputs {
            if op_group[inp] != op_group[op.id] {
                let fusable = matches!(
                    op.kind,
                    OpKind::Elementwise(_) | OpKind::Reduction(_) | OpKind::Norm(_)
                );
                if fusable {
                    fusion_opportunities += 1;
                }
            }
        }
    }

    // Longest chain of adjacent elementwise ops sitting in distinct groups.
    let mut unfused_ew_chain = 0u32;
    let mut chain = 0u32;
    for op in &graph.ops {
        let is_ew = matches!(op.kind, OpKind::Elementwise(_));
        let split = op.inputs.iter().any(|&i| {
            op_group[i] != op_group[op.id]
                && matches!(graph.op(i).kind, OpKind::Elementwise(_))
        });
        if is_ew && (split || chain == 0) {
            chain += 1;
            unfused_ew_chain = unfused_ew_chain.max(chain);
        } else if !is_ew {
            chain = 0;
        }
    }

    let reduction_pattern = graph
        .ops
        .iter()
        .find_map(|o| match o.kind {
            OpKind::Reduction(RedKind::Row) | OpKind::Norm(_) => Some(ReductionPattern::Row),
            OpKind::Reduction(RedKind::Col) => Some(ReductionPattern::Col),
            OpKind::Reduction(RedKind::Full) => Some(ReductionPattern::Full),
            _ => None,
        })
        .unwrap_or(ReductionPattern::None);

    // Register pressure class from tile area + unroll.
    let tile_area = dom.tile_m * dom.tile_n;
    let register_pressure = if tile_area >= 128 * 128 && dom.unroll >= 4 {
        2
    } else if tile_area >= 64 * 64 {
        1
    } else {
        0
    };

    let scratch = dom.scratch_bytes(4);
    let occupancy_limiter = if scratch > 96 * 1024 {
        OccupancyLimiter::Scratchpad
    } else if register_pressure == 2 {
        OccupancyLimiter::Registers
    } else if sched.num_kernels() == 1 && graph.len() == 1 && tile_area >= 128 * 128 {
        OccupancyLimiter::Blocks
    } else {
        OccupancyLimiter::None
    };

    CodeFeatures {
        naive_gemm_loop,
        smem_tiling: dom.staging,
        tensor_core: dom.mxu,
        vectorized_loads: dom.vector_width > 1,
        coalesced_access: !matches!(dom.layout, Layout::Strided),
        bank_conflict_risk: dom.staging && !dom.smem_padding,
        fusion_opportunities,
        unfused_ew_chain,
        reduction_pattern,
        mixed_precision: !matches!(dom.precision, Precision::F32),
        double_buffered: dom.double_buffer,
        unrolled: dom.unroll > 1,
        register_pressure,
        occupancy_limiter,
        strided_access: sched.cfg.iter().any(|c| matches!(c.layout, Layout::Strided)),
        uses_atomics: graph
            .ops
            .iter()
            .any(|o| matches!(o.kind, OpKind::Scatter | OpKind::Reduction(RedKind::Full))),
        divergence_risk: graph.ops.iter().any(|o| {
            matches!(o.kind, OpKind::Gather | OpKind::Reduction(RedKind::ArgMinMax))
        }),
        kernel_launches: sched.num_kernels() as u32,
        structured_operand: graph.structured_operands && !sched.specialized,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::EwKind;
    use crate::kir::schedule::GroupSchedule;

    fn gemm_chain() -> KernelGraph {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::MatMul, 256, 256, 256, vec![]);
        let b = g.push(OpKind::Elementwise(EwKind::Relu), 256, 256, 1, vec![a]);
        let _ = g.push(OpKind::Elementwise(EwKind::Scale), 256, 256, 1, vec![b]);
        g
    }

    #[test]
    fn naive_seed_features() {
        let g = gemm_chain();
        let s = Schedule::per_op_naive(&g);
        let f = ground_truth(&g, &s);
        assert!(f.naive_gemm_loop);
        assert!(!f.smem_tiling);
        assert!(!f.coalesced_access);
        assert_eq!(f.kernel_launches, 3);
        assert!(f.fusion_opportunities >= 2);
        assert!(!f.mixed_precision);
    }

    #[test]
    fn library_schedule_clears_naive_flags() {
        let g = gemm_chain();
        let mut s = Schedule::per_op_naive(&g);
        s.cfg[0] = GroupSchedule::library_gemm();
        let f = ground_truth(&g, &s);
        assert!(!f.naive_gemm_loop);
        assert!(f.smem_tiling);
        assert!(f.tensor_core);
        assert!(f.vectorized_loads);
        assert!(f.double_buffered);
        assert!(f.mixed_precision);
    }

    #[test]
    fn fusion_removes_opportunities() {
        let g = gemm_chain();
        let mut s = Schedule::per_op_naive(&g);
        let before = ground_truth(&g, &s).fusion_opportunities;
        s.merge_groups(0, 1);
        s.merge_groups(0, 1); // former group 2 is now index 1
        let after = ground_truth(&g, &s).fusion_opportunities;
        assert!(after < before);
        assert_eq!(ground_truth(&g, &s).kernel_launches, 1);
    }

    #[test]
    fn bank_conflict_requires_staging() {
        let g = gemm_chain();
        let mut s = Schedule::per_op_naive(&g);
        assert!(!ground_truth(&g, &s).bank_conflict_risk);
        s.cfg[0].staging = true;
        s.cfg[0].smem_padding = false;
        assert!(ground_truth(&g, &s).bank_conflict_risk);
    }

    #[test]
    fn llm_based_mask_has_both_kinds() {
        assert!(LLM_BASED.iter().any(|&b| b));
        assert!(LLM_BASED.iter().any(|&b| !b));
        assert_eq!(LLM_BASED.len(), 18);
    }
}
