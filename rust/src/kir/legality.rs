//! Compile-time legality checking — the Reviewer's "Compiler" half.
//!
//! A schedule that violates these rules corresponds to a kernel that fails
//! to build (resource over-subscription, illegal fusion, broken tiling).
//! The fault model (`device::faults`) layers *injected* compile errors from
//! buggy agent edits on top; this module covers the deterministic, structural
//! ones.

use super::graph::KernelGraph;
use super::op::{OpKind, RedKind};
use super::schedule::Schedule;
use crate::device::machine::DeviceSpec;

/// A compile diagnostic: rule id + message, the `feedbackc` of Algorithm 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompileError {
    pub rule: &'static str,
    pub message: String,
}

impl CompileError {
    fn new(rule: &'static str, message: String) -> Self {
        CompileError { rule, message }
    }
}

/// Check all structural legality rules; empty vec = compiles clean.
pub fn check(graph: &KernelGraph, sched: &Schedule, dev: &DeviceSpec) -> Vec<CompileError> {
    let mut errs = Vec::new();

    if let Err(e) = sched.validate(graph) {
        errs.push(CompileError::new("partition", e));
        return errs; // downstream checks assume a valid partition
    }

    for (gi, (group, cfg)) in sched.groups.iter().zip(&sched.cfg).enumerate() {
        // Scratchpad budget.
        let scratch = cfg.scratch_bytes(4);
        if scratch > dev.scratch_bytes {
            errs.push(CompileError::new(
                "scratch_overflow",
                format!(
                    "group {gi}: scratch {scratch} B exceeds {} B",
                    dev.scratch_bytes
                ),
            ));
        }

        // Tile sanity.
        if cfg.tile_m == 0 || cfg.tile_n == 0 {
            errs.push(CompileError::new(
                "zero_tile",
                format!("group {gi}: zero tile dims"),
            ));
        }
        if cfg.block_threads == 0 || cfg.block_threads > dev.max_block_threads {
            errs.push(CompileError::new(
                "bad_launch",
                format!("group {gi}: block_threads {}", cfg.block_threads),
            ));
        }

        // MXU path requires staged operands and 8-aligned dims.
        if cfg.mxu {
            if !cfg.staging {
                errs.push(CompileError::new(
                    "mxu_unstaged",
                    format!("group {gi}: tensor-core path without staged operands"),
                ));
            }
            for &oid in group {
                let op = graph.op(oid);
                if op.is_gemm_like() && (op.m % 8 != 0 || op.n % 8 != 0 || op.k % 8 != 0) {
                    errs.push(CompileError::new(
                        "mxu_alignment",
                        format!("group {gi}: {} not 8-aligned for MXU", op.label()),
                    ));
                }
            }
        }

        // Split-K needs a cross-block combine: illegal when fused with a
        // row-reduction consumer in the same kernel.
        if cfg.split_k > 1 {
            let has_red = group
                .iter()
                .any(|&o| matches!(graph.op(o).kind, OpKind::Reduction(_) | OpKind::Norm(_)));
            if has_red {
                errs.push(CompileError::new(
                    "splitk_fused_reduction",
                    format!("group {gi}: split-K cannot fuse with a reduction"),
                ));
            }
        }

        // Fusion legality inside the group.
        errs.extend(check_group_fusion(graph, group, gi));
    }

    errs
}

/// A fusion group is legal iff it is a connected producer-consumer chain
/// where (a) at most one GEMM-like op anchors it, (b) reductions appear only
/// after every elementwise op that feeds them, and (c) column reductions /
/// scatter never fuse with a GEMM (cross-block data flow).
fn check_group_fusion(graph: &KernelGraph, group: &[usize], gi: usize) -> Vec<CompileError> {
    let mut errs = Vec::new();

    let gemms = group.iter().filter(|&&o| graph.op(o).is_gemm_like()).count();
    if gemms > 1 {
        errs.push(CompileError::new(
            "multi_gemm_fusion",
            format!("group {gi}: {gemms} GEMMs in one kernel"),
        ));
    }

    let has_gemm = gemms > 0;
    for &oid in group {
        let op = graph.op(oid);
        match op.kind {
            OpKind::Reduction(RedKind::Col) | OpKind::Scatter if has_gemm => {
                errs.push(CompileError::new(
                    "cross_block_fusion",
                    format!("group {gi}: {} cannot fuse with GEMM", op.label()),
                ));
            }
            OpKind::Scan if group.len() > 1 => {
                errs.push(CompileError::new(
                    "scan_fusion",
                    format!("group {gi}: scan must be standalone"),
                ));
            }
            _ => {}
        }
    }

    // Connectivity: every op (except the group's first in graph order) must
    // have an in-group input or consumer; disconnected "fusion" is a
    // horizontal batch, which is only legal for small elementwise ops.
    if group.len() > 1 {
        for &oid in group {
            let op = graph.op(oid);
            let connected = op.inputs.iter().any(|i| group.contains(i))
                || graph.consumers(oid).iter().any(|c| group.contains(c));
            if !connected {
                let small = op.flops() < 1e6 && !op.is_gemm_like();
                if !small {
                    errs.push(CompileError::new(
                        "disconnected_fusion",
                        format!("group {gi}: {} fused without dataflow", op.label()),
                    ));
                }
            }
        }
    }

    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::machine::DeviceSpec;
    use crate::kir::op::EwKind;
    use crate::kir::schedule::GroupSchedule;

    fn dev() -> DeviceSpec {
        DeviceSpec::a100_like()
    }

    fn gemm_red_graph() -> KernelGraph {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::MatMul, 128, 128, 512, vec![]);
        let b = g.push(OpKind::Elementwise(EwKind::Relu), 128, 128, 1, vec![a]);
        let _ = g.push(OpKind::Reduction(RedKind::Row), 128, 128, 1, vec![b]);
        g
    }

    #[test]
    fn naive_schedule_compiles() {
        let g = gemm_red_graph();
        let s = Schedule::per_op_naive(&g);
        assert!(check(&g, &s, &dev()).is_empty());
    }

    #[test]
    fn scratch_overflow_detected() {
        let g = gemm_red_graph();
        let mut s = Schedule::per_op_naive(&g);
        s.cfg[0] = GroupSchedule::library_gemm();
        s.cfg[0].tile_m = 1024;
        s.cfg[0].tile_n = 1024;
        s.cfg[0].tile_k = 128;
        let errs = check(&g, &s, &dev());
        assert!(errs.iter().any(|e| e.rule == "scratch_overflow"), "{errs:?}");
    }

    #[test]
    fn mxu_requires_staging() {
        let g = gemm_red_graph();
        let mut s = Schedule::per_op_naive(&g);
        s.cfg[0].mxu = true;
        let errs = check(&g, &s, &dev());
        assert!(errs.iter().any(|e| e.rule == "mxu_unstaged"));
    }

    #[test]
    fn splitk_reduction_fusion_illegal() {
        let g = gemm_red_graph();
        let mut s = Schedule::per_op_naive(&g);
        s.merge_groups(0, 1);
        s.merge_groups(0, 1);
        s.cfg[0].split_k = 4;
        let errs = check(&g, &s, &dev());
        assert!(errs.iter().any(|e| e.rule == "splitk_fused_reduction"));
    }

    #[test]
    fn two_gemms_cannot_fuse() {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::MatMul, 64, 64, 64, vec![]);
        let _ = g.push(OpKind::MatMul, 64, 64, 64, vec![a]);
        let mut s = Schedule::per_op_naive(&g);
        s.merge_groups(0, 1);
        let errs = check(&g, &s, &dev());
        assert!(errs.iter().any(|e| e.rule == "multi_gemm_fusion"));
    }

    #[test]
    fn col_reduction_gemm_fusion_illegal() {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::MatMul, 64, 64, 64, vec![]);
        let _ = g.push(OpKind::Reduction(RedKind::Col), 64, 64, 1, vec![a]);
        let mut s = Schedule::per_op_naive(&g);
        s.merge_groups(0, 1);
        let errs = check(&g, &s, &dev());
        assert!(errs.iter().any(|e| e.rule == "cross_block_fusion"));
    }
}
