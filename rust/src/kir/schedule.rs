//! Schedule representation: how a task graph is realized as launched kernels.
//!
//! A [`Schedule`] partitions the graph's ops into *fusion groups* (one
//! launched kernel each) and gives every group a [`GroupSchedule`] — the
//! knobs the optimization methods (``kir::transforms``) turn. This is the
//! "kernel source code" of the simulation: static features are extracted
//! from it, legality is checked on it, and the device cost model prices it.

use super::graph::KernelGraph;
use super::op::OpId;

/// Numeric path. Mirrors the CUDA f32 / TF32 / tensor-core-bf16 choice and
/// the TPU f32-VPU / bf16-MXU choice (DESIGN.md §Hardware-Adaptation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    Tf32,
    Bf16Acc32,
}

/// Operand layout seen by the kernel's inner loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// Row-major, accesses along rows: coalesced / lane-aligned.
    Coalesced,
    /// Accesses stride across rows (e.g. untransposed B operand): poor.
    Strided,
    /// Explicitly tiled/swizzled staging layout: best, needs staging pass.
    Tiled,
}

/// Per-fusion-group schedule knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSchedule {
    /// Output tile (CUDA threadblock tile / Pallas BlockSpec block).
    pub tile_m: u64,
    pub tile_n: u64,
    /// Contraction blocking; 0 = no K blocking (full-K strips — the naive
    /// no-reuse schedule of the motivating example).
    pub tile_k: u64,
    /// Operands staged through shared memory / VMEM before use?
    pub staging: bool,
    /// Vector width of global loads (1/2/4 — ld.global.v4 analog).
    pub vector_width: u8,
    /// MXU / tensor-core path enabled (requires Precision != F32).
    pub mxu: bool,
    pub precision: Precision,
    /// Double-buffered HBM<->scratch pipeline (cp.async analog).
    pub double_buffer: bool,
    pub layout: Layout,
    /// Inner-loop unroll factor (1 = none).
    pub unroll: u8,
    /// Threads per block (CUDA) / rough parallel granularity knob.
    pub block_threads: u32,
    /// Scratchpad padding to dodge bank conflicts (CUDA) / lane misalignment.
    pub smem_padding: bool,
    /// Split-K factor (1 = off): extra parallelism for small-M GEMMs.
    pub split_k: u32,
}

impl GroupSchedule {
    /// The Generator's seed schedule: correct, unoptimized — exactly what the
    /// paper says the Generator aims for ("does not optimize for speed").
    pub fn naive() -> GroupSchedule {
        GroupSchedule {
            tile_m: 8,
            tile_n: 64,
            tile_k: 0,
            staging: false,
            vector_width: 1,
            mxu: false,
            precision: Precision::F32,
            double_buffer: false,
            layout: Layout::Strided,
            unroll: 1,
            block_threads: 256,
            smem_padding: false,
            split_k: 1,
        }
    }

    /// A vendor-library-quality GEMM schedule (the cuBLAS stand-in used by
    /// the Torch-Eager baseline cost for GEMM-like ops).
    pub fn library_gemm() -> GroupSchedule {
        GroupSchedule {
            tile_m: 128,
            tile_n: 128,
            tile_k: 32,
            staging: true,
            vector_width: 4,
            mxu: true,
            precision: Precision::Tf32,
            double_buffer: true,
            layout: Layout::Tiled,
            unroll: 4,
            block_threads: 256,
            smem_padding: true,
            split_k: 1,
        }
    }

    /// Scratchpad bytes this schedule keeps resident per block (operand
    /// tiles; doubled when double-buffered) for a GEMM-shaped op.
    pub fn scratch_bytes(&self, dtype_bytes: u64) -> u64 {
        if !self.staging {
            return 0;
        }
        let tk = if self.tile_k == 0 { 1 } else { self.tile_k };
        let a = self.tile_m * tk * dtype_bytes;
        let b = tk * self.tile_n * dtype_bytes;
        let acc = self.tile_m * self.tile_n * 4; // f32 accumulator
        let buf = if self.double_buffer { 2 } else { 1 };
        let pad = if self.smem_padding { (a + b) / 16 } else { 0 };
        buf * (a + b) + acc + pad
    }
}

/// A full schedule for a task graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Partition of op ids into fusion groups, each launched as one kernel.
    /// Groups are in execution order; within a group ops are in graph order.
    pub groups: Vec<Vec<OpId>>,
    /// One schedule per group (parallel to `groups`).
    pub cfg: Vec<GroupSchedule>,
    /// Structure specialization applied: the kernel exploits operand
    /// structure (diagonal/triangular/banded) instead of doing the dense
    /// work the eager reference does. See `bench_suite::eager`.
    pub specialized: bool,
}

impl Schedule {
    /// One kernel per op, all naive — the Generator's seed point.
    pub fn per_op_naive(graph: &KernelGraph) -> Schedule {
        Schedule {
            groups: graph.ops.iter().map(|o| vec![o.id]).collect(),
            cfg: graph.ops.iter().map(|_| GroupSchedule::naive()).collect(),
            specialized: false,
        }
    }

    pub fn num_kernels(&self) -> usize {
        self.groups.len()
    }

    /// Index of the group containing `op`, if any.
    pub fn group_of(&self, op: OpId) -> Option<usize> {
        self.groups.iter().position(|g| g.contains(&op))
    }

    /// Merge group `b` into group `a` (b's ops appended, b's cfg dropped,
    /// a's cfg kept). Caller is responsible for legality checking.
    pub fn merge_groups(&mut self, a: usize, b: usize) {
        assert!(a != b && a < self.groups.len() && b < self.groups.len());
        let (keep, drop) = (a.min(b), a.max(b));
        let moved = self.groups.remove(drop);
        self.cfg.remove(drop);
        self.groups[keep].extend(moved);
        self.groups[keep].sort_unstable();
    }

    /// Split `op` out of its group into a fresh naive singleton group.
    pub fn split_op(&mut self, op: OpId) {
        if let Some(g) = self.group_of(op) {
            if self.groups[g].len() <= 1 {
                return;
            }
            self.groups[g].retain(|&o| o != op);
            self.groups.push(vec![op]);
            self.cfg.push(GroupSchedule::naive());
        }
    }

    /// Structural invariant: groups form a partition of 0..n_ops.
    pub fn validate(&self, graph: &KernelGraph) -> Result<(), String> {
        if self.groups.len() != self.cfg.len() {
            return Err("groups/cfg length mismatch".into());
        }
        let mut seen = vec![false; graph.len()];
        for g in &self.groups {
            if g.is_empty() {
                return Err("empty fusion group".into());
            }
            for &op in g {
                if op >= graph.len() {
                    return Err(format!("op {op} out of range"));
                }
                if seen[op] {
                    return Err(format!("op {op} in two groups"));
                }
                seen[op] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("some ops unscheduled".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::op::{EwKind, OpKind};

    fn graph3() -> KernelGraph {
        let mut g = KernelGraph::new();
        let a = g.push(OpKind::MatMul, 64, 64, 64, vec![]);
        let b = g.push(OpKind::Elementwise(EwKind::Relu), 64, 64, 1, vec![a]);
        let _ = g.push(OpKind::Elementwise(EwKind::Scale), 64, 64, 1, vec![b]);
        g
    }

    #[test]
    fn per_op_naive_is_valid_partition() {
        let g = graph3();
        let s = Schedule::per_op_naive(&g);
        assert_eq!(s.num_kernels(), 3);
        assert!(s.validate(&g).is_ok());
    }

    #[test]
    fn merge_then_split_roundtrip() {
        let g = graph3();
        let mut s = Schedule::per_op_naive(&g);
        s.merge_groups(0, 1);
        assert_eq!(s.num_kernels(), 2);
        assert!(s.validate(&g).is_ok());
        assert_eq!(s.group_of(0), s.group_of(1));
        s.split_op(1);
        assert_eq!(s.num_kernels(), 3);
        assert!(s.validate(&g).is_ok());
    }

    #[test]
    fn scratch_bytes_scales_with_buffering() {
        let mut c = GroupSchedule::library_gemm();
        let single = {
            c.double_buffer = false;
            c.scratch_bytes(4)
        };
        c.double_buffer = true;
        assert!(c.scratch_bytes(4) > single);
    }

    #[test]
    fn naive_has_no_scratch() {
        assert_eq!(GroupSchedule::naive().scratch_bytes(4), 0);
    }

    #[test]
    fn validate_catches_double_membership() {
        let g = graph3();
        let mut s = Schedule::per_op_naive(&g);
        s.groups[0].push(1);
        assert!(s.validate(&g).is_err());
    }
}
