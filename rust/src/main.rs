//! KernelSkill CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands map onto the experiment index in DESIGN.md:
//!   table1 | table2 | table3 | per-round | trajectory   (paper artifacts)
//!   verify-artifacts | calibrate                        (real PJRT path)
//!   run-task --task <id> [--strategy <name>]            (single-task trace)
//!   suite --strategy <name> [--level N]                 (one-strategy suite)
//!   report --run-dir <dir>                              (streamed results)
//!   merge [--watch] --out <dir> <shard-dir>...          (union shard run dirs)
//!   launch --shards N --run-dir <dir> [flags]           (spawn+supervise+merge)
//!   skills inspect|gc --memory-dir <dir>                (learned-store tooling)
//!   smoke                                               (CI orchestration proof)
//!
//! Orchestration v2 flags (table*/suite): `--run-dir <dir>` streams every
//! finished cell to `<dir>/results.jsonl`, `--resume` skips cells already
//! checkpointed there, and `--memory-dir <dir>` warm-starts the persistent
//! long-term skill store and rewrites it after each task.
//!
//! Sharding (table*/suite): `--shards N --shard-index i` runs only shard
//! i's deterministic slice of the (strategy, task, seed) matrix into its
//! own `--run-dir`; `merge` unions the per-shard dirs into one whose
//! `report` and skill store are byte-identical to a single-process run.
//! `launch` wraps the whole dance — it spawns the shard processes,
//! restarts crashed ones into `--resume`, streams the merge live, and
//! finalizes it — and `--exchange-epoch N` additionally lets shards trade
//! learned skills at deterministic epoch boundaries mid-run.

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, Branch, LoopConfig};
use kernelskill::device::faults::ChaosConfig;
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::{calibrate, experiments, metrics};
use kernelskill::runtime::{self, Registry, Runtime};
use kernelskill::util::cli::Args;
use kernelskill::util::logging::{self, Level};

/// Subcommands a `launch` / `worker` fleet may fan out (they must accept
/// `--run-dir/--shards/--shard-index/--resume`, and in elastic fleets
/// `--batch-index/--batch-count`).
const SHARDABLE: [&str; 5] = ["suite", "table1", "table2", "table3", "per-round"];

/// Matrix-defining flags forwarded verbatim to shard children by `launch`
/// and `worker`.
const PASSTHROUGH_FLAGS: [&str; 8] =
    ["strategy", "level", "take", "seeds", "suite-seed", "workers", "device", "chaos"];

/// `--no-retrieval-cache` given in either spelling the hand-rolled parser
/// produces (bare switch, or `--no-retrieval-cache=1` as forwarded to
/// shard children, where a bare switch could swallow a following
/// positional).
fn no_retrieval_cache(args: &Args) -> bool {
    args.has("no-retrieval-cache") || args.get("no-retrieval-cache").is_some()
}

/// `--exchange-adaptive` in either spelling (bare switch, or the
/// `--exchange-adaptive=1` form forwarded to shard children).
fn exchange_adaptive(args: &Args) -> bool {
    args.has("exchange-adaptive") || args.get("exchange-adaptive").is_some()
}

/// The flags `launch` and `worker` share when fanning a matrix out to
/// shard children: the verbatim passthrough list, the exchange epoch, and
/// the per-shard crash budget. One parser for both, so the two fan-out
/// surfaces can never drift apart.
fn fanout_flags(args: &Args) -> Result<(Vec<String>, Option<usize>, usize), String> {
    let mut passthrough = Vec::new();
    for flag in PASSTHROUGH_FLAGS {
        if let Some(v) = args.get(flag) {
            passthrough.push(format!("--{flag}"));
            passthrough.push(v.to_string());
        }
    }
    if no_retrieval_cache(args) {
        // `=`-form: position-robust no matter what the child parser sees
        // after it.
        passthrough.push("--no-retrieval-cache=1".to_string());
    }
    if exchange_adaptive(args) {
        passthrough.push("--exchange-adaptive=1".to_string());
    }
    let mut exchange_epoch = None;
    if args.has("exchange") {
        exchange_epoch = Some(coordinator::DEFAULT_EXCHANGE_EPOCH);
    }
    if args.get("exchange-epoch").is_some() {
        exchange_epoch = Some(args.get_usize("exchange-epoch", 0)?);
    }
    let max_restarts = args.get_usize("max-restarts", 2)?;
    Ok((passthrough, exchange_epoch, max_restarts))
}

/// `--chaos tc=..,drop=..,sigma=..,bias=..,seed=..` — environment-fault
/// injection (see `device::faults::ChaosConfig`). Validated here so a
/// typo'd spec fails before any work is scheduled.
fn parse_chaos(args: &Args) -> Result<Option<ChaosConfig>, String> {
    match args.get("chaos") {
        None => Ok(None),
        Some(spec) => ChaosConfig::parse(spec).map(Some),
    }
}

fn parse_device(args: &Args) -> Result<Option<DeviceSpec>, String> {
    match args.get("device") {
        None => Ok(None),
        Some(name) => DeviceSpec::by_name(name).map(Some).ok_or_else(|| {
            format!(
                "unknown device preset {name:?} (known: {:?})",
                DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
            )
        }),
    }
}

fn exp_config(args: &Args) -> Result<experiments::ExpConfig, String> {
    let defaults = experiments::ExpConfig::default();
    let n_seeds = args.get_usize("seeds", 1)?;
    let shards = args.get_usize("shards", 1)?;
    let batch_count = args.get_usize("batch-count", 0)?;
    let run_dir = args.get("run-dir").map(std::path::PathBuf::from);
    if shards != 1 && run_dir.is_none() {
        return Err("--shards requires --run-dir (each shard streams its slice to its own \
                    run dir, then `merge` unions them)"
            .to_string());
    }
    if batch_count != 0 && run_dir.is_none() {
        return Err("--batch-count requires --run-dir (each batch streams its slice to its \
                    own run dir; a `worker` loop normally supplies it)"
            .to_string());
    }
    if args.get("batch-index").is_some() && batch_count == 0 {
        return Err("--batch-index requires --batch-count".to_string());
    }
    let exchange_dir = args.get("exchange-dir").map(std::path::PathBuf::from);
    let exchange_epoch = args.get_usize("exchange-epoch", 0)?;
    if exchange_dir.is_none() && exchange_epoch != 0 {
        return Err("--exchange-epoch requires --exchange-dir (every shard of the run must \
                    point at one shared exchange directory)"
            .to_string());
    }
    Ok(experiments::ExpConfig {
        suite_seed: args.get_u64("suite-seed", defaults.suite_seed)?,
        run_seeds: (0..n_seeds as u64).collect(),
        workers: args.get_usize("workers", defaults.workers)?,
        run_dir,
        resume: args.has("resume"),
        memory_dir: args.get("memory-dir").map(std::path::PathBuf::from),
        shards,
        shard_index: args.get_usize("shard-index", 0)?,
        batch_count,
        batch_index: args.get_usize("batch-index", 0)?,
        exchange_dir,
        exchange_epoch,
        exchange_adaptive: exchange_adaptive(args),
        device: parse_device(args)?,
        retrieval_cache: !no_retrieval_cache(args),
        chaos: parse_chaos(args)?,
    })
}

/// Mark a checkpointed run's directory complete once its whole slice of the
/// matrix is on disk, so `merge --watch` and `launch` know tail-following
/// can stop.
fn finish_run_dir(cfg: &experiments::ExpConfig) -> Result<(), String> {
    if let Some(dir) = &cfg.run_dir {
        kernelskill::coordinator::RunDir::open(dir)
            .and_then(|rd| rd.mark_complete())
            .map_err(|e| format!("writing completion marker in {}: {e}", dir.display()))?;
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        if coordinator::ExchangeWaitTimeout::matches(&e) {
            // EX_TEMPFAIL: a supervising launcher relaunches us with
            // `--resume` without burning the crash budget — the missing
            // peer delta is the *peer's* problem (it died or was
            // re-dispatched), not ours.
            std::process::exit(coordinator::EXCHANGE_TIMEOUT_EXIT);
        }
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let args = Args::from_env()?;
    if args.has("verbose") {
        logging::set_level(Level::Debug);
    }
    match args.subcommand.as_deref() {
        Some("table1") => {
            let cfg = exp_config(&args)?;
            let (rendered, _) = experiments::table1(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 1 — Success and Speedup vs Torch Eager\n{rendered}");
        }
        Some("table2") => {
            let cfg = exp_config(&args)?;
            let (rendered, _) = experiments::table2(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 2 — Memory ablations\n{rendered}");
        }
        Some("table3") => {
            let cfg = exp_config(&args)?;
            let (rendered, _) = experiments::table3(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 3 — Fast_1\n{rendered}");
        }
        Some("per-round") => {
            let cfg = exp_config(&args)?;
            let (rendered, _) = experiments::per_round_efficiency(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Per-round refinement efficiency (§5.4)\n{rendered}");
        }
        Some("trajectory") => {
            let cfg = exp_config(&args)?;
            println!("{}", experiments::trajectory_figures(&cfg));
        }
        Some("verify-artifacts") => {
            let seed = args.get_u64("seed", 7)?;
            let tol = args.get_f64("tolerance", 1e-3)?;
            let reg = Registry::load("artifacts").map_err(|e| e.to_string())?;
            let mut rt = Runtime::new("artifacts").map_err(|e| e.to_string())?;
            println!("platform = {}", rt.platform());
            let reports =
                runtime::verify_all(&mut rt, &reg, seed, tol).map_err(|e| e.to_string())?;
            let mut failed = 0;
            for r in &reports {
                println!(
                    "{:<20} {:<14} max_abs_err={:<10.2e} {}",
                    r.task,
                    r.variant,
                    r.max_abs_err,
                    if r.passed { "ok" } else { "FAIL" }
                );
                if !r.passed {
                    failed += 1;
                }
            }
            if failed > 0 {
                return Err(format!("{failed} variants failed verification"));
            }
            println!("all {} variants verified", reports.len());
        }
        Some("calibrate") => {
            let seed = args.get_u64("seed", 7)?;
            let rows = calibrate::calibrate(seed).map_err(|e| e.to_string())?;
            println!("{}", calibrate::render(&rows));
        }
        Some("run-task") => {
            let task_id = args.get("task").ok_or("--task <id> required")?;
            let strat_name = args.get_or("strategy", "KernelSkill");
            let strategy = baselines::by_name(strat_name)
                .ok_or_else(|| format!("unknown strategy {strat_name}"))?;
            let suite_seed = args.get_u64("suite-seed", 42)?;
            let tasks = bench_suite::full_suite(suite_seed);
            let task = tasks
                .iter()
                .find(|t| t.id.contains(task_id))
                .ok_or_else(|| format!("no task matching {task_id}"))?;
            let mut cfg = LoopConfig {
                run_seed: args.get_u64("seed", 0)?,
                memory_dir: args.get("memory-dir").map(std::path::PathBuf::from),
                retrieval_cache: !no_retrieval_cache(&args),
                ..LoopConfig::default()
            };
            // The device preset keys the skill partition the observations
            // land in, so run-task must honor it like every suite command.
            if let Some(dev) = parse_device(&args)? {
                cfg.dev = dev;
            }
            let r = coordinator::run_task(task, &strategy, &cfg);
            // Standalone runs persist their own observations (in a suite the
            // scheduler owns the write cycle), so learning accumulates
            // across repeated run-task invocations too.
            if let Some(dir) = &cfg.memory_dir {
                let path = dir.join("skills.json");
                let mut store =
                    kernelskill::memory::long_term::SegmentedSkillStore::open(dir)?;
                // One completed task = one fold epoch: the generation
                // clock advances even when the run produced no
                // observations, which is what ages stats that stop being
                // re-observed. Under the v4 layout advancing rotates the
                // previous epochs' head into an immutable segment instead
                // of rewriting accumulated history.
                let generation = store.generation() + 1;
                store
                    .advance_to(generation)
                    .map_err(|e| format!("rotating skill store head: {e}"))?;
                store.merge(&r.skill_obs);
                store
                    .save()
                    .map_err(|e| format!("saving skill store: {e}"))?;
                println!(
                    "memory: {} observation(s) merged into {} (generation {})",
                    r.skill_obs.len(),
                    path.display(),
                    generation
                );
            }
            println!(
                "{} [{}]: success={} best={:.3}x seed={:?} promotions={} repairs={}",
                r.task_id,
                r.strategy,
                r.success,
                r.best_speedup,
                r.seed_speedup,
                r.promotions,
                r.repair_attempts
            );
            for rec in &r.rounds {
                let what = match &rec.branch {
                    Branch::Optimize(m) => format!("optimize[{}]", m.name()),
                    Branch::Repair(f) => format!("repair[{f}]"),
                    Branch::Revert => "revert".into(),
                    Branch::Converged => "converged".into(),
                };
                println!(
                    "  round {:>2}: {:<30} ok={} speedup={:?}",
                    rec.round,
                    what,
                    rec.compiled && rec.correct,
                    rec.speedup
                );
            }
        }
        Some("suite") => {
            if args.has("smoke") {
                return run_smoke();
            }
            let strat_name = args.get_or("strategy", "KernelSkill");
            let strategy = baselines::by_name(strat_name)
                .ok_or_else(|| format!("unknown strategy {strat_name}"))?;
            let cfg = exp_config(&args)?;
            let level = args.get_usize("level", 0)?;
            let mut tasks = if level == 0 {
                bench_suite::full_suite(cfg.suite_seed)
            } else {
                bench_suite::level_suite(cfg.suite_seed, level as u8)
            };
            // Deterministic prefix slice: small fixed matrices for smokes
            // and the sharding CI job.
            let take = args.get_usize("take", 0)?;
            if take > 0 {
                tasks.truncate(take);
            }
            let suite = coordinator::run_suite_with(
                &tasks,
                &strategy,
                &cfg.loop_cfg(),
                &cfg.run_seeds,
                cfg.workers,
                &cfg.suite_opts(),
            )?;
            let split = metrics::by_level(&suite.results);
            for (i, lv) in split.iter().enumerate() {
                if lv.is_empty() {
                    continue;
                }
                let c = metrics::cell(lv, strategy.rounds);
                println!(
                    "L{}: n={} success={:.2} speedup={:.2} fast1={:.2} rounds={:.1}",
                    i + 1,
                    c.n,
                    c.success,
                    c.speedup,
                    c.fast1,
                    c.mean_rounds
                );
            }
            finish_run_dir(&cfg)?;
            if let Some(dir) = &cfg.run_dir {
                println!("checkpoint streamed to {}", dir.display());
            }
        }
        Some("report") => {
            let dir = args.get("run-dir").ok_or("--run-dir <dir> required")?;
            let rendered = experiments::report_run_dir(std::path::Path::new(dir))?;
            println!("{rendered}");
        }
        Some("merge") => {
            let out = args.get("out").ok_or("--out <dir> required")?;
            // The hand-rolled parser reads `--watch <path>` as a flag+value
            // pair, which would silently swallow the first shard dir (and
            // drop watch mode) when `--watch` directly precedes a
            // positional. Reclaim the swallowed path instead: merge output
            // is input-order-independent, so recovered-first is safe.
            let watch = args.has("watch") || args.get("watch").is_some();
            let mut inputs: Vec<std::path::PathBuf> = Vec::new();
            if let Some(v) = args.get("watch") {
                inputs.push(std::path::PathBuf::from(v));
            }
            inputs.extend(args.positional.iter().map(std::path::PathBuf::from));
            if inputs.is_empty() {
                return Err(
                    "usage: merge [--watch [--interval-ms N]] --out <dir> <shard-run-dir> \
                     [<shard-run-dir>...]"
                        .to_string(),
                );
            }
            let report = if watch {
                // Streaming merge: follow the shard checkpoints while their
                // processes are still running, then finalize once every
                // input carries the `complete` marker. The result is
                // byte-identical to a one-shot merge of the finished dirs.
                let interval = args.get_u64("interval-ms", 500)?.max(1);
                let mut watcher =
                    coordinator::MergeWatcher::new(std::path::Path::new(out), &inputs)?;
                let mut last = String::new();
                loop {
                    let status = watcher.poll()?;
                    let line = status.render();
                    if line != last {
                        println!("watch: {line}");
                        last = line;
                    }
                    if status.all_complete() {
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(interval));
                }
                watcher.finalize()?
            } else {
                coordinator::merge_run_dirs(std::path::Path::new(out), &inputs)?
            };
            print!("{}", report.render());
            println!("merged run dir: {out} (report it with: report --run-dir {out})");
        }
        Some("launch") => {
            let run_dir = args.get("run-dir").ok_or("--run-dir <dir> required")?;
            if args.get("memory-dir").is_some() {
                return Err("launch does not take --memory-dir: every shard would fight over \
                            one live store. Use --exchange-epoch for live cross-shard \
                            learning, or run the shards by hand with per-shard copies of the \
                            same skills.json"
                    .to_string());
            }
            if args.get("shard-index").is_some() {
                return Err("launch owns the shard assignment; drop --shard-index".to_string());
            }
            if args.get("batch-index").is_some() || args.get("batch-count").is_some() {
                return Err("batch slicing is elastic-fleet machinery: describe the fleet in \
                            an elastic manifest (total_batches + lease transport) and use \
                            launch --manifest / worker instead"
                    .to_string());
            }
            // Fleet mode: a worker manifest turns `launch` into the
            // pull-based cross-machine coordinator. `--manifest <file>` is
            // canonical; a non-numeric `--workers <file>` is accepted too
            // (a numeric value keeps its meaning: the children's
            // worker-pool size) — but only when it names a real file, so a
            // typo'd pool size gets a pointed error instead of a silent
            // mode switch.
            if let Some(path) = args.get("manifest") {
                return run_fleet(&args, path, run_dir);
            }
            if let Some(v) = args.get("workers").filter(|v| v.parse::<usize>().is_err()) {
                if std::path::Path::new(v).is_file() {
                    return run_fleet(&args, v, run_dir);
                }
                return Err(format!(
                    "--workers {v:?} is neither a worker-pool size nor an existing worker \
                     manifest file (fleet mode prefers --manifest <file>)"
                ));
            }
            let sub = args.get_or("cmd", "suite").to_string();
            if !SHARDABLE.contains(&sub.as_str()) {
                return Err(format!(
                    "launch --cmd {sub:?} is not shardable; expected one of {SHARDABLE:?}"
                ));
            }
            parse_device(&args)?; // refuse an unknown preset before spawning
            parse_chaos(&args)?; // refuse a malformed chaos spec likewise
            let program = std::env::current_exe()
                .map_err(|e| format!("resolving the current executable: {e}"))?;
            let shards = args.get_usize("shards", 2)?;
            let mut lc = coordinator::LaunchConfig::new(program, &sub, run_dir, shards);
            let (passthrough, exchange_epoch, max_restarts) = fanout_flags(&args)?;
            lc.passthrough = passthrough;
            lc.exchange_epoch = exchange_epoch;
            lc.max_restarts = max_restarts;
            let report = coordinator::launch(&lc)?;
            print!("{}", report.render());
            println!(
                "merged run dir: {run_dir} (report it with: report --run-dir {run_dir})"
            );
        }
        Some("worker") => return run_worker_cmd(&args),
        Some("skills") => return run_skills(&args),
        Some("smoke") => return run_smoke(),
        _ => {
            println!(
                "kernelskill — memory-augmented multi-agent kernel optimization (paper reproduction)\n\
                 \n\
                 usage: kernelskill <cmd> [flags]\n\
                 \n\
                 experiments:\n\
                 \x20 table1 | table2 | table3 | per-round | trajectory\n\
                 \x20     [--seeds N] [--suite-seed S] [--workers W] [--device D] [--chaos C]\n\
                 \x20     [--run-dir D] [--resume] [--memory-dir M]\n\
                 \x20     [--shards N --shard-index I | --batch-count B --batch-index K]\n\
                 \x20     [--exchange-dir X --exchange-epoch E [--exchange-adaptive]]\n\
                 real PJRT path:\n\
                 \x20 verify-artifacts [--seed S] [--tolerance T]\n\
                 \x20 calibrate [--seed S]\n\
                 single runs:\n\
                 \x20 run-task --task <substr> [--strategy <name>] [--seed S] [--memory-dir M] [--device D]\n\
                 \x20 suite --strategy <name> [--level 1|2|3|4] [--take N]\n\
                 \x20     [--run-dir D] [--resume] [--memory-dir M] [--smoke]\n\
                 \x20     [--shards N --shard-index I]\n\
                 \x20     [--device a100-like|tpu-like|h100-like|consumer-gpu-like|cpu-like]\n\
                 \x20     [--chaos tc=P,drop=P,sigma=S,bias=B,seed=N]   fault injection\n\
                 \x20     [--no-retrieval-cache]   A/B: per-task-run retrieval memo off\n\
                 orchestration:\n\
                 \x20 report --run-dir D     render tables from streamed results.jsonl\n\
                 \x20 merge --out D S0 S1..  union per-shard run dirs (checkpoints + skill stores)\n\
                 \x20     [--watch [--interval-ms N]]   follow still-running shards, then finalize\n\
                 \x20 launch --shards N --run-dir D [--cmd suite|table1|..]\n\
                 \x20     [--strategy S] [--level L] [--take K] [--seeds M] [--workers W]\n\
                 \x20     [--device D] [--chaos C] [--exchange-epoch E] [--max-restarts R]\n\
                 \x20     spawn N shard processes, restart crashes into --resume, merge into D\n\
                 \x20 launch --manifest workers.json --run-dir D\n\
                 \x20     [--stall-timeout-ms T] [--poll-ms P] [--lease-timeout-ms L]\n\
                 \x20     cross-machine coordinator: pull every worker's run dirs through\n\
                 \x20     their transports, relay exchange deltas, merge byte-identically;\n\
                 \x20     an *elastic* manifest (total_batches + lease transport) re-dispatches\n\
                 \x20     batches whose lease progress counter stalls for L ms\n\
                 \x20 worker --manifest workers.json --worker-id ID --run-dir D\n\
                 \x20     [--cmd suite|table1|..] [matrix flags as in launch]\n\
                 \x20     run this machine's manifest shard range and publish it\n\
                 \x20     (elastic manifest: claim lease batches until the board is done)\n\
                 \x20 smoke                  tiny checkpoint/resume/memory end-to-end (CI gate)\n\
                 learned memory (skills.json v4, see docs/memory-formats.md):\n\
                 \x20 skills inspect --memory-dir M [--device D] [--case SUBSTR] [--segments]\n\
                 \x20     per-partition stats, confidence, staleness, learned cases;\n\
                 \x20     --segments also prints the on-disk segment/head layout\n\
                 \x20 skills gc --memory-dir M [--max-age N] [--device D] [--dry-run]\n\
                 \x20     drop stats older than N generations (default 8); --device\n\
                 \x20     scopes the sweep to one partition\n\
                 \x20 skills compact --memory-dir M\n\
                 \x20     fold all on-disk segments into one (offline, atomic swap)\n\
                 \x20 skills diff A B\n\
                 \x20     per-stat divergence report between two stores (paths to\n\
                 \x20     skills.json or their directories), deterministic ordering\n\
                 \n\
                 strategies: KernelSkill, STARK, CudaForge, Astra, PRAGMA, QiMeng,\n\
                 \x20          Kevin-32B, 'w/o memory', 'w/o Short_term memory', 'w/o Long_term memory'"
            );
        }
    }
    Ok(())
}

/// `launch --manifest <file>`: the cross-machine coordinator. Spawns
/// nothing — it pulls every worker's published run dirs through their
/// transports, merges them live, and relays exchange deltas between
/// workers. The workers themselves are started out of band with the
/// `worker` subcommand.
fn run_fleet(args: &Args, manifest_path: &str, run_dir: &str) -> Result<(), String> {
    if args.get("shards").is_some() {
        return Err("launch --manifest: the manifest owns the shard assignment; drop --shards"
            .to_string());
    }
    // Matrix and supervision flags must live on the (uniform) `worker`
    // invocations; a flag here would silently apply to nothing.
    let matrix_flags = ["cmd", "exchange", "exchange-epoch", "strategy", "level", "take",
        "seeds", "suite-seed", "device", "chaos", "max-restarts", "no-retrieval-cache"];
    for flag in matrix_flags {
        if args.get(flag).is_some() || args.has(flag) {
            return Err(format!(
                "launch --manifest: --{flag} belongs on the `worker` invocations (every \
                 worker must run the same matrix flags); the coordinator only pulls, \
                 relays, and merges"
            ));
        }
    }
    // `--workers` doubles as the manifest-path spelling; any *other* value
    // here is the children's pool size and belongs on the workers too.
    if let Some(w) = args.get("workers") {
        if w != manifest_path {
            return Err(
                "launch --manifest: --workers <N> belongs on the `worker` invocations; \
                 the coordinator spawns nothing"
                    .to_string(),
            );
        }
    }
    let manifest =
        coordinator::WorkerManifest::load(std::path::Path::new(manifest_path))?;
    let mut fc = coordinator::FleetConfig::new(manifest, run_dir);
    fc.poll_ms = args.get_u64("poll-ms", fc.poll_ms)?;
    fc.stall_timeout_ms = args.get_u64("stall-timeout-ms", fc.stall_timeout_ms)?;
    fc.lease_timeout_ms = args.get_u64("lease-timeout-ms", fc.lease_timeout_ms)?;
    let report = coordinator::launch_workers(&fc)?;
    print!("{}", report.render());
    println!("merged run dir: {run_dir} (report it with: report --run-dir {run_dir})");
    Ok(())
}

/// The `worker` subcommand: run this machine's manifest row of a
/// cross-machine launch — spawn and supervise its shard range, publish
/// through its transport, pull the fleet's exchange deltas down.
fn run_worker_cmd(args: &Args) -> Result<(), String> {
    let manifest_path = args
        .get("manifest")
        .ok_or("worker: --manifest <workers.json> required")?;
    let id = args.get("worker-id").ok_or("worker: --worker-id <id> required")?;
    let run_dir = args
        .get("run-dir")
        .ok_or("worker: --run-dir <dir> required (local scratch for checkpoints and logs)")?;
    if args.get("memory-dir").is_some() {
        return Err("worker does not take --memory-dir: every shard would fight over one \
                    live store. Use --exchange-epoch for live cross-shard learning"
            .to_string());
    }
    if args.get("shards").is_some() || args.get("shard-index").is_some() {
        return Err(
            "the worker manifest owns the shard assignment; drop --shards/--shard-index"
                .to_string(),
        );
    }
    if args.get("batch-index").is_some() || args.get("batch-count").is_some() {
        return Err(
            "the elastic worker claims batches off the lease board itself; drop \
             --batch-index/--batch-count"
                .to_string(),
        );
    }
    let sub = args.get_or("cmd", "suite").to_string();
    if !SHARDABLE.contains(&sub.as_str()) {
        return Err(format!(
            "worker --cmd {sub:?} is not shardable; expected one of {SHARDABLE:?}"
        ));
    }
    parse_device(args)?; // refuse an unknown preset before spawning
    parse_chaos(args)?; // refuse a malformed chaos spec likewise
    let manifest = coordinator::WorkerManifest::load(std::path::Path::new(manifest_path))?;
    let program = std::env::current_exe()
        .map_err(|e| format!("resolving the current executable: {e}"))?;
    let mut wc = coordinator::WorkerConfig::new(program, &sub, run_dir, manifest, id);
    let (passthrough, exchange_epoch, max_restarts) = fanout_flags(args)?;
    wc.passthrough = passthrough;
    wc.exchange_epoch = exchange_epoch;
    wc.max_restarts = max_restarts;
    wc.poll_ms = args.get_u64("poll-ms", wc.poll_ms)?;
    let report = coordinator::run_worker(&wc)?;
    print!("{}", report.render());
    Ok(())
}

/// The `skills` subcommand family: introspect and maintain a persistent
/// learned store (`skills.json`, v4 segmented layout) without running
/// anything.
fn run_skills(args: &Args) -> Result<(), String> {
    use kernelskill::memory::long_term::diff::StoreDiff;
    use kernelskill::memory::long_term::{SegmentedSkillStore, SkillStore};

    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("inspect");

    // `skills diff A B` addresses two stores positionally and never needs
    // --memory-dir, so it resolves before the directory requirement.
    if action == "diff" {
        let (a, b) = match &args.positional[..] {
            [_, a, b] => (a.as_str(), b.as_str()),
            _ => return Err("skills diff <a> <b>: two store paths required \
                             (skills.json files or their directories)"
                .to_string()),
        };
        // Accept a directory (memory dir or run dir) or the file itself.
        let resolve = |p: &str| {
            let path = std::path::PathBuf::from(p);
            if path.is_dir() {
                path.join("skills.json")
            } else {
                path
            }
        };
        let (path_a, path_b) = (resolve(a), resolve(b));
        for p in [&path_a, &path_b] {
            if !p.exists() {
                return Err(format!("no skill store at {}", p.display()));
            }
        }
        // `load` folds segmented manifests transparently, so the diff is
        // always over logical content.
        let store_a = SkillStore::load(&path_a)?;
        let store_b = SkillStore::load(&path_b)?;
        let d = StoreDiff::compute(&store_a, &store_b);
        print!("{}", d.render(&path_a.display().to_string(), &path_b.display().to_string()));
        return Ok(());
    }

    let dir = args
        .get("memory-dir")
        .or_else(|| args.get("run-dir"))
        .ok_or("skills: --memory-dir <dir> (or --run-dir <dir>) required")?;
    let dir = std::path::Path::new(dir);
    let path = dir.join("skills.json");
    if !path.exists() {
        return Err(format!("no skill store at {}", path.display()));
    }
    // A run-dir skills.json is *derived* — rebuilt from the checkpointed
    // cells on every open — so mutating it would be silently undone by the
    // next resume/merge. Only the live memory-dir store may be rewritten.
    let needs_memory_dir = |what: &str| {
        if args.get("memory-dir").is_none() {
            Err(format!(
                "skills {what} needs --memory-dir: a run dir's skills.json is rebuilt \
                 from results.jsonl on every open, so {what} there would not stick"
            ))
        } else {
            Ok(())
        }
    };
    match action {
        "inspect" => {
            if let Some(d) = args.get("device") {
                if DeviceSpec::by_name(d).is_none() {
                    println!(
                        "note: {d:?} is not a built-in device preset \
                         (known: {:?})",
                        DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
                    );
                }
            }
            let store = SegmentedSkillStore::open(dir)?;
            print!(
                "{}",
                store.logical().render_inspect(args.get("device"), args.get("case"))
            );
            // The physical layout is opt-in: the default output is a pure
            // function of logical content, so two stores that fold equal
            // (e.g. compacted vs uncompacted) inspect byte-identically.
            if args.has("segments") {
                print!("{}", store.render_layout());
            }
        }
        "gc" => {
            needs_memory_dir("gc")?;
            let max_age = args.get_u64("max-age", 8)?;
            let device = args.get("device");
            if let Some(d) = device {
                if DeviceSpec::by_name(d).is_none() {
                    return Err(format!(
                        "skills gc --device {d:?}: not a built-in device preset \
                         (known: {:?})",
                        DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
                    ));
                }
            }
            let mut store = SegmentedSkillStore::open(dir)?;
            let report = store.gc_device(max_age, device);
            println!("{}", report.render());
            if args.has("dry-run") {
                println!("dry run: {} left untouched", path.display());
            } else {
                store
                    .save()
                    .map_err(|e| format!("rewriting {}: {e}", path.display()))?;
                println!("rewrote {}", path.display());
            }
        }
        "compact" => {
            needs_memory_dir("compact")?;
            let mut store = SegmentedSkillStore::open(dir)?;
            let report = store.compact()?;
            println!("{}", report.render());
        }
        other => {
            return Err(format!(
                "unknown skills action {other:?}; expected `inspect`, `gc`, `compact`, \
                 or `diff`"
            ));
        }
    }
    Ok(())
}

/// The CI bench-smoke path: 2 tasks × 1 seed end-to-end through checkpoint,
/// kill, resume, JSONL reload, and persistent memory.
fn run_smoke() -> Result<(), String> {
    let root = std::env::temp_dir().join(format!("kernelskill-smoke-{}", std::process::id()));
    let out = experiments::smoke(&root)?;
    print!("{out}");
    Ok(())
}
