//! KernelSkill CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands map onto the experiment index in DESIGN.md:
//!   table1 | table2 | table3 | per-round | trajectory   (paper artifacts)
//!   verify-artifacts | calibrate                        (real PJRT path)
//!   run-task --task <id> [--strategy <name>]            (single-task trace)
//!   suite --strategy <name> [--level N]                 (one-strategy suite)
//!   report --run-dir <dir>                              (streamed results)
//!   merge [--watch] --out <dir> <shard-dir>...          (union shard run dirs)
//!   launch --shards N --run-dir <dir> [flags]           (spawn+supervise+merge)
//!   serve --service-dir <dir>                           (job daemon)
//!   jobs <action> [--service-dir <dir>]                 (talk to the daemon)
//!   skills inspect|gc|compact|diff                      (learned-store tooling)
//!   smoke                                               (CI orchestration proof)
//!
//! Every subcommand declares its flags in the [`commands`] registry, so
//! parsing is strict (`util::cli::parse_checked`): a typo'd flag or
//! subcommand is a hard error with a did-you-mean suggestion, and
//! `--help` text is generated from the same declarations.
//!
//! Run identity (which matrix, which strategy, which device, which
//! faults) lives in a typed [`JobSpec`] — parsed once from human flags or
//! from a canonical `--job-spec <file|json>`, validated up front, and
//! executed through one shared entry point. `launch`/`worker` fan the
//! spec out to shard children as a single `--job-spec` artifact instead
//! of replaying individual flags, and the `serve` daemon runs submitted
//! specs the same way, so the batch path, the fan-out path, and the
//! service path cannot drift.
//!
//! Orchestration v2 flags (table*/suite): `--run-dir <dir>` streams every
//! finished cell to `<dir>/results.jsonl`, `--resume` skips cells already
//! checkpointed there, and `--memory-dir <dir>` warm-starts the persistent
//! long-term skill store and rewrites it after each task.
//!
//! Sharding (table*/suite): `--shards N --shard-index i` runs only shard
//! i's deterministic slice of the (strategy, task, seed) matrix into its
//! own `--run-dir`; `merge` unions the per-shard dirs into one whose
//! `report` and skill store are byte-identical to a single-process run.
//! `launch` wraps the whole dance — it spawns the shard processes,
//! restarts crashed ones into `--resume`, streams the merge live, and
//! finalizes it — and `--exchange-epoch N` additionally lets shards trade
//! learned skills at deterministic epoch boundaries mid-run.

use kernelskill::baselines;
use kernelskill::bench_suite;
use kernelskill::coordinator::{self, Branch, JobSpec, LoopConfig, Request};
use kernelskill::device::machine::DeviceSpec;
use kernelskill::harness::{calibrate, experiments, metrics};
use kernelskill::runtime::{self, Registry, Runtime};
use kernelskill::util::cli::{self, Args, CommandDef, FlagDef};
use kernelskill::util::json::Json;
use kernelskill::util::logging::{self, Level};

fn val(name: &'static str, metavar: &'static str, help: &'static str) -> FlagDef {
    FlagDef { name, value: Some(metavar), help }
}

fn sw(name: &'static str, help: &'static str) -> FlagDef {
    FlagDef { name, value: None, help }
}

/// The matrix-identity flags every [`JobSpec`]-running subcommand shares
/// (they are exactly what `JobSpec::from_args` reads).
fn identity_flags() -> Vec<FlagDef> {
    vec![
        val("job-spec", "FILE|JSON", "typed job spec (the whole identity; conflicts with the matrix flags)"),
        val("strategy", "NAME", "strategy to run (default KernelSkill; suite only)"),
        val("level", "N", "task level filter 1-4; 0 = full suite (suite only)"),
        val("take", "N", "deterministic prefix slice of the task list; 0 = all"),
        val("seeds", "N", "number of run seeds (the matrix runs seeds 0..N)"),
        val("suite-seed", "S", "suite-generation seed (task population)"),
        val("workers", "W", "worker-pool size; 0 = this machine's default"),
        val("device", "NAME", "device preset: a100-like|tpu-like|h100-like|consumer-gpu-like|cpu-like"),
        val("chaos", "SPEC", "fault injection: tc=P,drop=P,sigma=S,bias=B,seed=N"),
        sw("no-retrieval-cache", "A/B: per-task-run retrieval memo off"),
        sw("exchange-adaptive", "adaptive (doubling) exchange-epoch schedule"),
    ]
}

/// Placement flags: where a matrix run streams, shards, and exchanges.
/// Deliberately *not* part of the job spec — invariant 12 makes output
/// independent of placement.
fn placement_flags() -> Vec<FlagDef> {
    vec![
        val("run-dir", "DIR", "stream every finished cell to DIR/results.jsonl"),
        sw("resume", "skip cells already checkpointed in --run-dir"),
        val("memory-dir", "DIR", "warm-start + persist the long-term skill store"),
        val("shards", "N", "static sharding: total shard count (requires --run-dir)"),
        val("shard-index", "I", "static sharding: this process's shard"),
        val("batch-count", "B", "elastic fleet: total lease batches"),
        val("batch-index", "K", "elastic fleet: this process's batch"),
        val("exchange-dir", "DIR", "shared dir for live cross-shard skill exchange"),
        val("exchange-epoch", "E", "exchange learned skills every E tasks"),
    ]
}

fn matrix_command(name: &'static str, summary: &'static str) -> CommandDef {
    let mut flags = identity_flags();
    flags.extend(placement_flags());
    CommandDef { name, summary, usage: "[flags]", flags, positional: false }
}

/// The full subcommand registry: one source of truth for strict parsing
/// and for the generated `--help` text.
fn commands() -> Vec<CommandDef> {
    let mut suite = matrix_command("suite", "run one strategy over the task suite");
    suite.flags.push(sw("smoke", "run the tiny end-to-end smoke instead (alias of `smoke`)"));
    let fanout_refused = [
        val("memory-dir", "DIR", "refused here (shards would fight over one live store)"),
        val("shard-index", "I", "refused here (the launcher owns the shard assignment)"),
        val("batch-count", "B", "refused here (elastic workers claim leases themselves)"),
        val("batch-index", "K", "refused here (elastic workers claim leases themselves)"),
    ];
    let mut launch_flags = identity_flags();
    launch_flags.extend([
        val("run-dir", "DIR", "merged output dir (per-shard dirs live under it)"),
        val("cmd", "CMD", "subcommand to fan out (suite|table1|table2|table3|per-round)"),
        val("shards", "N", "number of shard processes to spawn (default 2)"),
        val("manifest", "FILE", "fleet mode: pull workers described in this manifest"),
        sw("exchange", "exchange learned skills at the default epoch"),
        val("exchange-epoch", "E", "exchange learned skills every E tasks"),
        val("max-restarts", "R", "per-shard crash budget (default 2)"),
        val("poll-ms", "MS", "fleet mode: transport poll interval"),
        val("stall-timeout-ms", "MS", "fleet mode: per-worker stall alarm"),
        val("lease-timeout-ms", "MS", "fleet mode: elastic lease re-dispatch timeout"),
    ]);
    launch_flags.extend(fanout_refused);
    let mut worker_flags = identity_flags();
    worker_flags.extend([
        val("manifest", "FILE", "the fleet's worker manifest"),
        val("worker-id", "ID", "this machine's manifest row"),
        val("run-dir", "DIR", "local scratch for checkpoints and logs"),
        val("cmd", "CMD", "subcommand to fan out (must match the fleet's)"),
        sw("exchange", "exchange learned skills at the default epoch"),
        val("exchange-epoch", "E", "exchange learned skills every E tasks"),
        val("max-restarts", "R", "per-shard crash budget (default 2)"),
        val("poll-ms", "MS", "transport poll interval"),
        val("shards", "N", "refused here (the manifest owns the shard assignment)"),
    ]);
    worker_flags.extend(fanout_refused);
    let mut jobs_flags = identity_flags();
    jobs_flags.extend([
        val("service-dir", "DIR", "the daemon's durable service directory"),
        val("cmd", "CMD", "submit: which matrix command the job runs (default suite)"),
        val("deadline-ms", "MS", "submit: wall-clock budget; past it the job is killed"),
    ]);
    vec![
        matrix_command("table1", "Table 1 — success and speedup vs Torch Eager"),
        matrix_command("table2", "Table 2 — memory ablations"),
        matrix_command("table3", "Table 3 — Fast_1"),
        matrix_command("per-round", "per-round refinement efficiency (§5.4)"),
        matrix_command("trajectory", "optimization-trajectory figures"),
        suite,
        CommandDef {
            name: "verify-artifacts",
            summary: "verify every artifact kernel against its reference (real PJRT path)",
            usage: "[flags]",
            flags: vec![
                val("seed", "S", "input-generation seed (default 7)"),
                val("tolerance", "T", "max abs error accepted (default 1e-3)"),
            ],
            positional: false,
        },
        CommandDef {
            name: "calibrate",
            summary: "measure this machine's cost-model calibration table",
            usage: "[flags]",
            flags: vec![val("seed", "S", "input-generation seed (default 7)")],
            positional: false,
        },
        CommandDef {
            name: "run-task",
            summary: "run one task through the closed loop and print its trace",
            usage: "--task <substr> [flags]",
            flags: vec![
                val("task", "SUBSTR", "task id substring to run"),
                val("strategy", "NAME", "strategy to run (default KernelSkill)"),
                val("seed", "S", "run seed (default 0)"),
                val("suite-seed", "S", "suite-generation seed (task population)"),
                val("memory-dir", "DIR", "warm-start + persist the long-term skill store"),
                val("device", "NAME", "device preset the run is priced on"),
                sw("no-retrieval-cache", "A/B: per-task-run retrieval memo off"),
            ],
            positional: false,
        },
        CommandDef {
            name: "report",
            summary: "render tables from a run dir's streamed results.jsonl",
            usage: "--run-dir <dir>",
            flags: vec![val("run-dir", "DIR", "the checkpointed run dir")],
            positional: false,
        },
        CommandDef {
            name: "merge",
            summary: "union per-shard run dirs (checkpoints + skill stores)",
            usage: "--out <dir> <shard-run-dir>... [flags]",
            flags: vec![
                val("out", "DIR", "merged output dir"),
                sw("watch", "follow still-running shards, then finalize"),
                val("interval-ms", "N", "watch poll interval (default 500)"),
            ],
            positional: true,
        },
        CommandDef {
            name: "launch",
            summary: "spawn shard processes, restart crashes, merge byte-identically",
            usage: "--run-dir <dir> [flags]",
            flags: launch_flags,
            positional: false,
        },
        CommandDef {
            name: "worker",
            summary: "run this machine's manifest shard range and publish it",
            usage: "--manifest <file> --worker-id <id> --run-dir <dir> [flags]",
            flags: worker_flags,
            positional: false,
        },
        CommandDef {
            name: "serve",
            summary: "long-lived daemon: accept, queue, and run optimization jobs",
            usage: "--service-dir <dir> [flags]",
            flags: vec![
                val("service-dir", "DIR", "durable queue root (job manifests + endpoint file)"),
                val("memory-dir", "DIR", "shared base skill store (jobs get copy-on-write overlays)"),
                val("queue-capacity", "N", "max queued+running jobs before backpressure (default 16)"),
                val("poll-ms", "MS", "scheduler poll interval (default 50)"),
                val("max-restarts", "R", "per-job crash budget (default 2)"),
                val("port", "P", "localhost TCP port (default 0 = ephemeral)"),
            ],
            positional: false,
        },
        CommandDef {
            name: "jobs",
            summary: "client for a serve daemon: submit/status/watch/cancel/list",
            usage: "<ping|submit|status|watch|cancel|list|shutdown> [job-id] [flags]",
            flags: jobs_flags,
            positional: true,
        },
        CommandDef {
            name: "skills",
            summary: "introspect and maintain a learned store (skills.json v4)",
            usage: "<inspect|gc|compact|diff> [paths] [flags]",
            flags: vec![
                val("memory-dir", "DIR", "the live store directory"),
                val("run-dir", "DIR", "inspect a run dir's derived store instead"),
                val("device", "NAME", "scope to one device partition"),
                val("case", "SUBSTR", "inspect: filter learned cases"),
                sw("segments", "inspect: also print the on-disk segment/head layout"),
                val("max-age", "N", "gc: drop stats older than N generations (default 8)"),
                sw("dry-run", "gc: report without rewriting"),
                val("auto", "N", "compact: fold automatically at N on-disk segments (0 = off)"),
            ],
            positional: true,
        },
        CommandDef {
            name: "smoke",
            summary: "tiny checkpoint/resume/memory end-to-end (CI gate)",
            usage: "",
            flags: vec![],
            positional: false,
        },
    ]
}

/// Resolve the matrix subcommand a fan-out or submission runs: `--cmd`
/// wins, else an explicit `--job-spec` names its own command (don't make
/// the user repeat it), else `suite`.
fn fanout_cmd(args: &Args) -> Result<String, String> {
    match (args.get("cmd"), args.get("job-spec")) {
        (Some(c), _) => Ok(c.to_string()),
        (None, Some(v)) => {
            let spec = if v.trim_start().starts_with('{') {
                JobSpec::parse(v)?
            } else {
                JobSpec::load(std::path::Path::new(v))?
            };
            Ok(spec.cmd)
        }
        (None, None) => Ok("suite".to_string()),
    }
}

/// The supervision flags `launch` and `worker` share: the exchange epoch
/// and the per-shard crash budget.
fn supervision_flags(args: &Args) -> Result<(Option<usize>, usize), String> {
    let mut exchange_epoch = None;
    if args.has("exchange") {
        exchange_epoch = Some(coordinator::DEFAULT_EXCHANGE_EPOCH);
    }
    if args.get("exchange-epoch").is_some() {
        exchange_epoch = Some(args.get_usize("exchange-epoch", 0)?);
    }
    let max_restarts = args.get_usize("max-restarts", 2)?;
    Ok((exchange_epoch, max_restarts))
}

fn parse_device(args: &Args) -> Result<Option<DeviceSpec>, String> {
    match args.get("device") {
        None => Ok(None),
        Some(name) => DeviceSpec::by_name(name).map(Some).ok_or_else(|| {
            format!(
                "unknown device preset {name:?} (known: {:?})",
                DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
            )
        }),
    }
}

/// Join a validated [`JobSpec`] (the run's identity) with this process's
/// placement flags into the experiment config. Identity comes only from
/// the spec; placement only from the CLI.
fn exp_config(spec: &JobSpec, args: &Args) -> Result<experiments::ExpConfig, String> {
    let defaults = experiments::ExpConfig::default();
    let shards = args.get_usize("shards", 1)?;
    let batch_count = args.get_usize("batch-count", 0)?;
    let run_dir = args.get("run-dir").map(std::path::PathBuf::from);
    if shards != 1 && run_dir.is_none() {
        return Err("--shards requires --run-dir (each shard streams its slice to its own \
                    run dir, then `merge` unions them)"
            .to_string());
    }
    if batch_count != 0 && run_dir.is_none() {
        return Err("--batch-count requires --run-dir (each batch streams its slice to its \
                    own run dir; a `worker` loop normally supplies it)"
            .to_string());
    }
    if args.get("batch-index").is_some() && batch_count == 0 {
        return Err("--batch-index requires --batch-count".to_string());
    }
    let exchange_dir = args.get("exchange-dir").map(std::path::PathBuf::from);
    let exchange_epoch = args.get_usize("exchange-epoch", 0)?;
    if exchange_dir.is_none() && exchange_epoch != 0 {
        return Err("--exchange-epoch requires --exchange-dir (every shard of the run must \
                    point at one shared exchange directory)"
            .to_string());
    }
    Ok(experiments::ExpConfig {
        suite_seed: spec.suite_seed,
        run_seeds: (0..spec.seeds as u64).collect(),
        workers: if spec.workers == 0 { defaults.workers } else { spec.workers },
        run_dir,
        resume: args.has("resume"),
        memory_dir: args.get("memory-dir").map(std::path::PathBuf::from),
        shards,
        shard_index: args.get_usize("shard-index", 0)?,
        batch_count,
        batch_index: args.get_usize("batch-index", 0)?,
        exchange_dir,
        exchange_epoch,
        exchange_adaptive: spec.exchange_adaptive,
        device: spec.device_spec(),
        retrieval_cache: spec.retrieval_cache,
        chaos: spec.chaos_config()?,
    })
}

/// Mark a checkpointed run's directory complete once its whole slice of the
/// matrix is on disk, so `merge --watch` and `launch` know tail-following
/// can stop.
fn finish_run_dir(cfg: &experiments::ExpConfig) -> Result<(), String> {
    if let Some(dir) = &cfg.run_dir {
        kernelskill::coordinator::RunDir::open(dir)
            .and_then(|rd| rd.mark_complete())
            .map_err(|e| format!("writing completion marker in {}: {e}", dir.display()))?;
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        if coordinator::ExchangeWaitTimeout::matches(&e) {
            // EX_TEMPFAIL: a supervising launcher relaunches us with
            // `--resume` without burning the crash budget — the missing
            // peer delta is the *peer's* problem (it died or was
            // re-dispatched), not ours.
            std::process::exit(coordinator::EXCHANGE_TIMEOUT_EXIT);
        }
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let registry = commands();
    let args = cli::parse_checked(std::env::args().skip(1), &registry)?;
    if args.has("verbose") {
        logging::set_level(Level::Debug);
    }
    let sub = args.subcommand.as_deref();
    if args.has("help") || sub.is_none() {
        match sub.and_then(|n| registry.iter().find(|c| c.name == n)) {
            Some(c) => print!("{}", cli::render_command_help(c)),
            None => {
                print!("{}", cli::render_global_help(&registry));
                println!(
                    "\nStrategies: KernelSkill, STARK, CudaForge, Astra, PRAGMA, QiMeng,\n\
                     \x20           Kevin-32B, 'w/o memory', 'w/o Short_term memory', \
                     'w/o Long_term memory'"
                );
            }
        }
        return Ok(());
    }
    match sub.unwrap() {
        cmd @ ("table1" | "table2" | "table3" | "per-round" | "trajectory" | "suite") => {
            run_matrix_cmd(cmd, &args)
        }
        "verify-artifacts" => {
            let seed = args.get_u64("seed", 7)?;
            let tol = args.get_f64("tolerance", 1e-3)?;
            let reg = Registry::load("artifacts").map_err(|e| e.to_string())?;
            let mut rt = Runtime::new("artifacts").map_err(|e| e.to_string())?;
            println!("platform = {}", rt.platform());
            let reports =
                runtime::verify_all(&mut rt, &reg, seed, tol).map_err(|e| e.to_string())?;
            let mut failed = 0;
            for r in &reports {
                println!(
                    "{:<20} {:<14} max_abs_err={:<10.2e} {}",
                    r.task,
                    r.variant,
                    r.max_abs_err,
                    if r.passed { "ok" } else { "FAIL" }
                );
                if !r.passed {
                    failed += 1;
                }
            }
            if failed > 0 {
                return Err(format!("{failed} variants failed verification"));
            }
            println!("all {} variants verified", reports.len());
            Ok(())
        }
        "calibrate" => {
            let seed = args.get_u64("seed", 7)?;
            let rows = calibrate::calibrate(seed).map_err(|e| e.to_string())?;
            println!("{}", calibrate::render(&rows));
            Ok(())
        }
        "run-task" => run_task_cmd(&args),
        "report" => {
            let dir = args.get("run-dir").ok_or("--run-dir <dir> required")?;
            let rendered = experiments::report_run_dir(std::path::Path::new(dir))?;
            println!("{rendered}");
            Ok(())
        }
        "merge" => run_merge(&args),
        "launch" => run_launch(&args),
        "worker" => run_worker_cmd(&args),
        "serve" => run_serve(&args),
        "jobs" => run_jobs(&args),
        "skills" => run_skills(&args),
        "smoke" => run_smoke(),
        other => Err(format!("unknown subcommand {other:?}")), // parse_checked refused it already
    }
}

/// The shared matrix entry point: every way a matrix run starts — human
/// flags, a fanned-out `--job-spec`, or a daemon job — lands here with
/// the same validated [`JobSpec`].
fn run_matrix_cmd(cmd: &str, args: &Args) -> Result<(), String> {
    if cmd == "suite" && args.has("smoke") {
        return run_smoke();
    }
    let spec = JobSpec::from_args(cmd, args)?;
    let cfg = exp_config(&spec, args)?;
    match cmd {
        "table1" => {
            let (rendered, _) = experiments::table1(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 1 — Success and Speedup vs Torch Eager\n{rendered}");
        }
        "table2" => {
            let (rendered, _) = experiments::table2(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 2 — Memory ablations\n{rendered}");
        }
        "table3" => {
            let (rendered, _) = experiments::table3(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Table 3 — Fast_1\n{rendered}");
        }
        "per-round" => {
            let (rendered, _) = experiments::per_round_efficiency(&cfg)?;
            finish_run_dir(&cfg)?;
            println!("Per-round refinement efficiency (§5.4)\n{rendered}");
        }
        "trajectory" => println!("{}", experiments::trajectory_figures(&cfg)),
        "suite" => return run_suite_job(&spec, &cfg),
        other => return Err(format!("{other:?} is not a matrix command")),
    }
    Ok(())
}

fn run_suite_job(spec: &JobSpec, cfg: &experiments::ExpConfig) -> Result<(), String> {
    let strategy = baselines::by_name(&spec.strategy)
        .ok_or_else(|| format!("unknown strategy {}", spec.strategy))?;
    let mut tasks = if spec.level == 0 {
        bench_suite::full_suite(cfg.suite_seed)
    } else {
        bench_suite::level_suite(cfg.suite_seed, spec.level as u8)
    };
    // Deterministic prefix slice: small fixed matrices for smokes and the
    // sharding CI job.
    if spec.take > 0 {
        tasks.truncate(spec.take);
    }
    let suite = coordinator::run_suite_with(
        &tasks,
        &strategy,
        &cfg.loop_cfg(),
        &cfg.run_seeds,
        cfg.workers,
        &cfg.suite_opts(),
    )?;
    let split = metrics::by_level(&suite.results);
    for (i, lv) in split.iter().enumerate() {
        if lv.is_empty() {
            continue;
        }
        let c = metrics::cell(lv, strategy.rounds);
        println!(
            "L{}: n={} success={:.2} speedup={:.2} fast1={:.2} rounds={:.1}",
            i + 1,
            c.n,
            c.success,
            c.speedup,
            c.fast1,
            c.mean_rounds
        );
    }
    finish_run_dir(cfg)?;
    if let Some(dir) = &cfg.run_dir {
        println!("checkpoint streamed to {}", dir.display());
    }
    Ok(())
}

fn run_task_cmd(args: &Args) -> Result<(), String> {
    let task_id = args.get("task").ok_or("--task <id> required")?;
    let strat_name = args.get_or("strategy", "KernelSkill");
    let strategy = baselines::by_name(strat_name)
        .ok_or_else(|| format!("unknown strategy {strat_name}"))?;
    let suite_seed = args.get_u64("suite-seed", 42)?;
    let tasks = bench_suite::full_suite(suite_seed);
    let task = tasks
        .iter()
        .find(|t| t.id.contains(task_id))
        .ok_or_else(|| format!("no task matching {task_id}"))?;
    let mut cfg = LoopConfig {
        run_seed: args.get_u64("seed", 0)?,
        memory_dir: args.get("memory-dir").map(std::path::PathBuf::from),
        retrieval_cache: !args.has("no-retrieval-cache"),
        ..LoopConfig::default()
    };
    // The device preset keys the skill partition the observations land in,
    // so run-task must honor it like every suite command.
    if let Some(dev) = parse_device(args)? {
        cfg.dev = dev;
    }
    let r = coordinator::run_task(task, &strategy, &cfg);
    // Standalone runs persist their own observations (in a suite the
    // scheduler owns the write cycle), so learning accumulates across
    // repeated run-task invocations too.
    if let Some(dir) = &cfg.memory_dir {
        let path = dir.join("skills.json");
        let mut store = kernelskill::memory::long_term::SegmentedSkillStore::open(dir)?;
        // One completed task = one fold epoch: the generation clock
        // advances even when the run produced no observations, which is
        // what ages stats that stop being re-observed. Under the v4
        // layout advancing rotates the previous epochs' head into an
        // immutable segment instead of rewriting accumulated history.
        let generation = store.generation() + 1;
        store
            .advance_to(generation)
            .map_err(|e| format!("rotating skill store head: {e}"))?;
        store.merge(&r.skill_obs);
        store
            .save()
            .map_err(|e| format!("saving skill store: {e}"))?;
        println!(
            "memory: {} observation(s) merged into {} (generation {})",
            r.skill_obs.len(),
            path.display(),
            generation
        );
    }
    println!(
        "{} [{}]: success={} best={:.3}x seed={:?} promotions={} repairs={}",
        r.task_id,
        r.strategy,
        r.success,
        r.best_speedup,
        r.seed_speedup,
        r.promotions,
        r.repair_attempts
    );
    for rec in &r.rounds {
        let what = match &rec.branch {
            Branch::Optimize(m) => format!("optimize[{}]", m.name()),
            Branch::Repair(f) => format!("repair[{f}]"),
            Branch::Revert => "revert".into(),
            Branch::Converged => "converged".into(),
        };
        println!(
            "  round {:>2}: {:<30} ok={} speedup={:?}",
            rec.round,
            what,
            rec.compiled && rec.correct,
            rec.speedup
        );
    }
    Ok(())
}

fn run_merge(args: &Args) -> Result<(), String> {
    let out = args.get("out").ok_or("--out <dir> required")?;
    let watch = args.has("watch");
    let inputs: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    if inputs.is_empty() {
        return Err(
            "usage: merge [--watch [--interval-ms N]] --out <dir> <shard-run-dir> \
             [<shard-run-dir>...]"
                .to_string(),
        );
    }
    let report = if watch {
        // Streaming merge: follow the shard checkpoints while their
        // processes are still running, then finalize once every input
        // carries the `complete` marker. The result is byte-identical to
        // a one-shot merge of the finished dirs.
        let interval = args.get_u64("interval-ms", 500)?.max(1);
        let mut watcher = coordinator::MergeWatcher::new(std::path::Path::new(out), &inputs)?;
        let mut last = String::new();
        loop {
            let status = watcher.poll()?;
            let line = status.render();
            if line != last {
                println!("watch: {line}");
                last = line;
            }
            if status.all_complete() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(interval));
        }
        watcher.finalize()?
    } else {
        coordinator::merge_run_dirs(std::path::Path::new(out), &inputs)?
    };
    print!("{}", report.render());
    println!("merged run dir: {out} (report it with: report --run-dir {out})");
    Ok(())
}

fn run_launch(args: &Args) -> Result<(), String> {
    let run_dir = args.get("run-dir").ok_or("--run-dir <dir> required")?;
    if args.get("memory-dir").is_some() {
        return Err("launch does not take --memory-dir: every shard would fight over \
                    one live store. Use --exchange-epoch for live cross-shard \
                    learning, or run the shards by hand with per-shard copies of the \
                    same skills.json"
            .to_string());
    }
    if args.get("shard-index").is_some() {
        return Err("launch owns the shard assignment; drop --shard-index".to_string());
    }
    if args.get("batch-index").is_some() || args.get("batch-count").is_some() {
        return Err("batch slicing is elastic-fleet machinery: describe the fleet in \
                    an elastic manifest (total_batches + lease transport) and use \
                    launch --manifest / worker instead"
            .to_string());
    }
    // Fleet mode: a worker manifest turns `launch` into the pull-based
    // cross-machine coordinator. `--manifest <file>` is canonical; a
    // non-numeric `--workers <file>` is accepted too (a numeric value
    // keeps its meaning: the children's worker-pool size) — but only when
    // it names a real file, so a typo'd pool size gets a pointed error
    // instead of a silent mode switch.
    if let Some(path) = args.get("manifest") {
        return run_fleet(args, path, run_dir);
    }
    if let Some(v) = args.get("workers").filter(|v| v.parse::<usize>().is_err()) {
        if std::path::Path::new(v).is_file() {
            return run_fleet(args, v, run_dir);
        }
        return Err(format!(
            "--workers {v:?} is neither a worker-pool size nor an existing worker \
             manifest file (fleet mode prefers --manifest <file>)"
        ));
    }
    let sub = fanout_cmd(args)?;
    if !coordinator::SHARDABLE.contains(&sub.as_str()) {
        return Err(format!(
            "launch --cmd {sub:?} is not shardable; expected one of {:?}",
            coordinator::SHARDABLE
        ));
    }
    let spec = JobSpec::from_args(&sub, args)?;
    let program = std::env::current_exe()
        .map_err(|e| format!("resolving the current executable: {e}"))?;
    let shards = args.get_usize("shards", 2)?;
    let mut lc = coordinator::LaunchConfig::new(program, &sub, run_dir, shards);
    // The children inherit the whole matrix identity as one canonical
    // artifact instead of a replayed flag list; the spec file doubles as
    // the merged run's identity record.
    std::fs::create_dir_all(run_dir).map_err(|e| format!("creating {run_dir}: {e}"))?;
    let spec_path = std::path::Path::new(run_dir).join("job-spec.json");
    spec.save(&spec_path)?;
    lc.passthrough = vec!["--job-spec".to_string(), spec_path.display().to_string()];
    let (exchange_epoch, max_restarts) = supervision_flags(args)?;
    lc.exchange_epoch = exchange_epoch;
    lc.max_restarts = max_restarts;
    let report = coordinator::launch(&lc)?;
    print!("{}", report.render());
    println!("merged run dir: {run_dir} (report it with: report --run-dir {run_dir})");
    Ok(())
}

/// `launch --manifest <file>`: the cross-machine coordinator. Spawns
/// nothing — it pulls every worker's published run dirs through their
/// transports, merges them live, and relays exchange deltas between
/// workers. The workers themselves are started out of band with the
/// `worker` subcommand.
fn run_fleet(args: &Args, manifest_path: &str, run_dir: &str) -> Result<(), String> {
    if args.get("shards").is_some() {
        return Err("launch --manifest: the manifest owns the shard assignment; drop --shards"
            .to_string());
    }
    // Matrix and supervision flags must live on the (uniform) `worker`
    // invocations; a flag here would silently apply to nothing.
    let matrix_flags = ["cmd", "exchange", "exchange-epoch", "strategy", "level", "take",
        "seeds", "suite-seed", "device", "chaos", "max-restarts", "no-retrieval-cache",
        "job-spec"];
    for flag in matrix_flags {
        if args.get(flag).is_some() || args.has(flag) {
            return Err(format!(
                "launch --manifest: --{flag} belongs on the `worker` invocations (every \
                 worker must run the same matrix flags); the coordinator only pulls, \
                 relays, and merges"
            ));
        }
    }
    // `--workers` doubles as the manifest-path spelling; any *other* value
    // here is the children's pool size and belongs on the workers too.
    if let Some(w) = args.get("workers") {
        if w != manifest_path {
            return Err(
                "launch --manifest: --workers <N> belongs on the `worker` invocations; \
                 the coordinator spawns nothing"
                    .to_string(),
            );
        }
    }
    let manifest =
        coordinator::WorkerManifest::load(std::path::Path::new(manifest_path))?;
    let mut fc = coordinator::FleetConfig::new(manifest, run_dir);
    fc.poll_ms = args.get_u64("poll-ms", fc.poll_ms)?;
    fc.stall_timeout_ms = args.get_u64("stall-timeout-ms", fc.stall_timeout_ms)?;
    fc.lease_timeout_ms = args.get_u64("lease-timeout-ms", fc.lease_timeout_ms)?;
    let report = coordinator::launch_workers(&fc)?;
    print!("{}", report.render());
    println!("merged run dir: {run_dir} (report it with: report --run-dir {run_dir})");
    Ok(())
}

/// The `worker` subcommand: run this machine's manifest row of a
/// cross-machine launch — spawn and supervise its shard range, publish
/// through its transport, pull the fleet's exchange deltas down.
fn run_worker_cmd(args: &Args) -> Result<(), String> {
    let manifest_path = args
        .get("manifest")
        .ok_or("worker: --manifest <workers.json> required")?;
    let id = args.get("worker-id").ok_or("worker: --worker-id <id> required")?;
    let run_dir = args
        .get("run-dir")
        .ok_or("worker: --run-dir <dir> required (local scratch for checkpoints and logs)")?;
    if args.get("memory-dir").is_some() {
        return Err("worker does not take --memory-dir: every shard would fight over one \
                    live store. Use --exchange-epoch for live cross-shard learning"
            .to_string());
    }
    if args.get("shards").is_some() || args.get("shard-index").is_some() {
        return Err(
            "the worker manifest owns the shard assignment; drop --shards/--shard-index"
                .to_string(),
        );
    }
    if args.get("batch-index").is_some() || args.get("batch-count").is_some() {
        return Err(
            "the elastic worker claims batches off the lease board itself; drop \
             --batch-index/--batch-count"
                .to_string(),
        );
    }
    let sub = fanout_cmd(args)?;
    if !coordinator::SHARDABLE.contains(&sub.as_str()) {
        return Err(format!(
            "worker --cmd {sub:?} is not shardable; expected one of {:?}",
            coordinator::SHARDABLE
        ));
    }
    let mut spec = JobSpec::from_args(&sub, args)?;
    let manifest = coordinator::WorkerManifest::load(std::path::Path::new(manifest_path))?;
    // Heterogeneous fleets: the manifest row's device pins this machine.
    // It merges into the job spec — not an extra child flag — so shard
    // children still receive exactly one identity artifact. A device that
    // collides with one already in the spec is refused up front: the two
    // would silently disagree about which wins.
    if let Some(dev) = manifest.worker(id).and_then(|w| w.device.clone()) {
        if spec.device.is_some() {
            return Err(format!(
                "worker {id:?}: the manifest assigns device {dev:?} but this invocation \
                 already carries a device; drop one of them"
            ));
        }
        spec.device = Some(dev);
        spec = spec.normalized()?;
    }
    let program = std::env::current_exe()
        .map_err(|e| format!("resolving the current executable: {e}"))?;
    let mut wc = coordinator::WorkerConfig::new(program, &sub, run_dir, manifest, id);
    std::fs::create_dir_all(run_dir).map_err(|e| format!("creating {run_dir}: {e}"))?;
    let spec_path = std::path::Path::new(run_dir).join("job-spec.json");
    spec.save(&spec_path)?;
    wc.passthrough = vec!["--job-spec".to_string(), spec_path.display().to_string()];
    let (exchange_epoch, max_restarts) = supervision_flags(args)?;
    wc.exchange_epoch = exchange_epoch;
    wc.max_restarts = max_restarts;
    wc.poll_ms = args.get_u64("poll-ms", wc.poll_ms)?;
    let report = coordinator::run_worker(&wc)?;
    print!("{}", report.render());
    Ok(())
}

/// The `serve` subcommand: the long-lived kernel-optimization-as-a-service
/// daemon. Jobs arrive over localhost TCP, queue durably as per-job
/// manifests under the service dir, and run one at a time through the
/// same matrix entry point every other path uses.
fn run_serve(args: &Args) -> Result<(), String> {
    let service_dir = args
        .get("service-dir")
        .ok_or("serve: --service-dir <dir> required (the durable queue + endpoint file)")?;
    let program = std::env::current_exe()
        .map_err(|e| format!("resolving the current executable: {e}"))?;
    let mut cfg =
        coordinator::ServiceConfig::new(std::path::PathBuf::from(service_dir), program);
    cfg.base_memory = args.get("memory-dir").map(std::path::PathBuf::from);
    cfg.queue_capacity = args.get_usize("queue-capacity", cfg.queue_capacity)?;
    cfg.poll_ms = args.get_u64("poll-ms", cfg.poll_ms)?;
    cfg.max_restarts = args.get_usize("max-restarts", cfg.max_restarts)?;
    let port = args.get_u64("port", cfg.port as u64)?;
    if port > u16::MAX as u64 {
        return Err(format!("--port {port} is out of range (max 65535)"));
    }
    cfg.port = port as u16;
    coordinator::serve(&cfg)
}

/// One line of `jobs status/list/watch` output.
fn render_snapshot(snap: &Json) -> String {
    let s = |k: &str| snap.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string();
    let n = |k: &str| snap.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let mut line = format!(
        "{:<12} {:<9} cmd={} cells={} restarts={}",
        s("job"),
        s("state"),
        s("cmd"),
        n("cells"),
        n("restarts")
    );
    if let Some(e) = snap.get("error").and_then(|v| v.as_str()) {
        line.push_str(&format!("  error: {e}"));
    }
    line
}

/// The `jobs` subcommand family: the client side of the service protocol.
fn run_jobs(args: &Args) -> Result<(), String> {
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or("jobs <ping|submit|status|watch|cancel|list|shutdown> — run `jobs --help`")?;
    let service_dir = args
        .get("service-dir")
        .ok_or("jobs: --service-dir <dir> required (the daemon's durable service directory)")?;
    let client = coordinator::Client::connect(std::path::Path::new(service_dir))?;
    let job_arg = || {
        args.positional
            .get(1)
            .cloned()
            .ok_or_else(|| format!("jobs {action}: <job-id> required (e.g. job-000001)"))
    };
    match action {
        "ping" => {
            client.request(&Request::Ping)?;
            println!("daemon behind {service_dir} is up");
        }
        "submit" => {
            let sub = fanout_cmd(args)?;
            let spec = JobSpec::from_args(&sub, args)?;
            let deadline_ms = match args.get("deadline-ms") {
                None => None,
                Some(v) => {
                    Some(v.parse::<u64>().map_err(|e| format!("--deadline-ms: {e}"))?)
                }
            };
            let reply = client.request(&Request::Submit { spec, deadline_ms })?;
            let job = reply
                .get("job")
                .and_then(|j| j.as_str())
                .ok_or("daemon accepted the job but returned no id")?
                .to_string();
            println!(
                "submitted {job} (follow it with: jobs watch {job} --service-dir {service_dir})"
            );
        }
        "status" => {
            let reply = client.request(&Request::Status { job: job_arg()? })?;
            let snap = reply.get("status").ok_or("daemon reply carried no status")?;
            println!("{}", render_snapshot(snap));
        }
        "list" => {
            let reply = client.request(&Request::List)?;
            let jobs = reply
                .get("jobs")
                .and_then(|j| j.as_arr())
                .ok_or("daemon reply carried no job list")?;
            if jobs.is_empty() {
                println!("no jobs");
            }
            for snap in jobs {
                println!("{}", render_snapshot(snap));
            }
        }
        "cancel" => {
            let job = job_arg()?;
            let reply = client.request(&Request::Cancel { job: job.clone() })?;
            let state = reply.get("state").and_then(|s| s.as_str()).unwrap_or("?");
            if matches!(reply.get("cancelling"), Some(Json::Bool(true))) {
                println!("{job}: cancelling (currently {state})");
            } else if let Some(note) = reply.get("note").and_then(|n| n.as_str()) {
                println!("{job}: {state} ({note})");
            } else {
                println!("{job}: {state}");
            }
        }
        "watch" => {
            let job = job_arg()?;
            let end = client.watch(&job, |event| {
                if event.get("event").and_then(|e| e.as_str()) == Some("state") {
                    println!("{}", render_snapshot(event));
                }
            })?;
            let state = end.get("state").and_then(|s| s.as_str()).unwrap_or("?");
            if state != "done" {
                let detail = end
                    .get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("no error detail");
                return Err(format!("{job} finished {state}: {detail}"));
            }
            println!("{job} done");
        }
        "shutdown" => {
            client.request(&Request::Shutdown)?;
            println!(
                "daemon draining: it exits once the running job (if any) finishes; \
                 queued jobs stay durably queued for the next daemon"
            );
        }
        other => {
            return Err(format!(
                "unknown jobs action {other:?}; expected ping, submit, status, watch, \
                 cancel, list, or shutdown"
            ));
        }
    }
    Ok(())
}

/// The `skills` subcommand family: introspect and maintain a persistent
/// learned store (`skills.json`, v4 segmented layout) without running
/// anything.
fn run_skills(args: &Args) -> Result<(), String> {
    use kernelskill::memory::long_term::diff::StoreDiff;
    use kernelskill::memory::long_term::{SegmentedSkillStore, SkillStore};

    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("inspect");

    // `skills diff A B` addresses two stores positionally and never needs
    // --memory-dir, so it resolves before the directory requirement.
    if action == "diff" {
        let (a, b) = match &args.positional[..] {
            [_, a, b] => (a.as_str(), b.as_str()),
            _ => return Err("skills diff <a> <b>: two store paths required \
                             (skills.json files or their directories)"
                .to_string()),
        };
        // Accept a directory (memory dir or run dir) or the file itself.
        let resolve = |p: &str| {
            let path = std::path::PathBuf::from(p);
            if path.is_dir() {
                path.join("skills.json")
            } else {
                path
            }
        };
        let (path_a, path_b) = (resolve(a), resolve(b));
        for p in [&path_a, &path_b] {
            if !p.exists() {
                return Err(format!("no skill store at {}", p.display()));
            }
        }
        // `load` folds segmented manifests transparently, so the diff is
        // always over logical content.
        let store_a = SkillStore::load(&path_a)?;
        let store_b = SkillStore::load(&path_b)?;
        let d = StoreDiff::compute(&store_a, &store_b);
        print!("{}", d.render(&path_a.display().to_string(), &path_b.display().to_string()));
        return Ok(());
    }

    let dir = args
        .get("memory-dir")
        .or_else(|| args.get("run-dir"))
        .ok_or("skills: --memory-dir <dir> (or --run-dir <dir>) required")?;
    let dir = std::path::Path::new(dir);
    let path = dir.join("skills.json");
    if !path.exists() {
        return Err(format!("no skill store at {}", path.display()));
    }
    // A run-dir skills.json is *derived* — rebuilt from the checkpointed
    // cells on every open — so mutating it would be silently undone by the
    // next resume/merge. Only the live memory-dir store may be rewritten.
    let needs_memory_dir = |what: &str| {
        if args.get("memory-dir").is_none() {
            Err(format!(
                "skills {what} needs --memory-dir: a run dir's skills.json is rebuilt \
                 from results.jsonl on every open, so {what} there would not stick"
            ))
        } else {
            Ok(())
        }
    };
    match action {
        "inspect" => {
            if let Some(d) = args.get("device") {
                if DeviceSpec::by_name(d).is_none() {
                    println!(
                        "note: {d:?} is not a built-in device preset \
                         (known: {:?})",
                        DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
                    );
                }
            }
            let store = SegmentedSkillStore::open(dir)?;
            print!(
                "{}",
                store.logical().render_inspect(args.get("device"), args.get("case"))
            );
            // The physical layout is opt-in: the default output is a pure
            // function of logical content, so two stores that fold equal
            // (e.g. compacted vs uncompacted) inspect byte-identically.
            if args.has("segments") {
                print!("{}", store.render_layout());
            }
        }
        "gc" => {
            needs_memory_dir("gc")?;
            let max_age = args.get_u64("max-age", 8)?;
            let device = args.get("device");
            if let Some(d) = device {
                if DeviceSpec::by_name(d).is_none() {
                    return Err(format!(
                        "skills gc --device {d:?}: not a built-in device preset \
                         (known: {:?})",
                        DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
                    ));
                }
            }
            let mut store = SegmentedSkillStore::open(dir)?;
            let report = store.gc_device(max_age, device);
            println!("{}", report.render());
            if args.has("dry-run") {
                println!("dry run: {} left untouched", path.display());
            } else {
                store
                    .save()
                    .map_err(|e| format!("rewriting {}: {e}", path.display()))?;
                println!("rewrote {}", path.display());
            }
        }
        "compact" => {
            needs_memory_dir("compact")?;
            let mut store = SegmentedSkillStore::open(dir)?;
            // `--auto N` records a compaction policy in the manifest (the
            // daemon and long-lived writers apply it at fold boundaries)
            // instead of folding right now.
            if let Some(v) = args.get("auto") {
                let n: u64 = v.parse().map_err(|e| format!("--auto: {e}"))?;
                store.set_auto_compact_segments(n)?;
                store
                    .save()
                    .map_err(|e| format!("rewriting {}: {e}", path.display()))?;
                if n == 0 {
                    println!("auto-compaction off");
                } else {
                    println!(
                        "auto-compaction at {n} segment(s) (applies at fold boundaries)"
                    );
                }
            } else {
                let report = store.compact()?;
                println!("{}", report.render());
            }
        }
        other => {
            return Err(format!(
                "unknown skills action {other:?}; expected `inspect`, `gc`, `compact`, \
                 or `diff`"
            ));
        }
    }
    Ok(())
}

/// The CI bench-smoke path: 2 tasks × 1 seed end-to-end through checkpoint,
/// kill, resume, JSONL reload, and persistent memory.
fn run_smoke() -> Result<(), String> {
    let root = std::env::temp_dir().join(format!("kernelskill-smoke-{}", std::process::id()));
    let out = experiments::smoke(&root)?;
    print!("{out}");
    Ok(())
}
