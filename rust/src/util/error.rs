//! Minimal error plumbing (anyhow is not vendored offline).
//!
//! Provides the `anyhow` subset this repo uses: a string-backed [`Error`]
//! that any `std::error::Error` converts into via `?`, a [`Result`] alias,
//! and a [`Context`] extension trait for `Result` and `Option`.

use std::fmt;

/// A flattened error message with its context chain pre-rendered.
#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` analog).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`; the
// blanket conversion below would otherwise overlap with `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

// Bridges between the typed [`Error`] and the `Result<_, String>` plumbing
// the coordinator layer grew up with: typed helpers can be called with `?`
// from string-error functions and vice versa, so the panic-path audit can
// convert call sites incrementally instead of all at once.
impl From<Error> for String {
    fn from(e: Error) -> String {
        e.msg
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_chains_on_result() {
        let e = io_fail().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        let e = io_fail().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert!(e.to_string().starts_with("pass 2: "));
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing field").unwrap_err().to_string(), "missing field");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }
}
