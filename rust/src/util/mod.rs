//! Infrastructure layer: deterministic RNG, statistics, JSON, CLI parsing,
//! thread pool, lazy statics, error plumbing, and logging. These stand in
//! for rand/serde/clap/tokio/once_cell/anyhow, which are unavailable in the
//! offline build environment (DESIGN.md §Infrastructure).

pub mod alloc_count;
pub mod cli;
pub mod error;
pub mod fsum;
pub mod json;
pub mod lazy;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
