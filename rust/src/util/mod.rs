//! Infrastructure layer: deterministic RNG, statistics, JSON, CLI parsing,
//! thread pool, and logging. These stand in for rand/serde/clap/tokio,
//! which are unavailable in the offline build environment (DESIGN.md
//! §Infrastructure).

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
