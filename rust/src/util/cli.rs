//! Hand-rolled CLI argument parsing (clap is not vendored offline).
//!
//! Grammar: `kernelskill <subcommand> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table1", "--seeds", "3", "--quiet", "--out=x.json"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get("seeds"), Some("3"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(a.has("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "5", "--r", "0.25"]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("r", 0.0).unwrap(), 0.25);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["x", "--n", "zz"]).get_usize("n", 1).is_err());
    }

    #[test]
    fn switch_at_end_and_negative_number_value() {
        let a = parse(&["run", "--thresh", "-0.5", "--verbose"]);
        assert_eq!(a.get_f64("thresh", 0.0).unwrap(), -0.5);
        assert!(a.has("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }
}
