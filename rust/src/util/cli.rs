//! Hand-rolled CLI argument parsing (clap is not vendored offline).
//!
//! Grammar: `kernelskill <subcommand> [--flag value]... [--switch]...`
//!
//! Two parsers live here. [`Args::parse`] is the original lenient pass:
//! it guesses whether `--name` takes a value by peeking at the next
//! token, and it accepts any flag name — a typo like `--sees 3` used to
//! silently run with the default seed count. [`parse_checked`] is the
//! strict pass `main` uses: every subcommand declares its flags as
//! [`FlagDef`]s in a [`CommandDef`] registry, so value flags always
//! consume exactly one value, switches never swallow a following
//! positional, unknown flags and subcommands are hard errors with a
//! did-you-mean suggestion, and per-subcommand `--help` text is
//! generated from the same declarations (one source of truth).

use std::collections::BTreeMap;

/// One declared flag of a subcommand.
#[derive(Debug, Clone, Copy)]
pub struct FlagDef {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// `Some(metavar)` for a value flag (`--seeds N`), `None` for a
    /// switch (`--resume`).
    pub value: Option<&'static str>,
    /// One-line help text.
    pub help: &'static str,
}

/// One declared subcommand: its flags, and whether it takes positional
/// arguments (e.g. `merge <shard-dirs>...`, `skills <action>`).
#[derive(Debug, Clone)]
pub struct CommandDef {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line summary for the global usage listing.
    pub summary: &'static str,
    /// Usage tail after the subcommand name, e.g. `"[flags]"` or
    /// `"<action> [flags]"`.
    pub usage: &'static str,
    /// Declared flags (value flags and switches).
    pub flags: Vec<FlagDef>,
    /// Whether bare positional arguments are accepted.
    pub positional: bool,
}

/// Switches accepted by every subcommand.
const GLOBAL_SWITCHES: [FlagDef; 2] = [
    FlagDef { name: "help", value: None, help: "print this subcommand's usage and exit" },
    FlagDef { name: "verbose", value: None, help: "per-cell progress on stderr" },
];

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{name}: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Strict parse against a command registry. Returns the same [`Args`]
/// shape the lenient parser produces, but:
///
/// - an unknown subcommand or flag is a hard error (with a
///   did-you-mean suggestion when a declared name is within edit
///   distance 2);
/// - a declared value flag always consumes exactly one value, and
///   `--flag` at end-of-line or followed by another `--flag` is an
///   error instead of a silent switch;
/// - a declared switch never consumes the next token (so
///   `merge --watch <dir>` keeps `<dir>` positional without hacks);
/// - `--switch=value` is an error;
/// - positional arguments are only accepted where the command declares
///   them.
///
/// `--help`/`--verbose` are accepted everywhere. A bare `--help` (or no
/// arguments at all) parses to `subcommand: None` so `main` can print
/// the global usage.
pub fn parse_checked<I: IntoIterator<Item = String>>(
    argv: I,
    commands: &[CommandDef],
) -> Result<Args, String> {
    let mut args = Args::default();
    let mut it = argv.into_iter().peekable();
    if let Some(first) = it.peek() {
        if !first.starts_with('-') {
            args.subcommand = it.next();
        }
    }
    let cmd = match &args.subcommand {
        None => {
            // No subcommand: accept only global switches (`--help`).
            for a in it {
                match a.strip_prefix("--") {
                    Some(name) if GLOBAL_SWITCHES.iter().any(|f| f.name == name) => {
                        args.switches.push(name.to_string());
                    }
                    _ => return Err(format!("unexpected argument {a:?} before a subcommand")),
                }
            }
            return Ok(args);
        }
        Some(name) => commands.iter().find(|c| c.name == *name).ok_or_else(|| {
            let mut msg = format!("unknown subcommand {name:?}");
            if let Some(s) = suggest(name, commands.iter().map(|c| c.name)) {
                msg.push_str(&format!(" (did you mean {s:?}?)"));
            }
            msg.push_str("; run with no arguments for usage");
            msg
        })?,
    };
    let lookup = |name: &str| {
        cmd.flags
            .iter()
            .chain(GLOBAL_SWITCHES.iter())
            .find(|f| f.name == name)
            .copied()
    };
    while let Some(a) = it.next() {
        let Some(name) = a.strip_prefix("--") else {
            if !cmd.positional {
                return Err(format!(
                    "{}: unexpected argument {a:?}; run `{} --help` for usage",
                    cmd.name, cmd.name
                ));
            }
            args.positional.push(a);
            continue;
        };
        if name.is_empty() {
            return Err("bare `--` not supported".into());
        }
        let (bare, inline) = match name.split_once('=') {
            Some((k, v)) => (k, Some(v)),
            None => (name, None),
        };
        let def = lookup(bare).ok_or_else(|| {
            let mut msg = format!("{}: unknown flag --{bare}", cmd.name);
            if let Some(s) =
                suggest(bare, cmd.flags.iter().chain(GLOBAL_SWITCHES.iter()).map(|f| f.name))
            {
                msg.push_str(&format!(" (did you mean --{s}?)"));
            }
            msg.push_str(&format!("; run `{} --help` for usage", cmd.name));
            msg
        })?;
        match (def.value, inline) {
            (Some(_), Some(v)) => {
                args.flags.insert(bare.to_string(), v.to_string());
            }
            (Some(metavar), None) => {
                let next_is_value = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                if !next_is_value {
                    return Err(format!(
                        "{}: --{bare} requires a value <{metavar}>",
                        cmd.name
                    ));
                }
                args.flags.insert(bare.to_string(), it.next().unwrap());
            }
            (None, Some(_)) => {
                return Err(format!(
                    "{}: --{bare} is a switch and takes no value",
                    cmd.name
                ));
            }
            (None, None) => args.switches.push(bare.to_string()),
        }
    }
    Ok(args)
}

/// The closest declared name within edit distance 2, for did-you-mean.
fn suggest<'a>(typo: &str, names: impl Iterator<Item = &'a str>) -> Option<&'a str> {
    names
        .map(|n| (edit_distance(typo, n), n))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, n)| n)
}

/// Classic Levenshtein distance, O(|a|·|b|) with a rolling row.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

/// Render one subcommand's `--help` text from its declarations.
pub fn render_command_help(cmd: &CommandDef) -> String {
    let mut out = format!("kernelskill {} {}\n  {}\n", cmd.name, cmd.usage, cmd.summary);
    if !cmd.flags.is_empty() {
        out.push_str("\nFlags:\n");
        let spelled: Vec<(String, &str)> = cmd
            .flags
            .iter()
            .map(|f| {
                let left = match f.value {
                    Some(metavar) => format!("--{} <{}>", f.name, metavar),
                    None => format!("--{}", f.name),
                };
                (left, f.help)
            })
            .collect();
        let width = spelled.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
        for (left, help) in spelled {
            out.push_str(&format!("  {left:width$}  {help}\n"));
        }
    }
    out
}

/// Render the global usage listing from the registry.
pub fn render_global_help(commands: &[CommandDef]) -> String {
    let mut out = String::from(
        "kernelskill — KernelSkill: multi-agent GPU kernel optimization\n\nUsage: \
         kernelskill <subcommand> [flags]  (run `kernelskill <subcommand> --help` for \
         per-command flags)\n\nSubcommands:\n",
    );
    let width = commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
    for c in commands {
        out.push_str(&format!("  {:width$}  {}\n", c.name, c.summary));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table1", "--seeds", "3", "--quiet", "--out=x.json"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get("seeds"), Some("3"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(a.has("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "5", "--r", "0.25"]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("r", 0.0).unwrap(), 0.25);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(parse(&["x", "--n", "zz"]).get_usize("n", 1).is_err());
    }

    #[test]
    fn switch_at_end_and_negative_number_value() {
        let a = parse(&["run", "--thresh", "-0.5", "--verbose"]);
        assert_eq!(a.get_f64("thresh", 0.0).unwrap(), -0.5);
        assert!(a.has("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }

    fn registry() -> Vec<CommandDef> {
        vec![
            CommandDef {
                name: "suite",
                summary: "run the suite",
                usage: "[flags]",
                flags: vec![
                    FlagDef { name: "seeds", value: Some("N"), help: "seed count" },
                    FlagDef { name: "run-dir", value: Some("DIR"), help: "checkpoint dir" },
                    FlagDef { name: "resume", value: None, help: "resume" },
                ],
                positional: false,
            },
            CommandDef {
                name: "merge",
                summary: "merge shards",
                usage: "<shard-dirs>... [flags]",
                flags: vec![FlagDef { name: "watch", value: None, help: "follow" }],
                positional: true,
            },
        ]
    }

    fn checked(v: &[&str]) -> Result<Args, String> {
        parse_checked(v.iter().map(|s| s.to_string()), &registry())
    }

    #[test]
    fn checked_accepts_declared_flags_and_switches() {
        let a = checked(&["suite", "--seeds", "3", "--resume", "--run-dir=/tmp/x"]).unwrap();
        assert_eq!(a.get("seeds"), Some("3"));
        assert_eq!(a.get("run-dir"), Some("/tmp/x"));
        assert!(a.has("resume"));
    }

    #[test]
    fn checked_rejects_typos_with_a_suggestion() {
        let err = checked(&["suite", "--sees", "3"]).unwrap_err();
        assert!(err.contains("--sees") && err.contains("--seeds"), "{err}");
        let err = checked(&["suiet"]).unwrap_err();
        assert!(err.contains("suiet") && err.contains("suite"), "{err}");
    }

    #[test]
    fn checked_switch_never_swallows_a_positional() {
        let a = checked(&["merge", "--watch", "/tmp/run", "--watch=1"]);
        // `--watch=1` is a switch with a value: refused.
        assert!(a.unwrap_err().contains("takes no value"));
        let a = checked(&["merge", "--watch", "/tmp/run"]).unwrap();
        assert!(a.has("watch"));
        assert_eq!(a.positional, vec!["/tmp/run".to_string()]);
    }

    #[test]
    fn checked_value_flag_requires_a_value() {
        let err = checked(&["suite", "--seeds"]).unwrap_err();
        assert!(err.contains("requires a value"), "{err}");
        let err = checked(&["suite", "--seeds", "--resume"]).unwrap_err();
        assert!(err.contains("requires a value"), "{err}");
    }

    #[test]
    fn checked_rejects_undeclared_positionals_and_allows_declared() {
        let err = checked(&["suite", "stray"]).unwrap_err();
        assert!(err.contains("stray"), "{err}");
        let a = checked(&["merge", "a", "b", "--watch"]).unwrap();
        assert_eq!(a.positional, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn checked_help_everywhere_and_rendering() {
        let a = checked(&["suite", "--help"]).unwrap();
        assert!(a.has("help"));
        let a = checked(&["--help"]).unwrap();
        assert_eq!(a.subcommand, None);
        let reg = registry();
        let help = render_command_help(&reg[0]);
        assert!(help.contains("--seeds <N>") && help.contains("seed count"), "{help}");
        let global = render_global_help(&reg);
        assert!(global.contains("suite") && global.contains("merge shards"), "{global}");
    }
}
