//! Deterministic PRNG stack (no `rand` crate offline): SplitMix64 for seed
//! derivation + Xoshiro256** for streams.
//!
//! Every stochastic decision in the system flows through an [`Rng`] derived
//! from `(experiment seed, task id, round, role)`, which makes whole-suite
//! runs bit-reproducible — the property the paper's evaluation protocol
//! (fixed seeds, repeated rounds) depends on.

/// SplitMix64 step — used both as a standalone mixer and to seed Xoshiro.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive a child seed from a parent seed and a list of domain labels.
/// Stable across runs; collision-resistant enough for experiment streams.
pub fn derive_seed(parent: u64, labels: &[u64]) -> u64 {
    let mut s = parent ^ 0xA076_1D64_78BD_642F;
    let mut out = splitmix64(&mut s);
    for &l in labels {
        s ^= l.wrapping_mul(0xE703_7ED1_A0B4_28DB);
        out ^= splitmix64(&mut s).rotate_left(17);
    }
    out
}

/// Hash a string label into a u64 for use with [`derive_seed`] (FNV-1a).
pub fn label(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Xoshiro256** — fast, high-quality, tiny.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        let mut rng = Rng { s };
        // Warm-up: xoshiro's first outputs correlate across weakly-related
        // seeds (observable as biased first Bernoulli draws over derived
        // per-task streams); burn a few states.
        for _ in 0..8 {
            rng.next_u64();
        }
        rng
    }

    /// Child RNG for a named sub-stream.
    pub fn child(&mut self, name: &str) -> Rng {
        self.child_with(label(name))
    }

    /// Child RNG for a sub-stream whose [`label`] was hashed ahead of time —
    /// byte-identical to [`Rng::child`] with the corresponding name, but
    /// hot-loop callers can hoist the FNV hash out of the loop.
    pub fn child_with(&mut self, lbl: u64) -> Rng {
        Rng::new(derive_seed(self.next_u64(), &[lbl]))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        // Lemire-style rejection-free-enough for our use (bias < 2^-32).
        lo + self.next_u64() % (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform choice from a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choose from empty slice");
        &items[self.range_usize(0, items.len())]
    }

    /// Weighted choice: weights must be non-negative, not all zero.
    pub fn choose_weighted<'a, T>(&mut self, items: &'a [T], weights: &[f64]) -> &'a T {
        assert_eq!(items.len(), weights.len());
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f64() * total;
        for (item, w) in items.iter().zip(weights) {
            x -= w;
            if x <= 0.0 {
                return item;
            }
        }
        items.last().unwrap()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Log-uniform sample in [lo, hi] (heavy-tailed workload parameters).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (lo.ln() + self.f64() * (hi.ln() - lo.ln())).exp()
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with given ln-space mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = Rng::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn child_with_matches_child() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut ca = a.child("round");
        let mut cb = b.child_with(label("round"));
        for _ in 0..16 {
            assert_eq!(ca.next_u64(), cb.next_u64());
        }
    }

    #[test]
    fn derive_seed_is_stable_and_label_sensitive() {
        let a = derive_seed(1, &[label("task"), 5]);
        let b = derive_seed(1, &[label("task"), 5]);
        let c = derive_seed(1, &[label("task"), 6]);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(5);
        let items = [0usize, 1, 2];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[*r.choose_weighted(&items, &[0.0, 1.0, 3.0])] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 2);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
