//! Heap-allocation counting for the perf trajectory.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every allocation
//! (plus allocated bytes) in process-wide relaxed atomics. It is *not*
//! installed by default: registering it is the caller's job, and only the
//! `perf_hotpath` bench does so, behind the `alloc-count` feature:
//!
//! ```ignore
//! #[cfg(feature = "alloc-count")]
//! #[global_allocator]
//! static ALLOC: kernelskill::util::alloc_count::CountingAlloc = CountingAlloc;
//! ```
//!
//! Global atomics rather than thread-locals on purpose: a `GlobalAlloc`
//! must not allocate while recording (TLS initialization can), and the
//! suite bench fans work across a thread pool, so the number we want —
//! allocations per task run, aggregated over the whole suite — is the
//! process-wide total anyway. Callers measure by snapshot difference:
//! read [`allocations`] before and after the region of interest.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// A [`GlobalAlloc`] that delegates to [`System`] and counts calls.
///
/// `alloc`, `alloc_zeroed`, and `realloc` each count as one allocation
/// event; `dealloc` is free. Counting uses `Ordering::Relaxed` — the
/// counters are a measurement, not a synchronization point, and the bench
/// reads them from a single thread after the pool has joined.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the atomics never allocate, so the
// allocator cannot re-enter itself.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total allocation events since process start (0 forever if
/// [`CountingAlloc`] was never registered as the global allocator).
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Total bytes requested since process start (same caveat as
/// [`allocations`]).
pub fn bytes_allocated() -> u64 {
    BYTES.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test binary does not register CountingAlloc, so exercise the
    // GlobalAlloc impl directly and check the counters move.
    #[test]
    fn counts_direct_alloc_calls() {
        let before = (allocations(), bytes_allocated());
        let layout = Layout::from_size_align(64, 8).unwrap();
        unsafe {
            let p = CountingAlloc.alloc(layout);
            assert!(!p.is_null());
            CountingAlloc.dealloc(p, layout);
        }
        assert_eq!(allocations(), before.0 + 1);
        assert_eq!(bytes_allocated(), before.1 + 64);
    }
}
