//! Level-filtered stderr logger. Global level set once by the CLI; agents and
//! the coordinator narrate rounds at `Debug`, experiments at `Info`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {}", format!("{l:?}").to_lowercase(), args);
    }
}

#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
