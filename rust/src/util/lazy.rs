//! Lazily-initialized statics over `std::sync::OnceLock` (once_cell is not
//! vendored offline).
//!
//! API-compatible with the `once_cell::sync::Lazy<T>` subset this repo uses:
//! `static X: Lazy<T> = Lazy::new(|| ...)` with a non-capturing closure
//! (which coerces to `fn() -> T`), then transparent `Deref` access.

use std::ops::Deref;
use std::sync::OnceLock;

pub struct Lazy<T> {
    cell: OnceLock<T>,
    init: fn() -> T,
}

impl<T> Lazy<T> {
    pub const fn new(init: fn() -> T) -> Lazy<T> {
        Lazy {
            cell: OnceLock::new(),
            init,
        }
    }

    /// Force initialization and return a reference to the value.
    pub fn force(this: &Lazy<T>) -> &T {
        this.cell.get_or_init(this.init)
    }
}

impl<T> Deref for Lazy<T> {
    type Target = T;
    fn deref(&self) -> &T {
        Lazy::force(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    static NUMS: Lazy<Vec<u32>> = Lazy::new(|| vec![1, 2, 3]);

    #[test]
    fn static_initializes_once() {
        assert_eq!(NUMS.len(), 3);
        assert_eq!(NUMS.iter().sum::<u32>(), 6);
    }

    #[test]
    fn local_lazy() {
        let l: Lazy<String> = Lazy::new(|| "hi".to_string());
        assert_eq!(&*l, "hi");
        assert_eq!(&*l, "hi");
    }
}
