//! Minimal JSON parser/writer (serde is not vendored offline).
//!
//! Scope: exactly what this repo needs — parsing `artifacts/manifest.json`
//! and emitting experiment-result dumps. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {:?}", other.map(|x| x as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(format!("expected , or ] got {:?}", other.map(|x| x as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other.map(|x| x as char))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders for result dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"tasks": {"matmul": {"inputs": [{"shape": [256, 512], "dtype": "float32"}],
            "variants": {"ref": {"file": "matmul__ref.hlo.txt", "hlo_chars": 367}}}}}"#;
        let v = Json::parse(text).unwrap();
        let shape = v
            .get("tasks")
            .and_then(|t| t.get("matmul"))
            .and_then(|m| m.get("inputs"))
            .and_then(|i| i.as_arr())
            .and_then(|a| a[0].get("shape"))
            .and_then(|s| s.as_arr())
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
        // Re-parse the printed form.
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }
}
