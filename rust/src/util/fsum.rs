//! Exactly-rounded, order-independent f64 accumulation (Shewchuk
//! expansions, the algorithm behind Python's `math.fsum`).
//!
//! Why this exists: the sharded suite (`coordinator::merge`) promises that
//! merging per-shard skill stores is *commutative and associative at the
//! bit level* — the merged `skills.json` must be byte-identical to the one
//! a single process would have written, no matter how the cell matrix was
//! partitioned or in which order cells completed. Plain `f64 +=` breaks
//! that promise: floating-point addition rounds, so different fold orders
//! can differ in the last ulp. [`ExactSum`] instead keeps the running sum
//! as a non-overlapping expansion of f64 components whose exact real sum
//! is the true sum; adding is error-free, so the represented value is a
//! function of the *multiset* of addends only. [`ExactSum::value`] rounds
//! the exact sum correctly (once), and [`ExactSum::canonical`] produces a
//! unique component decomposition for serialization and equality.
//!
//! Finite inputs only: infinities/NaNs would poison the expansion, and the
//! cost model never produces them.
//!
//! # Example: order-independent shard merges
//!
//! Two shards accumulate gains in different orders; folding either into
//! the other produces the same exact value *and* the same canonical
//! serialization — which is why merged `skills.json` files are
//! byte-identical to single-process ones:
//!
//! ```
//! use kernelskill::util::fsum::ExactSum;
//!
//! let mut a_then_b = ExactSum::from_parts(&[0.1, 1e16]);
//! a_then_b.add_sum(&ExactSum::from_parts(&[0.2, -1e16]));
//!
//! let mut b_then_a = ExactSum::from_parts(&[0.2, -1e16]);
//! b_then_a.add_sum(&ExactSum::from_parts(&[0.1, 1e16]));
//!
//! assert_eq!(a_then_b, b_then_a);
//! assert_eq!(a_then_b.canonical(), b_then_a.canonical());
//! assert_eq!(a_then_b.value(), b_then_a.value());
//! ```

#![warn(missing_docs)]

/// Error-free transform: returns `(s, e)` with `s = fl(a + b)` and
/// `a + b = s + e` exactly (Knuth two-sum; no magnitude precondition).
#[inline]
fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let z = s - a;
    let e = (a - (s - z)) + (b - z);
    (s, e)
}

/// An exact f64 accumulator: the value is the exact real sum of `parts`,
/// maintained as a non-overlapping expansion in increasing magnitude order
/// with no zero components.
#[derive(Debug, Clone, Default)]
pub struct ExactSum {
    parts: Vec<f64>,
}

impl ExactSum {
    /// An empty accumulator (exact value 0).
    pub fn new() -> ExactSum {
        ExactSum::default()
    }

    /// Rebuild an accumulator from serialized components (any finite f64
    /// list; the canonical form from [`ExactSum::canonical`] round-trips).
    pub fn from_parts(parts: &[f64]) -> ExactSum {
        let mut s = ExactSum::new();
        for &p in parts {
            s.add(p);
        }
        s
    }

    /// True when the exact value is 0 (the expansion has no components).
    pub fn is_zero(&self) -> bool {
        self.parts.is_empty()
    }

    /// Add one addend, exactly (grow-expansion with zero elimination).
    pub fn add(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "ExactSum::add requires finite input");
        let mut x = x;
        let mut out = Vec::with_capacity(self.parts.len() + 1);
        for &p in &self.parts {
            let (hi, lo) = two_sum(x, p);
            if lo != 0.0 {
                out.push(lo);
            }
            x = hi;
        }
        if x != 0.0 {
            out.push(x);
        }
        self.parts = out;
    }

    /// Add another accumulator, exactly.
    pub fn add_sum(&mut self, other: &ExactSum) {
        for &p in &other.parts {
            self.add(p);
        }
    }

    /// The correctly-rounded f64 nearest the exact sum. Because rounding is
    /// correct, this depends only on the exact value, never on which
    /// expansion happens to represent it.
    pub fn value(&self) -> f64 {
        let p = &self.parts;
        let mut n = p.len();
        if n == 0 {
            return 0.0;
        }
        n -= 1;
        let mut hi = p[n];
        let mut lo = 0.0;
        while n > 0 {
            let x = hi;
            n -= 1;
            let y = p[n];
            hi = x + y;
            let yr = hi - x;
            lo = y - yr;
            if lo != 0.0 {
                break;
            }
        }
        // Halfway correction (CPython math.fsum): if the truncated partials
        // all push the same way as `lo`, round-half-even would otherwise
        // land on the wrong neighbor.
        if n > 0 && ((lo < 0.0 && p[n - 1] < 0.0) || (lo > 0.0 && p[n - 1] > 0.0)) {
            let y = lo * 2.0;
            let x = hi + y;
            if y == x - hi {
                hi = x;
            }
        }
        hi
    }

    /// Unique greedy decomposition of the exact value: component k is the
    /// correctly-rounded remainder after subtracting components 0..k. Two
    /// accumulators holding the same exact value canonicalize identically,
    /// whatever their internal expansions look like — this is what makes
    /// serialized stores byte-comparable.
    pub fn canonical(&self) -> Vec<f64> {
        let mut rem = self.clone();
        let mut out = Vec::new();
        while !rem.parts.is_empty() {
            let v = rem.value();
            if v == 0.0 {
                break;
            }
            out.push(v);
            rem.add(-v); // v is representable, so this subtraction is exact
        }
        out.reverse(); // increasing magnitude, like the internal invariant
        out
    }
}

/// Equality of the represented exact values (not of internal expansions).
impl PartialEq for ExactSum {
    fn eq(&self, other: &ExactSum) -> bool {
        self.canonical() == other.canonical()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_is_zero() {
        let s = ExactSum::new();
        assert!(s.is_zero());
        assert_eq!(s.value(), 0.0);
        assert!(s.canonical().is_empty());
    }

    #[test]
    fn cancellation_is_exact() {
        let mut s = ExactSum::new();
        s.add(1e16);
        s.add(1.0);
        s.add(-1e16);
        // Naive left-to-right f64 addition loses the 1.0 entirely.
        assert_eq!(s.value(), 1.0);
        s.add(-1.0);
        assert!(s.is_zero());
    }

    #[test]
    fn value_beats_naive_summation() {
        // Ten 0.1's: naive left-to-right f64 addition gives
        // 0.9999999999999999, but the exact sum of ten nearest-0.1 doubles
        // correctly rounds to exactly 1.0 (as math.fsum does).
        let naive = (0..10).fold(0.0f64, |acc, _| acc + 0.1);
        assert_ne!(naive, 1.0);
        let mut s = ExactSum::new();
        for _ in 0..10 {
            s.add(0.1);
        }
        assert_eq!(s.value(), 1.0);
    }

    #[test]
    fn order_independent_at_bit_level() {
        // Sum a nasty mix in many different orders; exact accumulation must
        // give the same rounded value and the same canonical form always.
        let vals = [
            1e16,
            3.14159,
            -1e16,
            0.1,
            0.2,
            -0.3,
            1e-12,
            7.5e9,
            -2.5e-7,
            0.30000000000000004,
        ];
        let mut rng = Rng::new(42);
        let reference = ExactSum::from_parts(&vals);
        for _ in 0..200 {
            let mut shuffled = vals.to_vec();
            rng.shuffle(&mut shuffled);
            let s = ExactSum::from_parts(&shuffled);
            assert_eq!(s.value(), reference.value());
            assert_eq!(s.canonical(), reference.canonical());
            assert_eq!(s, reference);
        }
    }

    #[test]
    fn add_sum_is_associative_and_commutative() {
        let a = ExactSum::from_parts(&[0.1, 1e15, -7.25]);
        let b = ExactSum::from_parts(&[0.2, -1e15]);
        let c = ExactSum::from_parts(&[1e-9, 0.30000000000000004]);
        let mut ab_c = a.clone();
        ab_c.add_sum(&b);
        ab_c.add_sum(&c);
        let mut bc = b.clone();
        bc.add_sum(&c);
        let mut a_bc = a.clone();
        a_bc.add_sum(&bc);
        assert_eq!(ab_c, a_bc);
        assert_eq!(ab_c.canonical(), a_bc.canonical());
        let mut ba = b.clone();
        ba.add_sum(&a);
        let mut ab = a.clone();
        ab.add_sum(&b);
        assert_eq!(ab, ba);
        // Identity.
        let mut with_zero = a.clone();
        with_zero.add_sum(&ExactSum::new());
        assert_eq!(with_zero, a);
    }

    #[test]
    fn canonical_roundtrips_through_from_parts() {
        let s = ExactSum::from_parts(&[1e16, 1.0, 0.1, -3.0e-13]);
        let c = s.canonical();
        let back = ExactSum::from_parts(&c);
        assert_eq!(back, s);
        assert_eq!(back.canonical(), c);
        assert_eq!(back.value(), s.value());
    }
}
