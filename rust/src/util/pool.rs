//! Tiny work-stealing-free thread pool (tokio is not vendored offline).
//!
//! The suite runner fans 250 tasks × strategies × seeds over this pool; each
//! unit of work is CPU-bound (cost model + retrieval + loop), so a simple
//! shared-queue pool with `available_parallelism` workers is the right shape.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

/// Map `f` over `items` in parallel, preserving order of results.
///
/// `f` must be `Sync` (called from many threads) and items are handed out by
/// index from an atomic counter — no per-item allocation or channel traffic.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed"))
        .collect()
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the harness/IO thread), at least 1.
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Shared progress counter for long suite runs (printed by the harness).
#[derive(Clone)]
pub struct Progress {
    done: Arc<AtomicUsize>,
    total: usize,
}

impl Progress {
    pub fn new(total: usize) -> Self {
        Progress {
            done: Arc::new(AtomicUsize::new(0)),
            total,
        }
    }
    pub fn tick(&self) -> usize {
        self.done.fetch_add(1, Ordering::Relaxed) + 1
    }
    pub fn done(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |x| *x).is_empty());
    }

    #[test]
    fn progress_ticks() {
        let p = Progress::new(10);
        assert_eq!(p.tick(), 1);
        assert_eq!(p.tick(), 2);
        assert_eq!(p.done(), 2);
        assert_eq!(p.total(), 10);
    }
}
