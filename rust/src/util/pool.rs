//! Work-stealing thread pool primitives (tokio/rayon are not vendored
//! offline).
//!
//! The suite orchestrator fans 250 tasks × strategies × seeds over
//! [`run_streaming`]: jobs are dealt round-robin into per-worker deques,
//! idle workers steal from the back of a victim's deque, and every finished
//! result is handed to a single-threaded `sink` on the calling thread *as it
//! completes* — that is what lets the scheduler append checkpoint JSONL
//! lines and persist the skill store incrementally instead of holding the
//! whole matrix in memory until the end.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Pop from our own queue front, else steal from a victim's back.
fn pop_or_steal(queues: &[Mutex<VecDeque<usize>>], w: usize) -> Option<usize> {
    if let Some(i) = queues[w].lock().unwrap().pop_front() {
        return Some(i);
    }
    let n = queues.len();
    for off in 1..n {
        let victim = (w + off) % n;
        if let Some(i) = queues[victim].lock().unwrap().pop_back() {
            return Some(i);
        }
    }
    None
}

/// Map `f` over `items` on a work-stealing pool, streaming completions.
///
/// * `f(index, &item)` runs on worker threads; it must be pure per item for
///   results to be order-independent.
/// * `sink(index, &result)` runs on the calling thread, once per item, in
///   *completion* order (nondeterministic under parallelism).
/// * The returned vector is in item order regardless of completion order.
pub fn run_streaming<T, R, F, S>(items: &[T], workers: usize, f: F, mut sink: S) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
    S: FnMut(usize, &R),
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        // Serial fast path: same streaming contract, no threads.
        let mut out = Vec::with_capacity(n);
        for (i, t) in items.iter().enumerate() {
            let r = f(i, t);
            sink(i, &r);
            out.push(r);
        }
        return out;
    }

    let queues: Vec<Mutex<VecDeque<usize>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for i in 0..n {
        queues[i % workers].lock().unwrap().push_back(i);
    }

    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();

    thread::scope(|scope| {
        for w in 0..workers {
            let tx = tx.clone();
            let queues = &queues;
            let f = &f;
            scope.spawn(move || {
                while let Some(i) = pop_or_steal(queues, w) {
                    let r = f(i, &items[i]);
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        // Drain completions on the calling thread so the sink needs no
        // synchronization (it owns the checkpoint writer / skill store).
        for (i, r) in rx {
            sink(i, &r);
            slots[i] = Some(r);
        }
    });

    slots
        .into_iter()
        .map(|s| s.expect("worker completed every job"))
        .collect()
}

/// Map `f` over `items` in parallel, preserving order of results.
///
/// Thin wrapper over [`run_streaming`] with a no-op sink; kept for callers
/// that don't need completion streaming.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    run_streaming(items, workers, |_, t| f(t), |_, _| {})
}

/// Default worker count: physical parallelism minus one (leave a core for
/// the harness/IO thread), at least 1.
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

/// Shared progress counter for long suite runs (printed by the harness).
#[derive(Clone)]
pub struct Progress {
    done: Arc<AtomicUsize>,
    total: usize,
}

impl Progress {
    pub fn new(total: usize) -> Self {
        Progress {
            done: Arc::new(AtomicUsize::new(0)),
            total,
        }
    }
    pub fn tick(&self) -> usize {
        self.done.fetch_add(1, Ordering::Relaxed) + 1
    }
    pub fn done(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |x| *x).is_empty());
    }

    #[test]
    fn streaming_sink_sees_every_completion_once() {
        let items: Vec<u64> = (0..200).collect();
        let mut seen = vec![0u32; items.len()];
        let out = run_streaming(&items, 8, |_, x| x + 1, |i, r| {
            seen[i] += 1;
            assert_eq!(*r, items[i] + 1);
        });
        assert!(seen.iter().all(|&c| c == 1));
        assert_eq!(out.len(), items.len());
        assert_eq!(out[7], 8);
    }

    #[test]
    fn streaming_serial_is_in_order() {
        let items = vec![10, 20, 30];
        let mut order = Vec::new();
        let out = run_streaming(&items, 1, |_, x| *x, |i, _| order.push(i));
        assert_eq!(order, vec![0, 1, 2]);
        assert_eq!(out, items);
    }

    #[test]
    fn work_stealing_drains_unbalanced_queues() {
        // More workers than a single queue's share: stealing must finish
        // the whole range even when per-item cost is wildly skewed.
        let items: Vec<u64> = (0..64).collect();
        let out = run_streaming(
            &items,
            6,
            |_, x| {
                if x % 7 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                x * x
            },
            |_, _| {},
        );
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn progress_ticks() {
        let p = Progress::new(10);
        assert_eq!(p.tick(), 1);
        assert_eq!(p.tick(), 2);
        assert_eq!(p.done(), 2);
        assert_eq!(p.total(), 10);
    }
}
