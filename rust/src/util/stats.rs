//! Small statistics toolkit used by the cost model, the metrics layer, and
//! the bench harness (criterion is unavailable offline; `harness::bench`
//! builds on these).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean over strictly-positive values; 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|x| *x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (0..=100) with linear interpolation; input need not be sorted.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 25.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
