//! The dual-level memory bank (§4.2): cross-task long-term expert knowledge
//! and per-task short-term trajectory state.
//!
//! The long-term side is itself two-layered — a curated knowledge base
//! (`long_term::kb_content`) and a learned, device-partitioned skill store
//! (`long_term::skill_store`) that persists across tasks, seeds,
//! strategies, and processes. See `docs/architecture.md` for the dataflow
//! and `docs/memory-formats.md` for every on-disk format.

#![warn(missing_docs)]

pub mod long_term;
pub mod short_term;
