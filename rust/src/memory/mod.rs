//! The dual-level memory bank (§4.2): cross-task long-term expert knowledge
//! and per-task short-term trajectory state.

pub mod long_term;
pub mod short_term;
