//! `field_mapping` (Appendix-B field 1) + `run_features_schema` (field 2):
//! map raw, tool-version-specific NCU/NSYS keys onto standardized evidence
//! fields so downstream decisions are robust to tool renames.

use super::schema::Evidence;
use crate::device::metrics::RawProfile;
use crate::kir::features::{CodeFeatures, OccupancyLimiter, ReductionPattern};

/// Alias table: standardized field <- any of the raw keys (first hit wins).
/// Covers both the 2023 and 2024 Nsight Compute naming eras emitted by
/// `device::metrics`.
pub const FIELD_MAPPING: &[(&str, &[&str])] = &[
    (
        "dram_pct",
        &[
            "dram__throughput.avg.pct_of_peak_sustained_elapsed",
            "gpu__dram_throughput.avg.pct_of_peak_sustained_elapsed",
        ],
    ),
    (
        "sm_pct",
        &[
            "sm__throughput.avg.pct_of_peak_sustained_elapsed",
            "sm__pipe_tensor_op_hmma_cycles_active.avg.pct_of_peak_sustained_elapsed",
        ],
    ),
    (
        "occupancy_pct",
        &["sm__warps_active.avg.pct_of_peak_sustained_active"],
    ),
    (
        "tensor_pipe_pct",
        &["sm__pipe_tensor_cycles_active.avg.pct_of_peak_sustained_elapsed"],
    ),
    ("scratch_bytes", &["launch__shared_mem_per_block_dynamic"]),
    ("regs_per_thread", &["launch__registers_per_thread"]),
    ("block_size", &["launch__block_size"]),
    ("duration_ns", &["gpu__time_duration.sum"]),
    ("l2_hit_pct", &["lts__t_sector_hit_rate.pct"]),
    (
        "coalescing_pct",
        &["smsp__sass_average_data_bytes_per_sector_mem_global_op_ld.pct"],
    ),
    (
        "stall_memory_pct",
        &["smsp__warp_issue_stalled_long_scoreboard_per_warp_active.pct"],
    ),
    (
        "stall_bank_conflict_pct",
        &["smsp__warp_issue_stalled_bank_conflict_per_warp_active.pct"],
    ),
];

/// Run-feature schema: nsys-side fields copied through under `run.`.
pub const RUN_FEATURES: &[&str] = &[
    "kernel_launch_count",
    "total_time_us",
    "launch_overhead_fraction",
    "num_ops",
    "hot_kernel_time_fraction",
];

/// Step 2 of the decision workflow: normalize a raw profile into evidence.
pub fn normalize_profile(raw: &RawProfile) -> Evidence {
    let mut ev = Evidence::new();
    for (std_name, aliases) in FIELD_MAPPING {
        for alias in *aliases {
            if let Some(v) = raw.ncu_get(alias) {
                ev.insert(std_name, v);
                break;
            }
        }
    }
    for rf in RUN_FEATURES {
        if let Some(v) = raw.run_get(rf) {
            // Static key: find the canonical &'static str.
            let key: &'static str = match *rf {
                "kernel_launch_count" => "run.kernel_launch_count",
                "total_time_us" => "run.total_time_us",
                "launch_overhead_fraction" => "run.launch_overhead_fraction",
                "num_ops" => "run.num_ops",
                "hot_kernel_time_fraction" => "run.hot_kernel_time_fraction",
                _ => unreachable!(),
            };
            ev.insert(key, v);
        }
    }
    ev
}

/// Fold the 18 static code features into the evidence namespace
/// (`code_features`, Appendix-B field 3).
pub fn fold_features(ev: &mut Evidence, f: &CodeFeatures) {
    let b = |x: bool| if x { 1.0 } else { 0.0 };
    ev.insert("feat.naive_gemm_loop", b(f.naive_gemm_loop));
    ev.insert("feat.smem_tiling", b(f.smem_tiling));
    ev.insert("feat.tensor_core", b(f.tensor_core));
    ev.insert("feat.vectorized_loads", b(f.vectorized_loads));
    ev.insert("feat.coalesced_access", b(f.coalesced_access));
    ev.insert("feat.bank_conflict_risk", b(f.bank_conflict_risk));
    ev.insert("feat.fusion_opportunities", f.fusion_opportunities as f64);
    ev.insert("feat.unfused_ew_chain", f.unfused_ew_chain as f64);
    ev.insert(
        "feat.reduction_pattern",
        match f.reduction_pattern {
            ReductionPattern::None => 0.0,
            ReductionPattern::Row => 1.0,
            ReductionPattern::Col => 2.0,
            ReductionPattern::Full => 3.0,
        },
    );
    ev.insert("feat.mixed_precision", b(f.mixed_precision));
    ev.insert("feat.double_buffered", b(f.double_buffered));
    ev.insert("feat.unrolled", b(f.unrolled));
    ev.insert("feat.register_pressure", f.register_pressure as f64);
    ev.insert(
        "feat.occupancy_limiter",
        match f.occupancy_limiter {
            OccupancyLimiter::None => 0.0,
            OccupancyLimiter::Scratchpad => 1.0,
            OccupancyLimiter::Registers => 2.0,
            OccupancyLimiter::Blocks => 3.0,
        },
    );
    ev.insert("feat.strided_access", b(f.strided_access));
    ev.insert("feat.uses_atomics", b(f.uses_atomics));
    ev.insert("feat.divergence_risk", b(f.divergence_risk));
    ev.insert("feat.kernel_launches", f.kernel_launches as f64);
    ev.insert("feat.structured_operand", b(f.structured_operand));
}

/// Task-level facts the veto rules need.
pub fn fold_task_facts(
    ev: &mut Evidence,
    strict_tolerance: bool,
    mxu_alignable: bool,
    has_gemm: bool,
) {
    let b = |x: bool| if x { 1.0 } else { 0.0 };
    ev.insert("task.strict", b(strict_tolerance));
    ev.insert("task.mxu_alignable", b(mxu_alignable));
    ev.insert("task.has_gemm", b(has_gemm));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::costmodel::price;
    use crate::device::machine::DeviceSpec;
    use crate::device::metrics::{synthesize, ToolVersion};
    use crate::kir::graph::KernelGraph;
    use crate::kir::op::OpKind;
    use crate::kir::schedule::Schedule;

    fn raw(version: ToolVersion) -> RawProfile {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 512, 512, 512, vec![]);
        let s = Schedule::per_op_naive(&g);
        let c = price(&g, &s, &DeviceSpec::a100_like());
        synthesize(&g, &s, &c, version)
    }

    #[test]
    fn both_tool_versions_normalize_identically() {
        let a = normalize_profile(&raw(ToolVersion::Ncu2023));
        let b = normalize_profile(&raw(ToolVersion::Ncu2024));
        assert_eq!(a.get("dram_pct"), b.get("dram_pct"));
        assert_eq!(a.get("sm_pct").is_some(), true);
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn run_features_prefixed() {
        let ev = normalize_profile(&raw(ToolVersion::Ncu2023));
        assert_eq!(ev.get("run.kernel_launch_count"), Some(&1.0));
        assert!(ev.get("run.total_time_us").unwrap() > &0.0);
    }

    #[test]
    fn features_fold_in() {
        let mut g = KernelGraph::new();
        g.push(OpKind::MatMul, 512, 512, 512, vec![]);
        let s = Schedule::per_op_naive(&g);
        let f = crate::kir::features::ground_truth(&g, &s);
        let mut ev = Evidence::new();
        fold_features(&mut ev, &f);
        assert_eq!(ev.get("feat.naive_gemm_loop"), Some(&1.0));
        assert_eq!(ev.get("feat.kernel_launches"), Some(&1.0));
        fold_task_facts(&mut ev, true, false, true);
        assert_eq!(ev.get("task.strict"), Some(&1.0));
        assert_eq!(ev.get("task.mxu_alignable"), Some(&0.0));
    }
}
