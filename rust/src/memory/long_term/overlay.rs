//! Copy-on-write overlays over a shared segmented v4 base store.
//!
//! The `serve` daemon is multi-tenant: every job folds observations into
//! long-term memory, but jobs must not contend on (or corrupt) one
//! shared manifest, and a job's fold must stay byte-equivalent to the
//! same run made solo (invariant 18). The v4 layout makes this nearly
//! free: segments are **immutable and never renamed**, so an overlay is
//! just a fresh directory holding
//!
//! - a hard link (copy when linking fails, e.g. across filesystems) to
//!   every segment file the base manifest references, under the same
//!   relative `skills.segments/` names, and
//! - a verbatim byte copy of the base manifest.
//!
//! The overlay then *is* a segmented store whose logical fold equals the
//! base's byte-for-byte; the job's writer opens it like any memory dir
//! and rotates/compacts new segments privately. The base directory is
//! never written through an overlay — compaction inside the overlay
//! deletes only the overlay's links (the base's own directory entries
//! keep the inodes alive), which is exactly the reader-safety contract
//! segment immutability was designed for.

use std::path::Path;

use super::segmented::SegmentedSkillStore;

/// Materialize a copy-on-write overlay of the segmented store at `base`
/// into `overlay`. Idempotent: an overlay that already carries a
/// manifest is left untouched (the daemon-restart path — the overlay may
/// already hold the job's partial fold). A cold base (no manifest)
/// yields a cold overlay. Returns whether the overlay inherited a base
/// manifest.
pub fn create_overlay(base: &Path, overlay: &Path) -> Result<bool, String> {
    std::fs::create_dir_all(overlay)
        .map_err(|e| format!("creating overlay dir {}: {e}", overlay.display()))?;
    let overlay_manifest = overlay.join("skills.json");
    if overlay_manifest.exists() {
        return Ok(true);
    }
    let base_manifest = base.join("skills.json");
    if !base_manifest.exists() {
        return Ok(false);
    }
    // Open validates the manifest and pins the segment list we link; the
    // manifest bytes themselves are copied verbatim afterwards so the
    // overlay's logical content is the base's, byte-for-byte.
    let store = SegmentedSkillStore::open(base)
        .map_err(|e| format!("opening overlay base {}: {e}", base.display()))?;
    for r in store.segments() {
        let src = base.join(&r.file);
        let dst = overlay.join(&r.file);
        if let Some(parent) = dst.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("creating {}: {e}", parent.display()))?;
        }
        if std::fs::hard_link(&src, &dst).is_err() {
            std::fs::copy(&src, &dst).map_err(|e| {
                format!("copying segment {} into overlay: {e}", src.display())
            })?;
        }
    }
    let bytes = std::fs::read(&base_manifest)
        .map_err(|e| format!("reading {}: {e}", base_manifest.display()))?;
    let tmp = overlay.join("skills.json.tmp");
    std::fs::write(&tmp, &bytes).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &overlay_manifest)
        .map_err(|e| format!("publishing {}: {e}", overlay_manifest.display()))?;
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::transforms::MethodId;
    use crate::memory::long_term::{SkillObs, SkillStore};
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ks-overlay-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn obs(case: &str, gain: f64) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: MethodId::TileSmem,
            gain: Some(gain),
            device: "a100-like".to_string(),
        }
    }

    /// An overlay's logical fold equals the base's byte-for-byte, and
    /// writing through the overlay leaves every base byte untouched.
    #[test]
    fn overlay_matches_base_and_never_writes_it() {
        let base = tmp_dir("base");
        let over = tmp_dir("head");
        for e in 1..=2u64 {
            let mut seg = SegmentedSkillStore::open(&base).unwrap();
            seg.advance_to(seg.generation() + 1).unwrap();
            seg.merge(&[obs("gemm.naive_loop", e as f64)]);
            seg.save().unwrap();
        }
        let base_manifest_bytes = std::fs::read(base.join("skills.json")).unwrap();
        assert!(create_overlay(&base, &over).unwrap());
        assert_eq!(std::fs::read(over.join("skills.json")).unwrap(), base_manifest_bytes);
        assert_eq!(
            SkillStore::load(&over.join("skills.json")).unwrap().canonical_bytes(),
            SkillStore::load(&base.join("skills.json")).unwrap().canonical_bytes(),
        );
        // A second call is an idempotent no-op (daemon restart path).
        assert!(create_overlay(&base, &over).unwrap());

        // Write (and compact) through the overlay; the base stays intact.
        let mut job = SegmentedSkillStore::open(&over).unwrap();
        job.advance_to(job.generation() + 1).unwrap();
        job.merge(&[obs("gemm.naive_loop", 9.0)]);
        job.save().unwrap();
        let mut job = SegmentedSkillStore::open(&over).unwrap();
        job.advance_to(job.generation() + 1).unwrap();
        job.compact().unwrap();
        job.save().unwrap();
        assert_eq!(
            std::fs::read(base.join("skills.json")).unwrap(),
            base_manifest_bytes,
            "base manifest untouched by overlay writes"
        );
        let base_store = SegmentedSkillStore::open(&base).unwrap();
        for r in base_store.segments() {
            assert!(base.join(&r.file).exists(), "base segment {} survives", r.file);
        }
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&over);
    }

    /// A cold base yields a cold overlay that a writer can grow.
    #[test]
    fn cold_base_yields_cold_overlay() {
        let base = tmp_dir("cold-base");
        let over = tmp_dir("cold-head");
        assert!(!create_overlay(&base, &over).unwrap());
        assert!(!over.join("skills.json").exists());
        let _ = std::fs::remove_dir_all(&base);
        let _ = std::fs::remove_dir_all(&over);
    }
}
