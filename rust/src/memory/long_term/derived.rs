//! `derived_fields` (Appendix-B field 4): deterministic composite indicators
//! computed from normalized metrics + run/code features, and
//! `headroom_tiers` (field 5): discretized optimization headroom.

use super::schema::{Evidence, Tier};

/// Step 3 of the decision workflow: extend evidence with derived fields.
pub fn compute_derived(ev: &mut Evidence) {
    let g = |ev: &Evidence, f: &str| ev.get(f).copied().unwrap_or(0.0);

    // How far the hot kernel sits from *any* peak: the headroom proxy.
    let peak = g(ev, "dram_pct")
        .max(g(ev, "sm_pct"))
        .max(g(ev, "tensor_pipe_pct"));
    ev.insert("drv.peak_pct", peak);
    // Amdahl view: peak utilization only bounds the hot kernel's share of
    // the task; the rest of the runtime (other kernels, launches) is
    // headroom regardless of how saturated the hot kernel is.
    let hot_frac = ev
        .get("run.hot_kernel_time_fraction")
        .copied()
        .unwrap_or(1.0)
        .clamp(0.0, 1.0);
    let headroom = 100.0 - hot_frac * peak;
    ev.insert("drv.headroom_pct", headroom.max(0.0));

    // Memory-vs-compute skew: positive = memory side dominates.
    ev.insert(
        "drv.memory_over_compute",
        g(ev, "dram_pct") - g(ev, "sm_pct"),
    );

    // Matrix-unit opportunity: compute-heavy kernel with an idle tensor pipe.
    let mxu_opp = if g(ev, "task.has_gemm") > 0.5 && g(ev, "tensor_pipe_pct") < 10.0 {
        1.0
    } else {
        0.0
    };
    ev.insert("drv.mxu_opportunity", mxu_opp);

    // High L2 hit rate on a GEMM = operands are being re-streamed (poor
    // blocking), not a win: the naive-loop fingerprint.
    let restream = if g(ev, "task.has_gemm") > 0.5 && g(ev, "l2_hit_pct") > 70.0 {
        1.0
    } else {
        0.0
    };
    ev.insert("drv.gemm_restreaming", restream);

    ev.insert(
        "drv.coalescing_deficit",
        (100.0 - g(ev, "coalescing_pct")).max(0.0),
    );
    ev.insert(
        "drv.occupancy_deficit",
        (100.0 - g(ev, "occupancy_pct")).max(0.0),
    );
    ev.insert(
        "drv.launch_bound_pct",
        g(ev, "run.launch_overhead_fraction") * 100.0,
    );

    // Are there more kernels than the graph structurally needs? (fusion debt)
    let launches = g(ev, "run.kernel_launch_count");
    ev.insert(
        "drv.fusion_debt",
        (launches - 1.0).max(0.0).min(20.0) + g(ev, "feat.fusion_opportunities"),
    );
}

/// Step 4: discretize headroom.
pub fn headroom_tier(ev: &Evidence) -> Tier {
    let h = ev.get("drv.headroom_pct").copied().unwrap_or(100.0);
    if h > 55.0 {
        Tier::High
    } else if h > 22.0 {
        Tier::Medium
    } else {
        Tier::Low
    }
}

/// Whether the headroom tier leaves room for a learned *extension* to add
/// a method the curated case never listed. `Low` tier means the kernel is
/// near its roofline — only polish remains, so structural additions from
/// learned evidence are not allowed to widen the method set there.
pub fn tier_allows_extension(tier: Tier) -> bool {
    !matches!(tier, Tier::Low)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(pairs: &[(&'static str, f64)]) -> Evidence {
        pairs.iter().copied().collect()
    }

    #[test]
    fn headroom_from_peak() {
        let mut e = ev(&[("dram_pct", 30.0), ("sm_pct", 10.0)]);
        compute_derived(&mut e);
        assert_eq!(e.get("drv.peak_pct"), Some(&30.0));
        assert_eq!(e.get("drv.headroom_pct"), Some(&70.0));
        assert_eq!(headroom_tier(&e), Tier::High);
    }

    #[test]
    fn tiers_partition() {
        for (peak, tier) in [(10.0, Tier::High), (60.0, Tier::Medium), (90.0, Tier::Low)] {
            let mut e = ev(&[("sm_pct", peak)]);
            compute_derived(&mut e);
            assert_eq!(headroom_tier(&e), tier, "peak={peak}");
        }
    }

    #[test]
    fn extensions_gated_out_of_low_tier() {
        assert!(tier_allows_extension(Tier::High));
        assert!(tier_allows_extension(Tier::Medium));
        assert!(!tier_allows_extension(Tier::Low));
    }

    #[test]
    fn mxu_opportunity_needs_gemm() {
        let mut e = ev(&[("task.has_gemm", 1.0), ("tensor_pipe_pct", 0.0)]);
        compute_derived(&mut e);
        assert_eq!(e.get("drv.mxu_opportunity"), Some(&1.0));
        let mut e2 = ev(&[("task.has_gemm", 0.0), ("tensor_pipe_pct", 0.0)]);
        compute_derived(&mut e2);
        assert_eq!(e2.get("drv.mxu_opportunity"), Some(&0.0));
    }

    #[test]
    fn restreaming_fingerprint() {
        let mut e = ev(&[("task.has_gemm", 1.0), ("l2_hit_pct", 90.0)]);
        compute_derived(&mut e);
        assert_eq!(e.get("drv.gemm_restreaming"), Some(&1.0));
    }

    #[test]
    fn fusion_debt_counts_launches_and_edges() {
        let mut e = ev(&[
            ("run.kernel_launch_count", 5.0),
            ("feat.fusion_opportunities", 3.0),
        ]);
        compute_derived(&mut e);
        assert_eq!(e.get("drv.fusion_debt"), Some(&7.0));
    }
}
