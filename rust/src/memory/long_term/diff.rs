//! Store-to-store divergence reports for the `skills diff` CLI.
//!
//! Two long-term stores that should agree (a fleet mirror vs its origin, a
//! compacted store vs the uncompacted twin, two tenants seeded from the
//! same base) are compared stat-by-stat over the deterministic union of
//! their (device partition, case, method) triples. Scores are evaluated
//! against each store's *own* generation clock — the number retrieval
//! would actually use on that side. Ordering is the BTreeMap canonical
//! order everywhere, so equal inputs render equal reports byte-for-byte.

use std::collections::BTreeSet;

use super::skill_store::{MethodStat, SkillStore};
use crate::kir::transforms::MethodId;

/// One side's view of a stat, snapshotted for rendering.
#[derive(Debug, Clone, PartialEq)]
pub struct StatLine {
    pub attempts: u64,
    pub wins: u64,
    /// Wilson lower bound on the win rate.
    pub confidence: f64,
    pub mean_gain: f64,
    /// Confidence-weighted rerank score at the owning store's generation.
    pub score: f64,
}

impl StatLine {
    fn of(s: &MethodStat, generation: u64) -> StatLine {
        StatLine {
            attempts: s.attempts,
            wins: s.wins,
            confidence: s.wilson_lower_bound(),
            mean_gain: s.mean_gain(),
            score: s.score(generation),
        }
    }

    fn render(&self) -> String {
        format!(
            "attempts {:>4}  wins {:>4}  conf {:.2}  mean gain {:+.3}  score {:+.4}",
            self.attempts, self.wins, self.confidence, self.mean_gain, self.score
        )
    }
}

/// A (device, case, method) triple where the two stores disagree.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffEntry {
    /// `device/case/method` key.
    pub key: String,
    pub a: StatLine,
    pub b: StatLine,
}

/// The computed divergence between two stores.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreDiff {
    /// Triples present in both stores with different stats.
    pub diverging: Vec<DiffEntry>,
    /// Triples only store A carries (key + its stat line).
    pub only_a: Vec<(String, StatLine)>,
    /// Triples only store B carries.
    pub only_b: Vec<(String, StatLine)>,
    /// Triples carried identically by both.
    pub identical: usize,
    gen_a: u64,
    obs_a: u64,
    gen_b: u64,
    obs_b: u64,
}

impl StoreDiff {
    /// Walk the union of both stores' (device, case, method) triples in
    /// canonical order and classify each one.
    pub fn compute(a: &SkillStore, b: &SkillStore) -> StoreDiff {
        let mut out = StoreDiff {
            gen_a: a.generation,
            obs_a: a.observations,
            gen_b: b.generation,
            obs_b: b.observations,
            ..StoreDiff::default()
        };
        let mut keys: BTreeSet<(String, String, MethodId)> = BTreeSet::new();
        for store in [a, b] {
            for (dev, cases) in &store.partitions {
                for (case, methods) in cases {
                    for method in methods.keys() {
                        keys.insert((dev.clone(), case.clone(), *method));
                    }
                }
            }
        }
        for (dev, case, method) in keys {
            let key = format!("{dev}/{case}/{}", method.name());
            let stat = |s: &SkillStore| s.stat_in(&dev, &case, method).cloned();
            match (stat(a), stat(b)) {
                (Some(sa), Some(sb)) => {
                    if sa == sb && a.generation == b.generation {
                        out.identical += 1;
                    } else if sa == sb
                        && StatLine::of(&sa, a.generation) == StatLine::of(&sb, b.generation)
                    {
                        // Same stat, clocks differ but staleness decay
                        // happens to agree — still identical in effect.
                        out.identical += 1;
                    } else {
                        out.diverging.push(DiffEntry {
                            key,
                            a: StatLine::of(&sa, a.generation),
                            b: StatLine::of(&sb, b.generation),
                        });
                    }
                }
                (Some(sa), None) => out.only_a.push((key, StatLine::of(&sa, a.generation))),
                (None, Some(sb)) => out.only_b.push((key, StatLine::of(&sb, b.generation))),
                (None, None) => unreachable!("key came from one of the stores"),
            }
        }
        out
    }

    /// True when the stores carry identical stats (header counters may
    /// still differ — the render says so).
    pub fn stats_agree(&self) -> bool {
        self.diverging.is_empty() && self.only_a.is_empty() && self.only_b.is_empty()
    }

    /// Render the report. Deterministic: equal diffs render equal bytes.
    pub fn render(&self, label_a: &str, label_b: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "skills diff:\n  A: {label_a} (generation {}, {} observation(s))\n  B: {label_b} (generation {}, {} observation(s))\n",
            self.gen_a, self.obs_a, self.gen_b, self.obs_b
        ));
        if !self.diverging.is_empty() {
            out.push_str("diverging stats:\n");
            for e in &self.diverging {
                out.push_str(&format!("  {}:\n", e.key));
                out.push_str(&format!("    A: {}\n", e.a.render()));
                out.push_str(&format!("    B: {}\n", e.b.render()));
            }
        }
        for (title, list) in [("only in A:", &self.only_a), ("only in B:", &self.only_b)] {
            if !list.is_empty() {
                out.push_str(title);
                out.push('\n');
                for (key, line) in list {
                    out.push_str(&format!("  {key}: {}\n", line.render()));
                }
            }
        }
        out.push_str(&format!(
            "summary: {} diverging, {} only in A, {} only in B, {} identical\n",
            self.diverging.len(),
            self.only_a.len(),
            self.only_b.len(),
            self.identical
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::long_term::skill_store::SkillObs;

    fn obs_on(device: &str, case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: m,
            gain,
            device: device.to_string(),
        }
    }

    #[test]
    fn identical_stores_diff_clean() {
        let mut a = SkillStore::new();
        a.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0))]);
        let b = a.clone();
        let d = StoreDiff::compute(&a, &b);
        assert!(d.stats_agree());
        assert_eq!(d.identical, 1);
        assert!(d.render("a", "b").contains("summary: 0 diverging, 0 only in A, 0 only in B, 1 identical"));
    }

    #[test]
    fn divergence_and_one_sided_entries_classify_deterministically() {
        let mut a = SkillStore::new();
        a.merge(&[
            obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0)),
            obs_on("a100-like", "c", MethodId::SplitK, Some(0.5)),
        ]);
        let mut b = SkillStore::new();
        b.merge(&[
            obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0)),
            obs_on("a100-like", "c", MethodId::TileSmem, None),
            obs_on("tpu-like", "c", MethodId::UnrollInner, Some(2.0)),
        ]);
        let d = StoreDiff::compute(&a, &b);
        assert_eq!(d.diverging.len(), 1, "tile_smem stats differ");
        assert_eq!(d.diverging[0].key, "a100-like/c/tile_smem");
        assert_eq!((d.diverging[0].a.attempts, d.diverging[0].b.attempts), (1, 2));
        assert_eq!(d.only_a.len(), 1);
        assert_eq!(d.only_a[0].0, "a100-like/c/split_k");
        assert_eq!(d.only_b.len(), 1);
        assert_eq!(d.only_b[0].0, "tpu-like/c/unroll_inner");
        // Deterministic render: computing twice gives identical bytes.
        let d2 = StoreDiff::compute(&a, &b);
        assert_eq!(d.render("a", "b"), d2.render("a", "b"));
    }

    #[test]
    fn generation_skew_surfaces_as_score_divergence() {
        let mut a = SkillStore::new();
        a.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0))]);
        let mut b = a.clone();
        b.generation = 10; // same stat, much staler clock -> decayed score
        let d = StoreDiff::compute(&a, &b);
        assert_eq!(d.diverging.len(), 1);
        assert!(d.diverging[0].a.score > d.diverging[0].b.score);
    }
}
