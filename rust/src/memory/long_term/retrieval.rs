//! Optimization-method retrieval: the nine-step Appendix-C decision workflow.
//!
//!   1. input aggregation          -> [`aggregate`]
//!   2. metric normalization       -> `normalize::normalize_profile`
//!   3. derived-field computation  -> `derived::compute_derived`
//!   4. headroom tier assignment   -> `derived::headroom_tier`
//!   5. bottleneck identification  -> signature matching + priority rules
//!   6. case matching              -> tier + gate_when over the decision table
//!   7. global rule enforcement    -> `FORBIDDEN_RULES` vetoes
//!   8. method set retrieval       -> surviving `allowed_methods`
//!      8'.  learned rerank        -> confidence-weighted skill scores
//!      8''. matchable learned     -> [`apply_learned`]: cases past the
//!           Wilson matchability bars extend/demote the method set
//!   9. LLM-assisted planning      -> `knowledge` attached for the Planner
//!
//! Every step leaves a printable trace in [`RetrievalResult`] — the paper's
//! auditability claim, mechanically enforced.

use super::derived::{compute_derived, headroom_tier, tier_allows_extension};
use super::kb_content::{knowledge_for, predicate, DECISION_TABLE, FORBIDDEN_RULES};
use super::normalize::{fold_features, fold_task_facts, normalize_profile};
use super::schema::{
    Bottleneck, Evidence, LearnedCase, LearnedOrigin, MethodKnowledge, Tier, BOTTLENECK_PRIORITY,
};
use super::skill_store::SkillStore;
use crate::bench_suite::Task;
use crate::device::metrics::RawProfile;
use crate::kir::features::CodeFeatures;
use crate::kir::transforms::MethodId;
use std::collections::BTreeMap;

/// Memoized skill-layer lookups for step 8' of the decision workflow.
///
/// Within one task run the skill store is an immutable snapshot (the
/// scheduler only swaps snapshots between cells, at fold-epoch boundaries),
/// so every per-(case, method) rank score, formatted skill note, and
/// per-case learned-case rendering is a pure function of
/// `(case, device, generation)`. The cache keys on exactly that: entries
/// are reused while `(device, generation)` match the token they were
/// computed under and flushed the moment either changes — `generation`
/// advances precisely when the store folds, making it the natural
/// invalidation token.
///
/// Byte-determinism is preserved by construction: cached values are the
/// same f64s/Strings the uncached path computes (the rerank comparator is
/// replicated verbatim over the memoized scores), so cache-on and
/// cache-off runs produce identical reports and stores. The cache must not
/// outlive the store snapshot it was filled from; `loop_runner::run_task`
/// creates one per task run.
#[derive(Debug, Default)]
pub struct RetrievalCache {
    /// `(device, store generation)` the entries below were computed under.
    token: Option<(String, u64)>,
    /// Memoized `SkillStore::rank_score` per (case id, method).
    scores: BTreeMap<(&'static str, MethodId), f64>,
    /// Memoized formatted skill note per (case id, method); `None` caches
    /// the "no recorded evidence" outcome.
    notes: BTreeMap<(&'static str, MethodId), Option<String>>,
    /// Memoized synthesized learned cases per case id (structs, not
    /// renderings: step 8'' both renders them *and* applies the matchable
    /// ones to the method set).
    learned: BTreeMap<&'static str, Vec<LearnedCase>>,
}

impl RetrievalCache {
    /// Fresh, empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Flush every entry if `(device, generation)` no longer match the
    /// token the entries were computed under.
    fn validate(&mut self, store: &SkillStore, device: &str) {
        match &self.token {
            Some((d, g)) if d == device && *g == store.generation => {}
            _ => {
                self.scores.clear();
                self.notes.clear();
                self.learned.clear();
                self.token = Some((device.to_string(), store.generation));
            }
        }
    }
}

/// One formatted skill-evidence audit line for (device, case, method), or
/// `None` when the store holds no attempts for the pair. Shared by the
/// cached and uncached step-8' paths so their bytes cannot drift.
fn skill_note(
    store: &SkillStore,
    device: &str,
    case_id: &str,
    m: MethodId,
) -> Option<String> {
    let (stat, src) = match store.stat_in(device, case_id, m) {
        Some(s) => (Some(s.clone()), device),
        None => (store.pooled_stat(case_id, m), "pooled"),
    };
    let stat = stat?;
    if stat.attempts == 0 {
        return None;
    }
    Some(format!(
        "{}: {} attempts, {} wins, mean gain {:+.3}, conf {:.2}, staleness x{:.2} [{}]",
        m.name(),
        stat.attempts,
        stat.wins,
        stat.mean_gain(),
        stat.wilson_lower_bound(),
        stat.staleness_weight(store.generation),
        src
    ))
}

/// Step 8'': apply *matchable* learned cases to the retrieved method set.
///
/// Learned cases below the matchability bars ([`LearnedCase::matchable`]:
/// `MIN_MATCH_EVIDENCE` attempts and `MIN_MATCH_CONFIDENCE` Wilson lower
/// bound) only annotate the audit — a noisy shard's flukes cannot perturb
/// the curated table. Matchable ones act by origin:
///
/// * **Extension** — append the method to the allowed set, *unless* the
///   headroom tier forbids structural additions
///   ([`tier_allows_extension`]) or a global veto rule fires on this
///   evidence (the step-7 veto pass never saw the method, so it is
///   re-checked here and recorded in `vetoed` if it trips).
/// * **Demotion** — move the method to the end of the allowed set (the
///   confidence-weighted rerank usually sank it already; the move is
///   recorded only when it actually changes the order).
/// * **Promotion** — structurally a no-op: the step-8' rerank scores
///   already express any promotion that clears the evidence bars.
///
/// Returns the audit lines for the applications that actually happened.
/// Shared by the cached and uncached step-8' paths so their bytes cannot
/// drift.
fn apply_learned(
    ev: &Evidence,
    tier: Tier,
    learned: &[LearnedCase],
    allowed: &mut Vec<MethodId>,
    vetoed: &mut Vec<(MethodId, &'static str)>,
) -> Vec<String> {
    let mut applied = Vec::new();
    for lc in learned {
        if !lc.matchable() {
            continue;
        }
        match lc.origin {
            LearnedOrigin::Extension => {
                if !tier_allows_extension(tier) || allowed.contains(&lc.method) {
                    continue;
                }
                let veto = FORBIDDEN_RULES
                    .iter()
                    .find(|rule| rule.veto.contains(&lc.method) && rule.when.eval(ev));
                match veto {
                    Some(rule) => vetoed.push((lc.method, rule.id)),
                    None => {
                        allowed.push(lc.method);
                        applied.push(format!(
                            "{}: extended the method set with {}",
                            lc.id(),
                            lc.method.name()
                        ));
                    }
                }
            }
            LearnedOrigin::Demotion => {
                if let Some(pos) = allowed.iter().position(|&m| m == lc.method) {
                    if pos + 1 != allowed.len() {
                        let m = allowed.remove(pos);
                        allowed.push(m);
                        applied.push(format!(
                            "{}: demoted {} below every alternative",
                            lc.id(),
                            lc.method.name()
                        ));
                    }
                }
            }
            LearnedOrigin::Promotion => {}
        }
    }
    applied
}

/// Full audit trail of one retrieval (steps 4-9 outputs).
#[derive(Debug, Clone)]
pub struct RetrievalResult {
    /// Headroom tier assigned in step 4.
    pub tier: Tier,
    /// Bottleneck the matched case addresses (step 5).
    pub bottleneck: Bottleneck,
    /// Matched decision-table case id (step 6), if any.
    pub matched_case: Option<&'static str>,
    /// Final permitted methods, priority-ordered (step 8).
    pub allowed_methods: Vec<MethodId>,
    /// Named predicates that held on this evidence (audit).
    pub satisfied_predicates: Vec<&'static str>,
    /// (method, rule id) pairs removed by global vetoes (step 7).
    pub vetoed: Vec<(MethodId, &'static str)>,
    /// llm_assist entries for the permitted methods (step 9).
    pub knowledge: Vec<&'static MethodKnowledge>,
    /// Why the matched case fired (case rationale).
    pub case_why: Option<&'static str>,
    /// Persisted-skill evidence applied to this retrieval (one line per
    /// method with recorded outcomes; empty when retrieval ran cold).
    /// Each line names the partition the evidence came from (`[<device>]`
    /// or `[pooled]` for the cross-device fallback).
    pub skill_notes: Vec<String>,
    /// Learned decision cases the store synthesized for the matched case
    /// on this device (promotions/demotions/extensions of the curated KB);
    /// empty when retrieval ran cold or nothing was learned.
    pub learned_notes: Vec<String>,
    /// Matchable learned cases that actually modified the method set in
    /// step 8'' (one audit line per application; empty when none cleared
    /// the matchability bars or every application was a no-op).
    pub applied_learned: Vec<String>,
}

impl RetrievalResult {
    /// Render the audit trail (what the paper calls traceable selection).
    pub fn audit(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "tier={:?} bottleneck={:?} case={}\n",
            self.tier,
            self.bottleneck,
            self.matched_case.unwrap_or("<none>")
        ));
        s.push_str(&format!(
            "evidence: {}\n",
            self.satisfied_predicates.join(", ")
        ));
        for (m, rule) in &self.vetoed {
            s.push_str(&format!("vetoed: {} by {}\n", m.name(), rule));
        }
        s.push_str(&format!(
            "allowed: [{}]\n",
            self.allowed_methods
                .iter()
                .map(|m| m.name())
                .collect::<Vec<_>>()
                .join(", ")
        ));
        if !self.skill_notes.is_empty() {
            s.push_str("skills (persistent long-term memory):\n");
            for note in &self.skill_notes {
                s.push_str(&format!("  {note}\n"));
            }
        }
        if !self.learned_notes.is_empty() {
            s.push_str("learned decision cases:\n");
            for note in &self.learned_notes {
                s.push_str(&format!("  {note}\n"));
            }
        }
        if !self.applied_learned.is_empty() {
            s.push_str("learned cases applied to the method set:\n");
            for note in &self.applied_learned {
                s.push_str(&format!("  {note}\n"));
            }
        }
        s
    }
}

/// Step 1: aggregate raw profile + code features + task facts into one
/// evidence namespace (steps 2-3 applied inside).
pub fn aggregate(task: &Task, features: &CodeFeatures, raw: &RawProfile) -> Evidence {
    let mut ev = normalize_profile(raw); // step 2
    fold_features(&mut ev, features);
    let dom = task.graph.dominant_op();
    let mxu_alignable = dom
        .map(|o| o.m % 8 == 0 && o.n % 8 == 0 && o.k % 8 == 0)
        .unwrap_or(false);
    let has_gemm = !task.graph.gemm_ops().is_empty();
    fold_task_facts(&mut ev, task.strict_tolerance, mxu_alignable, has_gemm);
    compute_derived(&mut ev); // step 3
    ev
}

/// Steps 4-9: run the deterministic decision policy over evidence (cold —
/// no persisted skills).
pub fn retrieve(ev: &Evidence) -> RetrievalResult {
    retrieve_with(ev, None, "")
}

/// Steps 4-9 with an optional warm-started [`SkillStore`]: persisted
/// observations rerank the matched case's allowed methods (step 8') with a
/// confidence-weighted, staleness-decayed score, and are surfaced in the
/// audit trail together with any learned decision cases.
///
/// `device` names the partition to consult first (`DeviceSpec::name`, e.g.
/// `a100-like`); methods the partition never observed fall back to the
/// pooled cross-device view at a discount. An empty `device` ranks on the
/// pooled view at full weight.
pub fn retrieve_with(ev: &Evidence, skills: Option<&SkillStore>, device: &str) -> RetrievalResult {
    retrieve_with_cache(ev, skills, device, None)
}

/// [`retrieve_with`] with an optional [`RetrievalCache`] memoizing the
/// skill-layer lookups of step 8'. With `None` the behavior is exactly
/// `retrieve_with`; with a cache the result is byte-identical but repeat
/// retrievals against the same store snapshot skip the store walks and
/// note formatting.
pub fn retrieve_with_cache(
    ev: &Evidence,
    skills: Option<&SkillStore>,
    device: &str,
    cache: Option<&mut RetrievalCache>,
) -> RetrievalResult {
    // Audit: which named predicates hold.
    let satisfied: Vec<&'static str> = super::kb_content::PREDICATES
        .iter()
        .filter(|p| p.pred.eval(ev))
        .map(|p| p.name)
        .collect();

    let tier = headroom_tier(ev); // step 4

    // Step 5+6: walk bottlenecks in priority order; within a bottleneck,
    // take the first case whose signature, tier, and gate all hold.
    let mut matched: Option<&super::schema::DecisionCase> = None;
    'outer: for b in BOTTLENECK_PRIORITY {
        for case in DECISION_TABLE.iter().filter(|c| c.bottleneck == b) {
            let sig_ok = case
                .ncu_signature
                .iter()
                .all(|s| predicate(s).map(|p| p.pred.eval(ev)).unwrap_or(false));
            let tier_ok = case.tiers.contains(&tier);
            if sig_ok && tier_ok && case.gate_when.eval(ev) {
                matched = Some(case);
                break 'outer;
            }
        }
    }

    // Step 7: global veto enforcement.
    let mut allowed = Vec::new();
    let mut vetoed = Vec::new();
    if let Some(case) = matched {
        'methods: for &m in &case.allowed_methods {
            for rule in FORBIDDEN_RULES.iter() {
                if rule.veto.contains(&m) && rule.when.eval(ev) {
                    vetoed.push((m, rule.id));
                    continue 'methods;
                }
            }
            allowed.push(m);
        }
    }

    // Step 8': persisted skills rerank the surviving methods — learned
    // outcomes take precedence over curated priority (confidence-weighted
    // and staleness-decayed, device partition first), untried methods keep
    // their curated order.
    let mut skill_notes = Vec::new();
    let mut learned_notes = Vec::new();
    let mut applied_learned = Vec::new();
    if let (Some(store), Some(case)) = (skills, matched) {
        match cache {
            Some(cache) => {
                cache.validate(store, device);
                // Rerank replicated over memoized scores: same values, same
                // comparator, same stable sort as `SkillStore::rerank`.
                let scores: Vec<f64> = allowed
                    .iter()
                    .map(|&m| {
                        *cache
                            .scores
                            .entry((case.id, m))
                            .or_insert_with(|| store.rank_score(device, case.id, m))
                    })
                    .collect();
                let mut order: Vec<usize> = (0..allowed.len()).collect();
                order.sort_by(|&a, &b| {
                    scores[b]
                        .partial_cmp(&scores[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                let reordered: Vec<MethodId> = order.iter().map(|&i| allowed[i]).collect();
                allowed.copy_from_slice(&reordered);
                let learned = cache
                    .learned
                    .entry(case.id)
                    .or_insert_with(|| store.learned_for(device, case.id))
                    .clone();
                // Step 8'' before note formatting, so an extended method
                // gets its skill note like any curated one.
                applied_learned = apply_learned(ev, tier, &learned, &mut allowed, &mut vetoed);
                for &m in &allowed {
                    let note = cache
                        .notes
                        .entry((case.id, m))
                        .or_insert_with(|| skill_note(store, device, case.id, m));
                    if let Some(n) = note {
                        skill_notes.push(n.clone());
                    }
                }
                learned_notes = learned.iter().map(|lc| lc.render()).collect();
            }
            None => {
                store.rerank(device, case.id, &mut allowed);
                let learned = store.learned_for(device, case.id);
                applied_learned = apply_learned(ev, tier, &learned, &mut allowed, &mut vetoed);
                for &m in &allowed {
                    if let Some(n) = skill_note(store, device, case.id, m) {
                        skill_notes.push(n);
                    }
                }
                learned_notes = learned.iter().map(|lc| lc.render()).collect();
            }
        }
    }

    // Step 9: attach method knowledge.
    let knowledge = allowed.iter().filter_map(|&m| knowledge_for(m)).collect();

    RetrievalResult {
        tier,
        bottleneck: matched.map(|c| c.bottleneck).unwrap_or(Bottleneck::NearRoofline),
        matched_case: matched.map(|c| c.id),
        allowed_methods: allowed,
        satisfied_predicates: satisfied,
        vetoed,
        knowledge,
        case_why: matched.map(|c| c.why),
        skill_notes,
        learned_notes,
        applied_learned,
    }
}

/// Convenience: full pipeline from raw inputs (cold).
pub fn retrieve_for(task: &Task, features: &CodeFeatures, raw: &RawProfile) -> RetrievalResult {
    retrieve(&aggregate(task, features, raw))
}

/// Full pipeline from raw inputs with a warm-started skill store.
/// `device` selects the store partition consulted first (see
/// [`retrieve_with`]).
pub fn retrieve_for_with(
    task: &Task,
    features: &CodeFeatures,
    raw: &RawProfile,
    skills: Option<&SkillStore>,
    device: &str,
) -> RetrievalResult {
    retrieve_with(&aggregate(task, features, raw), skills, device)
}

/// [`retrieve_for_with`] with an optional [`RetrievalCache`] (see
/// [`retrieve_with_cache`]). The loop runner threads one cache through all
/// rounds of a task run.
pub fn retrieve_for_with_cache(
    task: &Task,
    features: &CodeFeatures,
    raw: &RawProfile,
    skills: Option<&SkillStore>,
    device: &str,
    cache: Option<&mut RetrievalCache>,
) -> RetrievalResult {
    retrieve_with_cache(&aggregate(task, features, raw), skills, device, cache)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::level2::appendix_d_graph;
    use crate::device::costmodel::price;
    use crate::device::machine::DeviceSpec;
    use crate::device::metrics::{synthesize, ToolVersion};
    use crate::kir::features::ground_truth;
    use crate::kir::schedule::Schedule;
    use crate::kir::transforms::{self, MethodId};

    fn appendix_d_task() -> Task {
        Task {
            id: "t".into(),
            level: 2,
            name: "fused_epilogue".into(),
            graph: appendix_d_graph(1024, 8192, 8192),
            eager_waste: 1.0,
            sched_ceiling: 3.2,
            strict_tolerance: false,
            translation_risk: 0.05,
            artifact: None,
        }
    }

    fn retrieval_at(task: &Task, sched: &Schedule) -> RetrievalResult {
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, sched, &dev);
        let raw = synthesize(&task.graph, sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, sched);
        retrieve_for(task, &feats, &raw)
    }

    #[test]
    fn motivating_example_picks_gemm_tiling_not_fusion() {
        // The §3 failure mode: a naive seed on the Appendix-D task. The
        // memory-free optimizer chose fusion; the decision policy must
        // target the dominant GEMM first.
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let r = retrieval_at(&task, &sched);
        assert_eq!(r.matched_case, Some("gemm.naive_loop"), "{}", r.audit());
        assert_eq!(r.allowed_methods.first(), Some(&MethodId::TileSmem));
    }

    #[test]
    fn after_tiling_recommends_tensor_core() {
        let task = appendix_d_task();
        let mut sched = Schedule::per_op_naive(&task.graph);
        transforms::apply(MethodId::TileSmem, &task.graph, &mut sched);
        let r = retrieval_at(&task, &sched);
        assert_eq!(r.matched_case, Some("gemm.no_tensor_core"), "{}", r.audit());
        assert!(r.allowed_methods.contains(&MethodId::UseTensorCore));
    }

    #[test]
    fn fusion_surfaces_once_gemm_is_healthy() {
        let task = appendix_d_task();
        let mut sched = Schedule::per_op_naive(&task.graph);
        for m in [
            MethodId::TileSmem,
            MethodId::UseTensorCore,
            MethodId::PadScratch,
            MethodId::DoubleBuffer,
            MethodId::VectorizeLoads,
            MethodId::UnrollInner,
        ] {
            if transforms::applicable(m, &task.graph, &sched).is_ok() {
                transforms::apply(m, &task.graph, &mut sched);
            }
        }
        let r = retrieval_at(&task, &sched);
        // GEMM is now on the matrix unit; the next bottleneck should be the
        // unfused epilogue (fusion) or access-pattern cleanup on the tail.
        assert!(
            matches!(
                r.bottleneck,
                Bottleneck::FusionOpportunity
                    | Bottleneck::PoorAccessPattern
                    | Bottleneck::LaunchOverhead
            ),
            "{}",
            r.audit()
        );
    }

    #[test]
    fn strict_task_vetoes_downcast() {
        let mut task = appendix_d_task();
        task.strict_tolerance = true;
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        let ev = aggregate(&task, &feats, &raw);
        // Force-match the polish case by evaluating vetoes directly.
        assert!(super::super::kb_content::FORBIDDEN_RULES
            .iter()
            .find(|r| r.id == "strict_no_downcast")
            .unwrap()
            .when
            .eval(&ev));
    }

    #[test]
    fn ragged_dims_veto_tensor_core() {
        let mut task = appendix_d_task();
        // Rebuild with a ragged K.
        task.graph = appendix_d_graph(1024, 8191, 8192);
        let mut sched = Schedule::per_op_naive(&task.graph);
        transforms::apply(MethodId::TileSmem, &task.graph, &mut sched);
        let r = retrieval_at(&task, &sched);
        assert!(
            !r.allowed_methods.contains(&MethodId::UseTensorCore),
            "{}",
            r.audit()
        );
        if r.matched_case == Some("gemm.no_tensor_core") {
            assert!(r.vetoed.iter().any(|(m, rule)| {
                *m == MethodId::UseTensorCore && *rule == "mxu_needs_alignment"
            }));
        }
    }

    #[test]
    fn audit_trail_is_renderable() {
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let r = retrieval_at(&task, &sched);
        let audit = r.audit();
        assert!(audit.contains("bottleneck="));
        assert!(audit.contains("allowed:"));
    }

    #[test]
    fn warm_skills_surface_in_audit() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        let mut store = SkillStore::new();
        store.observe(&SkillObs {
            case_id: "gemm.naive_loop".to_string(),
            method: MethodId::TileSmem,
            gain: Some(2.5),
            device: dev.name.to_string(),
        });
        let r = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        assert_eq!(r.matched_case, Some("gemm.naive_loop"), "{}", r.audit());
        assert!(!r.skill_notes.is_empty());
        let audit = r.audit();
        assert!(audit.contains("skills (persistent long-term memory)"));
        assert!(audit.contains("tile_smem: 1 attempts, 1 wins"));
        assert!(audit.contains("[a100-like]"), "note must name its partition:\n{audit}");
        // Cold retrieval is unchanged by the skill layer's existence.
        let cold = retrieve_for(&task, &feats, &raw);
        assert_eq!(cold.allowed_methods, r.allowed_methods);
        assert!(cold.skill_notes.is_empty());
    }

    #[test]
    fn learned_cases_surface_in_audit() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        // Enough consistent failures of the curated first choice to
        // synthesize a demotion for the matched case.
        let mut store = SkillStore::new();
        for _ in 0..8 {
            store.observe(&SkillObs {
                case_id: "gemm.naive_loop".to_string(),
                method: MethodId::TileSmem,
                gain: None,
                device: dev.name.to_string(),
            });
        }
        let r = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        assert_eq!(r.matched_case, Some("gemm.naive_loop"), "{}", r.audit());
        assert!(!r.learned_notes.is_empty(), "{}", r.audit());
        let audit = r.audit();
        assert!(audit.contains("learned decision cases:"), "{audit}");
        assert!(audit.contains("[demotion]"), "{audit}");
    }

    #[test]
    fn matchable_extension_widens_the_method_set() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        // gemm.naive_loop's curated set is [TileSmem] only. Eight clean
        // wins of VectorizeLoads clear both matchability bars
        // (wilson(8,8) ~ 0.89 >= 0.7), so the extension must act.
        let mut store = SkillStore::new();
        for _ in 0..8 {
            store.observe(&SkillObs {
                case_id: "gemm.naive_loop".to_string(),
                method: MethodId::VectorizeLoads,
                gain: Some(1.5),
                device: dev.name.to_string(),
            });
        }
        let cold = retrieve_for(&task, &feats, &raw);
        assert_eq!(cold.matched_case, Some("gemm.naive_loop"));
        assert!(!cold.allowed_methods.contains(&MethodId::VectorizeLoads));
        let r = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        assert!(
            r.allowed_methods.contains(&MethodId::VectorizeLoads),
            "{}",
            r.audit()
        );
        assert_eq!(
            r.allowed_methods.last(),
            Some(&MethodId::VectorizeLoads),
            "extensions append after the curated (reranked) set"
        );
        assert!(!r.applied_learned.is_empty());
        let audit = r.audit();
        assert!(audit.contains("learned cases applied to the method set:"), "{audit}");
        assert!(audit.contains("extended the method set with vectorize_loads"), "{audit}");
        assert!(
            r.skill_notes.iter().any(|n| n.starts_with("vectorize_loads:")),
            "the extended method gets a skill note too:\n{audit}"
        );
        // Cached path produces the same bytes.
        let mut cache = RetrievalCache::new();
        for _ in 0..2 {
            let c = retrieve_for_with_cache(
                &task,
                &feats,
                &raw,
                Some(&store),
                dev.name,
                Some(&mut cache),
            );
            assert_eq!(c.allowed_methods, r.allowed_methods);
            assert_eq!(c.audit(), r.audit());
        }
    }

    #[test]
    fn sub_threshold_learned_cases_cannot_modify_the_method_set() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        // Five wins is enough to *synthesize* an extension (it shows in the
        // audit) but below MIN_MATCH_EVIDENCE — a noisy shard's early
        // streak must not widen the curated set.
        let mut store = SkillStore::new();
        for _ in 0..5 {
            store.observe(&SkillObs {
                case_id: "gemm.naive_loop".to_string(),
                method: MethodId::VectorizeLoads,
                gain: Some(1.5),
                device: dev.name.to_string(),
            });
        }
        let cold = retrieve_for(&task, &feats, &raw);
        let r = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        assert!(!r.learned_notes.is_empty(), "the case exists:\n{}", r.audit());
        assert_eq!(
            r.allowed_methods, cold.allowed_methods,
            "but it may not act:\n{}",
            r.audit()
        );
        assert!(r.applied_learned.is_empty());
    }

    #[test]
    fn cached_retrieval_matches_uncached() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        let mut store = SkillStore::new();
        store.observe(&SkillObs {
            case_id: "gemm.naive_loop".to_string(),
            method: MethodId::TileSmem,
            gain: Some(2.5),
            device: dev.name.to_string(),
        });
        for _ in 0..8 {
            store.observe(&SkillObs {
                case_id: "gemm.naive_loop".to_string(),
                method: MethodId::UnrollInner,
                gain: None,
                device: dev.name.to_string(),
            });
        }
        let plain = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        let mut cache = RetrievalCache::new();
        // First call fills the cache, second is served from it; both must
        // match the uncached result field for field.
        for _ in 0..2 {
            let c = retrieve_for_with_cache(
                &task,
                &feats,
                &raw,
                Some(&store),
                dev.name,
                Some(&mut cache),
            );
            assert_eq!(c.allowed_methods, plain.allowed_methods);
            assert_eq!(c.skill_notes, plain.skill_notes);
            assert_eq!(c.learned_notes, plain.learned_notes);
            assert_eq!(c.audit(), plain.audit());
        }
    }

    #[test]
    fn cache_invalidates_when_generation_advances() {
        use super::super::skill_store::{SkillObs, SkillStore};
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let dev = DeviceSpec::a100_like();
        let cost = price(&task.graph, &sched, &dev);
        let raw = synthesize(&task.graph, &sched, &cost, ToolVersion::Ncu2023);
        let feats = ground_truth(&task.graph, &sched);
        let mut store = SkillStore::new();
        store.observe(&SkillObs {
            case_id: "gemm.naive_loop".to_string(),
            method: MethodId::TileSmem,
            gain: Some(2.5),
            device: dev.name.to_string(),
        });
        let mut cache = RetrievalCache::new();
        let _ = retrieve_for_with_cache(
            &task,
            &feats,
            &raw,
            Some(&store),
            dev.name,
            Some(&mut cache),
        );
        // New fold epoch + fresh evidence: the staleness-decayed notes
        // change, and a stale cache would serve the old bytes.
        store.advance_generation();
        store.observe(&SkillObs {
            case_id: "gemm.naive_loop".to_string(),
            method: MethodId::TileSmem,
            gain: Some(1.0),
            device: dev.name.to_string(),
        });
        let plain = retrieve_for_with(&task, &feats, &raw, Some(&store), dev.name);
        let cached = retrieve_for_with_cache(
            &task,
            &feats,
            &raw,
            Some(&store),
            dev.name,
            Some(&mut cache),
        );
        assert_eq!(cached.skill_notes, plain.skill_notes);
        assert_eq!(cached.allowed_methods, plain.allowed_methods);
    }

    #[test]
    fn retrieval_is_deterministic() {
        let task = appendix_d_task();
        let sched = Schedule::per_op_naive(&task.graph);
        let a = retrieval_at(&task, &sched);
        let b = retrieval_at(&task, &sched);
        assert_eq!(a.matched_case, b.matched_case);
        assert_eq!(a.allowed_methods, b.allowed_methods);
    }
}
