//! Long-term memory: the externalized expert-knowledge store (§4.2.1) —
//! a Deterministic Decision Policy (normalize -> derive -> tier -> match ->
//! veto) plus the Method Knowledge (`llm_assist`) store, and the persistent
//! learned layer (`skill_store`, v3: device-partitioned,
//! confidence-weighted, generation-aged) that survives across tasks,
//! seeds, strategies, and processes.

pub mod derived;
pub mod kb_content;
pub mod normalize;
pub mod retrieval;
pub mod schema;
pub mod skill_store;

pub use skill_store::{SkillObs, SkillStore};
