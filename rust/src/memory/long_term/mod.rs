//! Long-term memory: the externalized expert-knowledge store (§4.2.1) —
//! a Deterministic Decision Policy (normalize -> derive -> tier -> match ->
//! veto) plus the Method Knowledge (`llm_assist`) store, and the persistent
//! learned layer (`skill_store`, v4: device-partitioned,
//! confidence-weighted, generation-aged, with a segmented on-disk layout
//! (`segmented`) and matchable learned cases) that survives across tasks,
//! seeds, strategies, and processes. `diff` compares two stores for the
//! `skills diff` CLI; `overlay` builds per-job copy-on-write heads over a
//! shared segmented base for the multi-tenant service.

pub mod derived;
pub mod diff;
pub mod kb_content;
pub mod normalize;
pub mod overlay;
pub mod retrieval;
pub mod schema;
pub mod segmented;
pub mod skill_store;

pub use overlay::create_overlay;
pub use segmented::SegmentedSkillStore;
pub use skill_store::{SkillObs, SkillStore};
