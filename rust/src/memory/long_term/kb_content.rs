//! The curated long-term knowledge base: predicate library, decision table,
//! global veto rules, and the `llm_assist` method-knowledge store.
//!
//! Content is distilled from the GPU-optimization survey taxonomy the paper
//! cites (Hijma et al., CSUR 2023) following the paper's three-step curation:
//! scenario abstraction -> evidence formalization -> rule materialization.
//! Every entry is data, not code: auditable, printable, and extensible.

use crate::util::lazy::Lazy;

use super::schema::{
    Bottleneck, DecisionCase, ForbiddenRule, Gain, MethodKnowledge, NamedPred, Pred, Tier,
};
use crate::kir::transforms::MethodId;

// ------------------------------------------------------------------------
// ncu_predicates — the reusable Boolean predicate library (field 7).
// ------------------------------------------------------------------------

/// The reusable Boolean predicate library (`ncu_predicates`, field 7):
/// every named profiling condition decision-case signatures can reference.
pub static PREDICATES: Lazy<Vec<NamedPred>> = Lazy::new(|| {
    vec![
        NamedPred {
            name: "dram_saturated",
            pred: Pred::Gt("dram_pct", 55.0),
        },
        NamedPred {
            name: "compute_saturated",
            pred: Pred::Gt("sm_pct", 55.0),
        },
        NamedPred {
            name: "tensor_idle",
            pred: Pred::Lt("tensor_pipe_pct", 10.0),
        },
        NamedPred {
            name: "tensor_busy",
            pred: Pred::Gt("tensor_pipe_pct", 40.0),
        },
        NamedPred {
            name: "memory_stalls",
            pred: Pred::Gt("stall_memory_pct", 25.0),
        },
        NamedPred {
            name: "bank_conflicts",
            pred: Pred::Gt("stall_bank_conflict_pct", 8.0),
        },
        NamedPred {
            name: "low_occupancy",
            pred: Pred::Lt("occupancy_pct", 40.0),
        },
        NamedPred {
            name: "poor_coalescing",
            pred: Pred::Gt("drv.coalescing_deficit", 40.0),
        },
        NamedPred {
            name: "gemm_restreaming",
            pred: Pred::Is("drv.gemm_restreaming"),
        },
        NamedPred {
            name: "mxu_opportunity",
            pred: Pred::Is("drv.mxu_opportunity"),
        },
        NamedPred {
            name: "launch_heavy",
            pred: Pred::Gt("drv.launch_bound_pct", 18.0),
        },
        NamedPred {
            name: "fusion_debt",
            pred: Pred::Gt("drv.fusion_debt", 1.5),
        },
        NamedPred {
            name: "near_roofline",
            pred: Pred::Gt("drv.peak_pct", 78.0),
        },
        NamedPred {
            name: "memory_dominant",
            pred: Pred::Gt("drv.memory_over_compute", 15.0),
        },
        NamedPred {
            name: "has_reduction",
            pred: Pred::Gt("feat.reduction_pattern", 0.5),
        },
        NamedPred {
            name: "divergent",
            pred: Pred::Is("feat.divergence_risk"),
        },
        NamedPred {
            name: "uses_atomics",
            pred: Pred::Is("feat.uses_atomics"),
        },
        NamedPred {
            name: "grid_starved",
            pred: Pred::Gt("feat.occupancy_limiter", 2.5),
        },
        NamedPred {
            name: "l2_friendly",
            pred: Pred::Lt("l2_hit_pct", 40.0),
        },
    ]
});

/// Look up a named predicate from the `PREDICATES` library.
pub fn predicate(name: &str) -> Option<&'static NamedPred> {
    PREDICATES.iter().find(|p| p.name == name)
}

// ------------------------------------------------------------------------
// decision_table (field 9) — bottleneck x headroom x code-gates -> methods.
// ------------------------------------------------------------------------

/// The curated decision table (field 9): bottleneck x headroom-tier x
/// code-feature gates -> priority-ordered method sets. Retrieval walks it
/// in [`super::schema::BOTTLENECK_PRIORITY`] order and takes the first
/// case whose signature, tier, and gate all hold.
pub static DECISION_TABLE: Lazy<Vec<DecisionCase>> = Lazy::new(|| {
    use MethodId::*;
    vec![
        // ---- GEMM efficiency (the motivating example's fix, priority 1) --
        DecisionCase {
            id: "gemm.structured_operand",
            bottleneck: Bottleneck::GemmUnderutilized,
            ncu_signature: vec![],
            tiers: vec![Tier::High, Tier::Medium, Tier::Low],
            gate_when: Pred::Is("feat.structured_operand"),
            allowed_methods: vec![SpecializeStructure],
            why: "The operand has exploitable structure the reference \
                  densifies (diagonal/triangular/banded); skipping the dense \
                  work dwarfs every schedule-level optimization.",
        },
        DecisionCase {
            id: "gemm.naive_loop",
            bottleneck: Bottleneck::GemmUnderutilized,
            ncu_signature: vec!["tensor_idle"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Is("feat.naive_gemm_loop"),
                Pred::Any(vec![
                    Pred::Is("drv.gemm_restreaming"),
                    Pred::Gt("stall_memory_pct", 25.0),
                    Pred::Gt("drv.memory_over_compute", 15.0),
                ]),
            ]),
            allowed_methods: vec![TileSmem],
            why: "A GEMM streaming full K-strips per output block is the \
                  dominant inefficiency; blocking through scratch must land \
                  before any epilogue work.",
        },
        DecisionCase {
            id: "gemm.no_tensor_core",
            bottleneck: Bottleneck::GemmUnderutilized,
            ncu_signature: vec!["tensor_idle", "mxu_opportunity"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Is("feat.smem_tiling"),
                Pred::Not("feat.tensor_core"),
            ]),
            allowed_methods: vec![UseTensorCore],
            why: "Staged, blocked GEMM still on the FP32 vector pipe: moving \
                  math to the matrix unit is the single largest win left.",
        },
        DecisionCase {
            id: "gemm.exposed_pipeline",
            bottleneck: Bottleneck::GemmUnderutilized,
            ncu_signature: vec!["memory_stalls"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Is("feat.smem_tiling"),
                Pred::Not("feat.double_buffered"),
            ]),
            allowed_methods: vec![DoubleBuffer, VectorizeLoads],
            why: "Copy latency is exposed between tiles; prefetch the next \
                  tile while computing (cp.async / pipelined BlockSpec grid).",
        },
        DecisionCase {
            id: "gemm.bank_conflicts",
            bottleneck: Bottleneck::GemmUnderutilized,
            ncu_signature: vec!["bank_conflicts"],
            tiers: vec![Tier::High, Tier::Medium, Tier::Low],
            gate_when: Pred::Is("feat.bank_conflict_risk"),
            allowed_methods: vec![PadScratch],
            why: "Staged operands without padding serialize scratch access.",
        },
        DecisionCase {
            id: "gemm.small_m_splitk",
            bottleneck: Bottleneck::LowOccupancy,
            ncu_signature: vec!["low_occupancy", "tensor_busy"],
            tiers: vec![Tier::Medium, Tier::High],
            gate_when: Pred::All(vec![
                Pred::Is("task.has_gemm"),
                Pred::Lt("feat.reduction_pattern", 0.5),
            ]),
            allowed_methods: vec![SplitK, IncreaseOccupancy],
            why: "Few output tiles leave the device idle; split the \
                  contraction across blocks and combine.",
        },
        // ---- Access patterns ---------------------------------------------
        DecisionCase {
            id: "access.strided",
            bottleneck: Bottleneck::PoorAccessPattern,
            ncu_signature: vec!["poor_coalescing"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::Is("feat.strided_access"),
            allowed_methods: vec![CoalesceAccesses, TiledLayout],
            why: "Strided global access wastes most of each memory \
                  transaction; reorder indexing (or swizzle the staged tile).",
        },
        DecisionCase {
            id: "access.narrow_loads",
            bottleneck: Bottleneck::PoorAccessPattern,
            ncu_signature: vec!["memory_dominant"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Not("feat.vectorized_loads"),
                Pred::Is("feat.coalesced_access"),
            ]),
            allowed_methods: vec![VectorizeLoads],
            why: "Coalesced but narrow accesses leave bus width unused; issue \
                  128-bit (lane-aligned) loads.",
        },
        // ---- Fusion --------------------------------------------------------
        DecisionCase {
            id: "fusion.epilogue_reduction",
            bottleneck: Bottleneck::FusionOpportunity,
            ncu_signature: vec!["fusion_debt"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Gt("feat.reduction_pattern", 0.5),
                Pred::Gt("feat.fusion_opportunities", 0.5),
            ]),
            allowed_methods: vec![FuseEpilogueReduction, FuseElementwise],
            why: "A row-reduction epilogue and its elementwise tail can ride \
                  in the producer kernel: one HBM round-trip instead of three.",
        },
        DecisionCase {
            id: "fusion.elementwise_chain",
            bottleneck: Bottleneck::FusionOpportunity,
            ncu_signature: vec!["fusion_debt"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::Gt("feat.fusion_opportunities", 0.5),
            allowed_methods: vec![FuseElementwise],
            why: "Adjacent elementwise kernels bounce intermediates through \
                  HBM; inline the consumer into the producer.",
        },
        // ---- Reductions ----------------------------------------------------
        DecisionCase {
            id: "reduction.scalar_tree",
            bottleneck: Bottleneck::ReductionInefficiency,
            ncu_signature: vec!["memory_stalls"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::Gt("feat.reduction_pattern", 0.5),
            allowed_methods: vec![WarpReduceShuffle, VectorizeLoads],
            why: "Reduction built through scratch with narrow loads; use lane \
                  shuffles and wide loads for the tree.",
        },
        DecisionCase {
            id: "access.transpose_movement",
            bottleneck: Bottleneck::PoorAccessPattern,
            ncu_signature: vec!["poor_coalescing", "memory_dominant"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![
                Pred::Is("feat.strided_access"),
                Pred::Not("task.has_gemm"),
            ]),
            allowed_methods: vec![CoalesceAccesses, TiledLayout, VectorizeLoads],
            why: "Pure data-movement kernels (transpose/gather) live or die \
                  on transaction efficiency; fix the walk order, then stage \
                  through a swizzled tile for the written side.",
        },
        DecisionCase {
            id: "reduction.divergent_indexing",
            bottleneck: Bottleneck::ReductionInefficiency,
            ncu_signature: vec!["divergent"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::Gt("feat.reduction_pattern", 0.5),
            allowed_methods: vec![VectorizeLoads, CacheBlocking],
            why: "Data-dependent lanes (argmin/argmax, gathers) cannot use \
                  plain lane shuffles; wide loads + cache blocking recover \
                  most of the bandwidth instead.",
        },
        DecisionCase {
            id: "membw.atomic_contention",
            bottleneck: Bottleneck::MemoryBandwidth,
            ncu_signature: vec!["uses_atomics", "memory_stalls"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![]),
            allowed_methods: vec![WarpReduceShuffle, CacheBlocking],
            why: "Cross-block atomics serialize on hot addresses; reduce \
                  within the block first so each block issues one atomic.",
        },
        // ---- Plain memory bandwidth ---------------------------------------
        DecisionCase {
            id: "membw.streaming",
            bottleneck: Bottleneck::MemoryBandwidth,
            ncu_signature: vec!["dram_saturated", "memory_dominant"],
            tiers: vec![Tier::Medium, Tier::High],
            gate_when: Pred::All(vec![
                Pred::Is("feat.coalesced_access"),
                Pred::Not("task.has_gemm"),
            ]),
            allowed_methods: vec![VectorizeLoads, CacheBlocking, AsyncPrefetch],
            why: "Streaming kernel already coalesced: widen accesses, block \
                  for cache, overlap copies.",
        },
        // ---- Launch overhead ------------------------------------------------
        DecisionCase {
            id: "launch.many_small",
            bottleneck: Bottleneck::LaunchOverhead,
            ncu_signature: vec!["launch_heavy"],
            tiers: vec![Tier::High, Tier::Medium, Tier::Low],
            gate_when: Pred::Gt("feat.kernel_launches", 3.5),
            allowed_methods: vec![FuseElementwise, HorizontalFuse],
            why: "Fixed launch cost dominates many tiny kernels; merge \
                  producer-consumer pairs first, then batch independents.",
        },
        DecisionCase {
            id: "launch.tiny_single_kernel",
            bottleneck: Bottleneck::LaunchOverhead,
            ncu_signature: vec!["launch_heavy"],
            tiers: vec![Tier::High, Tier::Medium, Tier::Low],
            gate_when: Pred::Lt("feat.kernel_launches", 3.5),
            allowed_methods: vec![LaunchTune],
            why: "A single tiny kernel is launch-bound by definition; only \
                  geometry tuning is left (batching needs more kernels).",
        },
        // ---- Occupancy -------------------------------------------------------
        DecisionCase {
            id: "occupancy.resource_bound",
            bottleneck: Bottleneck::LowOccupancy,
            ncu_signature: vec!["low_occupancy"],
            tiers: vec![Tier::Medium, Tier::High],
            gate_when: Pred::Gt("feat.occupancy_limiter", 0.5),
            allowed_methods: vec![IncreaseOccupancy, RecomputeCheap, LaunchTune],
            why: "Blocks are starved by scratch/register appetite; shrink \
                  tiles or recompute cheap values instead of spilling.",
        },
        DecisionCase {
            id: "occupancy.grid_starved",
            bottleneck: Bottleneck::LowOccupancy,
            ncu_signature: vec!["low_occupancy", "grid_starved"],
            tiers: vec![Tier::High, Tier::Medium],
            gate_when: Pred::All(vec![]),
            allowed_methods: vec![IncreaseOccupancy, SplitK, LaunchTune],
            why: "The grid itself is too small for the device (few huge \
                  tiles): shrink tiles or split the contraction for \
                  parallelism before touching anything else.",
        },
        // ---- Near roofline: polish only -------------------------------------
        DecisionCase {
            id: "roofline.polish",
            bottleneck: Bottleneck::NearRoofline,
            ncu_signature: vec!["near_roofline"],
            tiers: vec![Tier::Low],
            gate_when: Pred::All(vec![]),
            allowed_methods: vec![UnrollInner, LaunchTune, PrecisionDowncast],
            why: "Within ~20% of a peak: only micro-knobs remain; avoid \
                  speculative restructuring.",
        },
    ]
});

// ------------------------------------------------------------------------
// global_forbidden_rules (field 8) — veto constraints.
// ------------------------------------------------------------------------

/// The global veto rules (field 8): while a rule's predicate holds, its
/// methods are removed from every matched case (step 7 of retrieval).
pub static FORBIDDEN_RULES: Lazy<Vec<ForbiddenRule>> = Lazy::new(|| {
    use MethodId::*;
    vec![
        ForbiddenRule {
            id: "strict_no_downcast",
            when: Pred::Is("task.strict"),
            veto: vec![PrecisionDowncast],
            why: "Task verifies under a tight tolerance; narrowing the math \
                  path risks verification failure.",
        },
        ForbiddenRule {
            id: "mxu_needs_alignment",
            when: Pred::Not("task.mxu_alignable"),
            veto: vec![UseTensorCore],
            why: "Matrix-unit fragments need 8-aligned dims; ragged shapes \
                  would need padding the task does not allow.",
        },
        ForbiddenRule {
            id: "splitk_vs_reduction",
            when: Pred::Gt("feat.reduction_pattern", 0.5),
            veto: vec![SplitK],
            why: "Split-K partial combine conflicts with a fused reduction \
                  epilogue (cross-block dataflow).",
        },
        ForbiddenRule {
            id: "no_fusion_under_register_pressure",
            when: Pred::Gt("feat.register_pressure", 1.5),
            veto: vec![FuseElementwise, FuseEpilogueReduction],
            why: "Fusing more work into a register-starved kernel forces \
                  spills that cost more than the saved traffic.",
        },
        ForbiddenRule {
            id: "scratch_budget_guard",
            when: Pred::Gt("scratch_bytes", 96.0 * 1024.0),
            veto: vec![DoubleBuffer],
            why: "Double buffering doubles scratch residency; over ~96KB the \
                  occupancy loss exceeds the pipelining gain.",
        },
        ForbiddenRule {
            id: "no_fission_single_kernel",
            when: Pred::Lt("feat.kernel_launches", 1.5),
            veto: vec![KernelFission],
            why: "Nothing to split: the task is already one kernel.",
        },
    ]
});

// ------------------------------------------------------------------------
// llm_assist (field 10) — Method Knowledge store.
// ------------------------------------------------------------------------

/// The `llm_assist` Method Knowledge store (field 10): per-method
/// rationale, implementation cues, expected gain, and known risks attached
/// to retrieval results for the Planner.
pub static METHOD_KNOWLEDGE: Lazy<Vec<MethodKnowledge>> = Lazy::new(|| {
    use MethodId::*;
    vec![
        MethodKnowledge {
            method: SpecializeStructure,
            rationale: "When an operand is diagonal/triangular/banded, the \
                        dense reference performs O(n) to O(n^2) redundant \
                        work per output; a structure-aware kernel indexes \
                        only the nonzero pattern.",
            cues: "Diagonal B: out[i][j] = A[i][j] * d[j] (one multiply per \
                   element, no contraction loop). Triangular: bound the K \
                   loop at the diagonal. Banded: clamp K to the band. \
                   TPU/Pallas: express as elementwise or short-K BlockSpec.",
            expected_gain: Gain::Large,
            risks: "Indexing subtleties (band offsets, unit diagonals) make \
                    this the most numerics-bug-prone rewrite in the library.",
        },
        MethodKnowledge {
            method: TileSmem,
            rationale: "Blocking the contraction through scratch converts \
                        O(N/t) operand re-reads into one cooperative load per \
                        tile — the canonical fix for a naive GEMM loop.",
            cues: "CUDA: __shared__ A_tile[tm][tk], B_tile[tk][tn]; loop over \
                   K in tk steps; __syncthreads() between load/compute. \
                   TPU/Pallas: BlockSpec((tm, tk), (i,k)) x ((tk, tn), (k,j)) \
                   with an accumulating out block over the k grid axis.",
            expected_gain: Gain::Large,
            risks: "Off-by-one on tail tiles; missing sync (race); scratch \
                    over-allocation killing occupancy.",
        },
        MethodKnowledge {
            method: UseTensorCore,
            rationale: "Matrix units deliver ~8x the FP32 vector pipe for \
                        dense contractions at TF32/BF16.",
            cues: "CUDA: wmma/mma.sync on 16x16x16 fragments, f32 accumulate. \
                   TPU/Pallas: jnp.dot(..., preferred_element_type=f32) on \
                   bf16 tiles — the MXU systolic path.",
            expected_gain: Gain::Large,
            risks: "Alignment padding; accuracy drift on strict tasks; \
                    fragment underfill on small tiles.",
        },
        MethodKnowledge {
            method: VectorizeLoads,
            rationale: "128-bit loads quadruple bytes-per-transaction on \
                        coalesced streams.",
            cues: "CUDA: float4 / ld.global.v4 with 16B-aligned pointers. \
                   TPU: keep the last dim a multiple of the 128-lane register.",
            expected_gain: Gain::Medium,
            risks: "Misaligned base pointers fault; tail elements need a \
                    scalar epilogue.",
        },
        MethodKnowledge {
            method: CoalesceAccesses,
            rationale: "Threads in a warp touching contiguous addresses turn \
                        32 transactions into one.",
            cues: "Swap the index roles so threadIdx.x walks the contiguous \
                   dim; or transpose via a staged tile.",
            expected_gain: Gain::Large,
            risks: "Easy to silently change the output layout.",
        },
        MethodKnowledge {
            method: TiledLayout,
            rationale: "Swizzled scratch layouts keep both the load and the \
                        compute phases conflict-free.",
            cues: "XOR-swizzle the scratch column index; Pallas: let the \
                   compiler pick via BlockSpec, avoid manual transposes.",
            expected_gain: Gain::Small,
            risks: "Index arithmetic bugs dominate this edit.",
        },
        MethodKnowledge {
            method: FuseElementwise,
            rationale: "An elementwise consumer re-reads its producer's whole \
                        output; inlining it is free compute on in-flight data.",
            cues: "Apply the epilogue op to the accumulator before the store; \
                   preserve the original store layout.",
            expected_gain: Gain::Medium,
            risks: "Fusing into a register-starved kernel causes spills.",
        },
        MethodKnowledge {
            method: FuseEpilogueReduction,
            rationale: "Row reductions over a producer's output can ride the \
                        producer's tiles: keep running max/sum per row strip.",
            cues: "CUDA: block-level partial reduction + one cross-block pass. \
                   Pallas: row-blocked kernel, jnp.max/sum over the strip \
                   (logsumexp: track (m, sum_exp) pairs).",
            expected_gain: Gain::Large,
            risks: "Numerically unstable if the running-max rewrite is \
                    skipped; this is a coupled multi-step edit.",
        },
        MethodKnowledge {
            method: HorizontalFuse,
            rationale: "Independent small kernels can share one launch to \
                        amortize fixed cost.",
            cues: "Batch same-shape elementwise ops into one grid with a \
                   block-id switch; or CUDA Graphs for the launch sequence.",
            expected_gain: Gain::Medium,
            risks: "Divergence between batched bodies erodes the win.",
        },
        MethodKnowledge {
            method: DoubleBuffer,
            rationale: "Prefetching tile k+1 while computing tile k hides copy \
                        latency behind math.",
            cues: "CUDA: cp.async into the alternate buffer + commit/wait. \
                   Pallas: the grid pipeline does this when in/out specs \
                   differ in the k axis; keep two live buffers in VMEM.",
            expected_gain: Gain::Medium,
            risks: "Doubles scratch footprint; wrong wait-stage deadlocks or \
                    races.",
        },
        MethodKnowledge {
            method: UnrollInner,
            rationale: "Unrolling exposes independent FMAs to the scheduler \
                        and trims loop overhead.",
            cues: "#pragma unroll 4 on the K-fragment loop; keep an eye on \
                   register count.",
            expected_gain: Gain::Small,
            risks: "Register pressure; icache misses on huge bodies.",
        },
        MethodKnowledge {
            method: PadScratch,
            rationale: "A +1 column pad de-conflicts power-of-two row strides \
                        across scratch banks.",
            cues: "__shared__ float tile[TM][TK+1]; TPU: pad the minor dim \
                   off the 128-lane boundary.",
            expected_gain: Gain::Small,
            risks: "Footprint creep past the scratch budget.",
        },
        MethodKnowledge {
            method: IncreaseOccupancy,
            rationale: "More resident blocks hide latency when a kernel is \
                        neither bandwidth- nor compute-saturated.",
            cues: "Halve the tile, cap registers (__launch_bounds__), retune \
                   block size.",
            expected_gain: Gain::Medium,
            risks: "Smaller tiles reduce reuse — can backfire on GEMMs.",
        },
        MethodKnowledge {
            method: SplitK,
            rationale: "Small-output GEMMs under-fill the device; splitting K \
                        multiplies available parallelism.",
            cues: "Partial accumulators per K-slice + a second combine kernel \
                   (or atomics at low split factors).",
            expected_gain: Gain::Medium,
            risks: "Combine-pass traffic; floating-point non-determinism; \
                    illegal with a fused reduction epilogue.",
        },
        MethodKnowledge {
            method: PrecisionDowncast,
            rationale: "TF32/BF16 inputs double-to-octuple math throughput \
                        while keeping f32 accumulation.",
            cues: "cublasSetMathMode / explicit __nv_bfloat16 casts; Pallas: \
                   operands .astype(bf16), accumulate f32.",
            expected_gain: Gain::Medium,
            risks: "Verification failure on strict-tolerance tasks.",
        },
        MethodKnowledge {
            method: LaunchTune,
            rationale: "Block geometry interacts with occupancy and tail \
                        effects; a sweep is cheap.",
            cues: "Try 128/256/512 threads; prefer multiples of the wave size.",
            expected_gain: Gain::Small,
            risks: "Mostly none; occasionally perturbs a tuned balance.",
        },
        MethodKnowledge {
            method: KernelFission,
            rationale: "Over-fused kernels can exceed resource budgets; \
                        splitting restores occupancy.",
            cues: "Move the tail op into its own kernel; re-check traffic.",
            expected_gain: Gain::Small,
            risks: "Reintroduces intermediate traffic.",
        },
        MethodKnowledge {
            method: RecomputeCheap,
            rationale: "Recomputing cheap values beats spilling registers to \
                        local memory.",
            cues: "Drop cached indices/masks that are one ALU op to rebuild.",
            expected_gain: Gain::Small,
            risks: "Recomputing expensive expressions backfires.",
        },
        MethodKnowledge {
            method: WarpReduceShuffle,
            rationale: "Lane shuffles reduce within a warp registers-only; \
                        scratch is touched once per warp, not per element.",
            cues: "CUDA: __shfl_down_sync tree then one scratch slot per \
                   warp. TPU/Pallas: keep the reduction in the 8x128 register \
                   tile; jnp.max/sum over the minor axis.",
            expected_gain: Gain::Medium,
            risks: "Width/mask bugs produce silently wrong sums.",
        },
        MethodKnowledge {
            method: AsyncPrefetch,
            rationale: "Memory-bound streaming kernels can overlap the next \
                        block's loads with this block's math.",
            cues: "cp.async / software pipelining; Pallas: stage through VMEM \
                   with a lookahead block index.",
            expected_gain: Gain::Medium,
            risks: "Scratch footprint; stale-buffer bugs.",
        },
        MethodKnowledge {
            method: CacheBlocking,
            rationale: "Blocking a large streaming op for L2 keeps its reuse \
                        window resident.",
            cues: "Process the tensor in L2-sized row panels.",
            expected_gain: Gain::Small,
            risks: "Wrong block size just adds loop overhead.",
        },
    ]
});

/// Look up the `METHOD_KNOWLEDGE` entry for one method.
pub fn knowledge_for(method: MethodId) -> Option<&'static MethodKnowledge> {
    METHOD_KNOWLEDGE.iter().find(|k| k.method == method)
}

/// Serialize the curated knowledge base (predicate library, decision table,
/// veto rules, method knowledge) to JSON. The suite orchestrator writes
/// this next to the learned skill store so a memory directory is a complete,
/// self-describing snapshot of long-term memory — curated + learned.
pub fn export_kb() -> crate::util::json::Json {
    use crate::util::json::{arr, num, obj, s, Json};

    let predicates = PREDICATES
        .iter()
        .map(|p| obj(vec![("name", s(p.name)), ("pred", s(&p.pred.render()))]))
        .collect();
    let table = DECISION_TABLE
        .iter()
        .map(|c| {
            obj(vec![
                ("id", s(c.id)),
                ("bottleneck", s(&format!("{:?}", c.bottleneck))),
                (
                    "ncu_signature",
                    arr(c.ncu_signature.iter().map(|&n| s(n)).collect()),
                ),
                (
                    "tiers",
                    arr(c.tiers.iter().map(|t| s(&format!("{t:?}"))).collect()),
                ),
                ("gate_when", s(&c.gate_when.render())),
                (
                    "allowed_methods",
                    arr(c.allowed_methods.iter().map(|m| s(m.name())).collect()),
                ),
                ("why", s(c.why)),
            ])
        })
        .collect();
    let forbidden = FORBIDDEN_RULES
        .iter()
        .map(|r| {
            obj(vec![
                ("id", s(r.id)),
                ("when", s(&r.when.render())),
                ("veto", arr(r.veto.iter().map(|m| s(m.name())).collect())),
                ("why", s(r.why)),
            ])
        })
        .collect();
    let knowledge = METHOD_KNOWLEDGE
        .iter()
        .map(|k| {
            obj(vec![
                ("method", s(k.method.name())),
                ("rationale", s(k.rationale)),
                ("cues", s(k.cues)),
                ("expected_gain", s(&format!("{:?}", k.expected_gain))),
                ("risks", s(k.risks)),
            ])
        })
        .collect();
    obj(vec![
        ("version", num(1.0)),
        ("predicates", Json::Arr(predicates)),
        ("decision_table", Json::Arr(table)),
        ("forbidden_rules", Json::Arr(forbidden)),
        ("method_knowledge", Json::Arr(knowledge)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::transforms::ALL_METHODS;

    #[test]
    fn every_method_has_knowledge() {
        for m in ALL_METHODS {
            assert!(knowledge_for(m).is_some(), "{m:?} missing llm_assist entry");
        }
    }

    #[test]
    fn every_case_signature_resolves() {
        for case in DECISION_TABLE.iter() {
            for sig in &case.ncu_signature {
                assert!(
                    predicate(sig).is_some(),
                    "case {} references unknown predicate {sig}",
                    case.id
                );
            }
            assert!(!case.allowed_methods.is_empty() || case.id == "roofline.stop");
        }
    }

    #[test]
    fn case_ids_unique() {
        let mut ids: Vec<&str> = DECISION_TABLE.iter().map(|c| c.id).collect();
        ids.sort();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn every_bottleneck_has_a_case() {
        use super::super::schema::BOTTLENECK_PRIORITY;
        for b in BOTTLENECK_PRIORITY {
            assert!(
                DECISION_TABLE.iter().any(|c| c.bottleneck == b),
                "no case for {b:?}"
            );
        }
    }

    #[test]
    fn kb_export_parses_and_is_complete() {
        let j = export_kb();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        let table = parsed.get("decision_table").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(table.len(), DECISION_TABLE.len());
        let mk = parsed.get("method_knowledge").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(mk.len(), METHOD_KNOWLEDGE.len());
        let preds = parsed.get("predicates").and_then(|t| t.as_arr()).unwrap();
        assert_eq!(preds.len(), PREDICATES.len());
    }

    #[test]
    fn veto_rules_reference_real_methods() {
        for r in FORBIDDEN_RULES.iter() {
            assert!(!r.veto.is_empty(), "{}", r.id);
        }
    }
}
