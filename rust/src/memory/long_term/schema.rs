//! Long-term memory schema — the ten Appendix-B fields as concrete types.
//!
//! Everything downstream of `field_mapping` operates on an [`Evidence`] map
//! of standardized fields (profiling metrics, run features, code features,
//! and derived fields all share one namespace), so predicates and decision
//! cases are uniform, printable, and auditable.

use std::collections::BTreeMap;

use crate::kir::transforms::MethodId;

/// Standardized evidence: field name -> value. Conventions:
///   * NCU-derived percentages:   `dram_pct`, `sm_pct`, ... in [0, 100]
///   * nsys run features:         `run.kernel_launch_count`, ...
///   * code features:             `feat.naive_gemm_loop` (0/1), ...
///   * task facts:                `task.strict` (0/1), `task.mxu_alignable`
///   * derived fields:            `drv.headroom_pct`, ...
pub type Evidence = BTreeMap<&'static str, f64>;

/// Optimization-headroom tier (Appendix-B field 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    /// Little headroom left: the kernel is close to its roofline.
    Low,
    /// Moderate headroom: targeted fixes still pay off.
    Medium,
    /// Large headroom: structural optimizations are on the table.
    High,
}

/// Bottleneck taxonomy used by `decision_table` signatures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Bottleneck {
    /// A GEMM running far off the matrix unit (the motivating example).
    GemmUnderutilized,
    /// Uncoalesced / strided global access.
    PoorAccessPattern,
    /// Producer-consumer intermediates bouncing through HBM.
    FusionOpportunity,
    /// Reduction tree built without lane primitives / wide loads.
    ReductionInefficiency,
    /// Saturated DRAM on an already-coalesced kernel.
    MemoryBandwidth,
    /// Fixed launch cost dominating (deep L3 graphs).
    LaunchOverhead,
    /// Grid/resources under-filling the device.
    LowOccupancy,
    /// Close to roofline; only polish remains.
    NearRoofline,
}

/// A reusable Boolean predicate over standardized evidence (Appendix-B
/// field 7, `ncu_predicates`). The tree form keeps every decision printable
/// for the audit trail.
#[derive(Debug, Clone, PartialEq)]
pub enum Pred {
    /// field > threshold
    Gt(&'static str, f64),
    /// field < threshold
    Lt(&'static str, f64),
    /// boolean field (0/1) is set
    Is(&'static str),
    /// boolean field (0/1) is clear
    Not(&'static str),
    /// conjunction: every sub-predicate holds
    All(Vec<Pred>),
    /// disjunction: at least one sub-predicate holds
    Any(Vec<Pred>),
}

impl Pred {
    /// Evaluate against evidence; missing fields read as 0.0 (absent signal).
    pub fn eval(&self, ev: &Evidence) -> bool {
        let get = |f: &&'static str| ev.get(f).copied().unwrap_or(0.0);
        match self {
            Pred::Gt(f, t) => get(f) > *t,
            Pred::Lt(f, t) => get(f) < *t,
            Pred::Is(f) => get(f) > 0.5,
            Pred::Not(f) => get(f) <= 0.5,
            Pred::All(ps) => ps.iter().all(|p| p.eval(ev)),
            Pred::Any(ps) => ps.iter().any(|p| p.eval(ev)),
        }
    }

    /// Render for the audit trail.
    pub fn render(&self) -> String {
        match self {
            Pred::Gt(f, t) => format!("{f} > {t}"),
            Pred::Lt(f, t) => format!("{f} < {t}"),
            Pred::Is(f) => format!("{f}"),
            Pred::Not(f) => format!("!{f}"),
            Pred::All(ps) => format!(
                "({})",
                ps.iter().map(|p| p.render()).collect::<Vec<_>>().join(" & ")
            ),
            Pred::Any(ps) => format!(
                "({})",
                ps.iter().map(|p| p.render()).collect::<Vec<_>>().join(" | ")
            ),
        }
    }
}

/// A named predicate from the `ncu_predicates` library.
#[derive(Debug, Clone)]
pub struct NamedPred {
    /// Stable name decision-case signatures reference.
    pub name: &'static str,
    /// The predicate tree itself.
    pub pred: Pred,
}

/// One decision-table case (Appendix-B field 9).
#[derive(Debug, Clone)]
pub struct DecisionCase {
    /// Stable id, e.g. "gemm.naive_loop".
    pub id: &'static str,
    /// Bottleneck class this case addresses (priority resolution key).
    pub bottleneck: Bottleneck,
    /// Profiling signature: names into the `ncu_predicates` library.
    pub ncu_signature: Vec<&'static str>,
    /// Headroom tiers this case fires in.
    pub tiers: Vec<Tier>,
    /// Additional gating predicate over code features / evidence.
    pub gate_when: Pred,
    /// Candidate methods, priority-ordered.
    pub allowed_methods: Vec<MethodId>,
    /// Human rationale for the audit trail.
    pub why: &'static str,
}

/// A global veto rule (Appendix-B field 8).
#[derive(Debug, Clone)]
pub struct ForbiddenRule {
    /// Stable id surfaced in the audit trail.
    pub id: &'static str,
    /// When this predicate holds, the listed methods are vetoed everywhere.
    pub when: Pred,
    /// Methods removed from every case while `when` holds.
    pub veto: Vec<MethodId>,
    /// Human rationale for the audit trail.
    pub why: &'static str,
}

/// Expected-benefit class for `llm_assist` method knowledge.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Gain {
    /// Single-digit-percent improvements (polish).
    Small,
    /// Tens of percent.
    Medium,
    /// Multiples (structural fixes).
    Large,
}

/// How a learned decision case relates to the curated knowledge base.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LearnedOrigin {
    /// Evidence contradicts the curated priority order: a lower-priority
    /// (but curated-allowed) method consistently beats the first choice.
    Promotion,
    /// Evidence contradicts the curated recommendation outright: the
    /// curated first choice consistently fails on this hardware.
    Demotion,
    /// Evidence extends the curated method set: a method outside the
    /// case's `allowed_methods` consistently wins here.
    Extension,
}

impl LearnedOrigin {
    /// Stable serialization name.
    pub fn name(&self) -> &'static str {
        match self {
            LearnedOrigin::Promotion => "promotion",
            LearnedOrigin::Demotion => "demotion",
            LearnedOrigin::Extension => "extension",
        }
    }
}

/// Minimum attempts before a learned case may *change the method set*
/// during retrieval (as opposed to merely reranking and annotating
/// audits). Stricter than the synthesis floor (`MIN_LEARN_EVIDENCE`): a
/// case can exist — and be inspected — long before it is allowed to act.
pub const MIN_MATCH_EVIDENCE: u64 = 8;

/// Minimum Wilson-lower-bound confidence for a learned case to act during
/// retrieval. Together with [`MIN_MATCH_EVIDENCE`] this is the poison
/// gate: a noisy shard's flukes never clear both bars, so they cannot
/// perturb the curated table's method sets.
pub const MIN_MATCH_CONFIDENCE: f64 = 0.7;

/// A decision case synthesized from the learned skill store (skill-store
/// v4) when observed outcomes consistently contradict or extend the
/// curated decision table. Unlike [`DecisionCase`], a learned case is
/// *derived* — recomputed deterministically from the recorded stats, never
/// hand-authored — and is scoped to one device partition.
#[derive(Debug, Clone, PartialEq)]
pub struct LearnedCase {
    /// Device partition the evidence came from (`DeviceSpec::name`).
    pub device: String,
    /// Curated decision-table case id the evidence is about.
    pub base_case: String,
    /// Method the evidence concerns.
    pub method: MethodId,
    /// Relationship to the curated KB (promotion / demotion / extension).
    pub origin: LearnedOrigin,
    /// Attempts backing the synthesis.
    pub attempts: u64,
    /// Wins among those attempts.
    pub wins: u64,
    /// Mean speedup delta over winning attempts.
    pub mean_gain: f64,
    /// Wilson-lower-bound confidence in the observed direction.
    pub confidence: f64,
    /// Deterministic human rationale (audit trail).
    pub why: String,
}

impl LearnedCase {
    /// Stable id, e.g. `learned.gemm.naive_loop@tile_smem/a100-like`.
    pub fn id(&self) -> String {
        format!("learned.{}@{}/{}", self.base_case, self.method.name(), self.device)
    }

    /// One-line rendering for audit trails and `skills inspect`.
    pub fn render(&self) -> String {
        format!(
            "[{}] {}: {} (conf {:.2}, {} attempts)",
            self.origin.name(),
            self.id(),
            self.why,
            self.confidence,
            self.attempts
        )
    }

    /// True when the case has cleared the matchability bars
    /// ([`MIN_MATCH_EVIDENCE`], [`MIN_MATCH_CONFIDENCE`]) and may modify
    /// the retrieved method set, not just rerank it.
    pub fn matchable(&self) -> bool {
        self.attempts >= MIN_MATCH_EVIDENCE && self.confidence >= MIN_MATCH_CONFIDENCE
    }
}

/// Method Knowledge entry (Appendix-B field 10, the `llm_assist` store).
#[derive(Debug, Clone)]
pub struct MethodKnowledge {
    /// Method this knowledge is about.
    pub method: MethodId,
    /// Why this method addresses its bottleneck.
    pub rationale: &'static str,
    /// Concrete implementation cues (CUDA and TPU/Pallas vocabulary).
    pub cues: &'static str,
    /// Expected-benefit class when the method lands.
    pub expected_gain: Gain,
    /// Known failure modes the Optimizer should guard against.
    pub risks: &'static str,
}

/// Priority order for bottleneck resolution (Appendix-B field 6): when
/// several bottlenecks match, the earliest in this list wins. This ordering
/// IS the fix for the motivating example — the GEMM bottleneck outranks
/// fusion opportunities.
pub const BOTTLENECK_PRIORITY: [Bottleneck; 8] = [
    Bottleneck::GemmUnderutilized,
    Bottleneck::PoorAccessPattern,
    Bottleneck::FusionOpportunity,
    Bottleneck::ReductionInefficiency,
    Bottleneck::MemoryBandwidth,
    Bottleneck::LaunchOverhead,
    Bottleneck::LowOccupancy,
    Bottleneck::NearRoofline,
];

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(pairs: &[(&'static str, f64)]) -> Evidence {
        pairs.iter().copied().collect()
    }

    #[test]
    fn predicate_eval() {
        let e = ev(&[("dram_pct", 70.0), ("feat.naive_gemm_loop", 1.0)]);
        assert!(Pred::Gt("dram_pct", 60.0).eval(&e));
        assert!(!Pred::Lt("dram_pct", 60.0).eval(&e));
        assert!(Pred::Is("feat.naive_gemm_loop").eval(&e));
        assert!(Pred::Not("feat.smem_tiling").eval(&e));
        assert!(Pred::All(vec![
            Pred::Gt("dram_pct", 60.0),
            Pred::Is("feat.naive_gemm_loop")
        ])
        .eval(&e));
        assert!(
            Pred::Any(vec![Pred::Gt("dram_pct", 90.0), Pred::Is("feat.naive_gemm_loop")]).eval(&e)
        );
    }

    #[test]
    fn missing_fields_read_zero() {
        let e = Evidence::new();
        assert!(!Pred::Gt("nope", 0.5).eval(&e));
        assert!(Pred::Lt("nope", 0.5).eval(&e));
        assert!(Pred::Not("nope").eval(&e));
    }

    #[test]
    fn render_is_readable() {
        let p = Pred::All(vec![Pred::Gt("a", 1.0), Pred::Not("b")]);
        assert_eq!(p.render(), "(a > 1 & !b)");
    }

    #[test]
    fn matchable_requires_both_bars() {
        let mut lc = LearnedCase {
            device: "a100-like".into(),
            base_case: "c".into(),
            method: MethodId::TileSmem,
            origin: LearnedOrigin::Promotion,
            attempts: MIN_MATCH_EVIDENCE,
            wins: MIN_MATCH_EVIDENCE,
            mean_gain: 1.0,
            confidence: 0.88,
            why: "w".into(),
        };
        assert!(lc.matchable());
        lc.attempts = MIN_MATCH_EVIDENCE - 1;
        assert!(!lc.matchable(), "evidence bar");
        lc.attempts = MIN_MATCH_EVIDENCE;
        lc.confidence = MIN_MATCH_CONFIDENCE - 0.01;
        assert!(!lc.matchable(), "confidence bar");
    }

    #[test]
    fn priority_starts_with_gemm() {
        assert_eq!(BOTTLENECK_PRIORITY[0], Bottleneck::GemmUnderutilized);
        assert_eq!(*BOTTLENECK_PRIORITY.last().unwrap(), Bottleneck::NearRoofline);
    }
}
