//! Segmented on-disk layout for the long-term skill store (v4).
//!
//! A live memory-dir store at millions-of-runs scale cannot afford to
//! rewrite the whole world at every fold epoch. The segmented layout keeps
//! history in **immutable folded segments** — plain flat v4 stores under
//! `skills.segments/` — plus a small **active head** that absorbs the
//! current epoch's observations. The manifest (`skills.json`) is the head's
//! flat serialization with two twists:
//!
//! - its `segments` list names every segment file in canonical (oldest
//!   first) order, each with the `generation`/`observations`/`cases` the
//!   segment carries, and
//! - its `learned` section is derived from the **logical** store (head +
//!   every segment folded), so readers that only look at the manifest still
//!   see the synthesized decision cases for the whole history.
//!
//! The logical content of a segmented store is the [`SkillStore::merge_store`]
//! fold of head and segments — the same commutative/associative ExactSum
//! algebra the sharded suite's `merge` uses — so a segmented store folds to
//! **byte-identical** `canonical_bytes` as the equivalent one-blob store
//! (`docs/memory-formats.md`, invariant 17). [`SkillStore::load`] performs
//! that fold transparently; only *writers* (the suite scheduler, `run-task`,
//! the `skills` CLI) open the [`SegmentedSkillStore`] form.
//!
//! Epoch rotation ([`SegmentedSkillStore::advance_to`]) freezes the head
//! into a fresh segment file instead of rewriting accumulated history;
//! compaction ([`SegmentedSkillStore::compact`]) is an offline merge-shaped
//! job that folds N segments into one and swaps the manifest atomically —
//! segment files are immutable and names are never reused, so a reader
//! holding an older manifest keeps resolving every file it references.

use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

use super::skill_store::{GcReport, SkillObs, SkillStore};

/// Directory (relative to the manifest) holding immutable segment files.
pub const SEGMENT_DIR: &str = "skills.segments";

/// How many times `open` re-reads the manifest when a referenced segment
/// file vanishes mid-open (a concurrent compaction swapped the manifest
/// and deleted its inputs between our manifest read and segment read).
const OPEN_RETRIES: usize = 5;

/// One manifest entry: an immutable folded segment on disk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentRef {
    /// Path relative to the manifest's directory, forward slashes
    /// (`skills.segments/seg-000001.json`).
    pub file: String,
    /// The segment's fold-epoch clock (max epoch stamped inside).
    pub generation: u64,
    /// Observations folded into the segment.
    pub observations: u64,
    /// Distinct case ids the segment carries (layout display only).
    pub cases: u64,
}

/// Report returned by [`SegmentedSkillStore::compact`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// Segments folded away (0 = nothing to do).
    pub folded_segments: usize,
    /// The fresh segment file the fold landed in.
    pub into: Option<String>,
    /// Observations the folded segment carries.
    pub observations: u64,
}

impl CompactReport {
    /// Human-readable one-line summary.
    pub fn render(&self) -> String {
        match &self.into {
            Some(f) => format!(
                "compacted {} segment(s) into {f} ({} observation(s))",
                self.folded_segments, self.observations
            ),
            None => "compact: nothing to do (fewer than 2 segments)".to_string(),
        }
    }
}

/// A live memory-dir store in the segmented v4 layout: immutable folded
/// segments + active head on disk, with the full logical fold kept warm in
/// memory for retrieval and learned-case synthesis.
///
/// Writer invariant: `head.generation == logical.generation` (the head's
/// clock is maxed over every segment at open and both advance together),
/// so observations folded through [`SegmentedSkillStore::merge`] land with
/// identical epoch stamps in both views.
#[derive(Debug, Clone)]
pub struct SegmentedSkillStore {
    /// Directory the manifest lives in (segment paths resolve against it).
    dir: PathBuf,
    /// Manifest path (`<dir>/skills.json`).
    path: PathBuf,
    /// Manifest segment list, canonical (oldest-first) order.
    segments: Vec<SegmentRef>,
    /// Active head: the current epoch's (and any un-rotated history's)
    /// stats. What the manifest's `partitions` serialize.
    head: SkillStore,
    /// The logical store: head + every segment folded. Pure function of
    /// the on-disk state; retrieval and `learned` derivation read this.
    logical: SkillStore,
    /// Files superseded by gc/compaction, deleted (best-effort) only
    /// *after* the next manifest lands so older manifests stay readable.
    pending_delete: Vec<PathBuf>,
    /// Automatic-compaction policy, recorded in the manifest
    /// (`auto_compact_segments`): when non-zero, a rotation in
    /// [`SegmentedSkillStore::advance_to`] that leaves at least this many
    /// segments triggers [`SegmentedSkillStore::compact`] inline — the
    /// *same* code path as the offline `skills compact` CLI, so a
    /// long-lived daemon's store stays bounded without a second fold
    /// implementation. 0 = off (the default, and the flat fixed point:
    /// the key is omitted from the manifest when 0).
    auto_compact_segments: u64,
}

impl SegmentedSkillStore {
    /// Open the store rooted at `dir` (`<dir>/skills.json`). A missing
    /// manifest is a cold store; flat v1–v4 blobs load with the whole store
    /// as head and no segments (and re-save as the v4 fixed point).
    pub fn open(dir: &Path) -> Result<SegmentedSkillStore, String> {
        SegmentedSkillStore::open_path(&dir.join("skills.json"))
    }

    /// [`SegmentedSkillStore::open`] addressed by manifest path. Retries
    /// the manifest read when a referenced segment file disappears
    /// mid-open: segments are immutable and names are never reused, so a
    /// vanished file means a concurrent compaction swapped the manifest —
    /// re-reading converges.
    pub fn open_path(path: &Path) -> Result<SegmentedSkillStore, String> {
        let dir = path
            .parent()
            .map(Path::to_path_buf)
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or_else(|| PathBuf::from("."));
        let mut last_race = String::new();
        for _ in 0..OPEN_RETRIES {
            match SegmentedSkillStore::open_once(&dir, path) {
                Ok(store) => return Ok(store),
                Err(OpenError::SegmentVanished(why)) => last_race = why,
                Err(OpenError::Fatal(e)) => return Err(e),
            }
        }
        Err(format!(
            "{}: segment files kept vanishing across {OPEN_RETRIES} manifest reads \
             (last: {last_race})",
            path.display()
        ))
    }

    fn open_once(dir: &Path, path: &Path) -> Result<SegmentedSkillStore, OpenError> {
        if !path.exists() {
            return Ok(SegmentedSkillStore {
                dir: dir.to_path_buf(),
                path: path.to_path_buf(),
                segments: Vec::new(),
                head: SkillStore::new(),
                logical: SkillStore::new(),
                pending_delete: Vec::new(),
                auto_compact_segments: 0,
            });
        }
        let bytes = std::fs::read(path)
            .map_err(|e| OpenError::Fatal(format!("reading {}: {e}", path.display())))?;
        let text = std::str::from_utf8(&bytes).map_err(|e| {
            OpenError::Fatal(format!("{}: skill store is not UTF-8: {e}", path.display()))
        })?;
        let j = Json::parse(text)
            .map_err(|e| OpenError::Fatal(format!("{}: parsing skill store: {e}", path.display())))?;
        let segments = parse_segment_refs(&j)
            .map_err(|e| OpenError::Fatal(format!("{}: {e}", path.display())))?;
        let auto_compact_segments = j
            .get("auto_compact_segments")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        // The head is the manifest body with the segment list blanked —
        // flat v1–v3 blobs (no `segments` key) take this path unchanged.
        let head_json = match &j {
            Json::Obj(map) => {
                let mut m = map.clone();
                m.insert("segments".to_string(), json::arr(vec![]));
                Json::Obj(m)
            }
            other => other.clone(),
        };
        let mut head = SkillStore::from_json(&head_json)
            .map_err(|e| OpenError::Fatal(format!("{}: {e}", path.display())))?;
        let mut logical = head.clone();
        for r in &segments {
            let seg_path = dir.join(&r.file);
            let seg_bytes = match std::fs::read(&seg_path) {
                Ok(b) => b,
                Err(e) if e.kind() == io::ErrorKind::NotFound => {
                    return Err(OpenError::SegmentVanished(format!(
                        "{} referenced by the manifest is gone",
                        seg_path.display()
                    )));
                }
                Err(e) => {
                    return Err(OpenError::Fatal(format!(
                        "reading segment {}: {e}",
                        seg_path.display()
                    )));
                }
            };
            let seg = SkillStore::from_bytes(&seg_bytes)
                .map_err(|e| OpenError::Fatal(format!("segment {}: {e}", seg_path.display())))?;
            logical.merge_store(&seg);
        }
        // Writer invariant: the head clock rides at the logical clock so
        // new observations stamp consistently in both views (also repairs
        // manifests written by a foreign/older writer).
        head.generation = logical.generation;
        Ok(SegmentedSkillStore {
            dir: dir.to_path_buf(),
            path: path.to_path_buf(),
            segments,
            head,
            logical,
            pending_delete: Vec::new(),
            auto_compact_segments,
        })
    }

    /// The logical fold-epoch clock.
    pub fn generation(&self) -> u64 {
        self.logical.generation
    }

    /// The full logical store (head + segments folded).
    pub fn logical(&self) -> &SkillStore {
        &self.logical
    }

    /// The active head (what the next rotation would freeze).
    pub fn head(&self) -> &SkillStore {
        &self.head
    }

    /// Manifest segment list, canonical order.
    pub fn segments(&self) -> &[SegmentRef] {
        &self.segments
    }

    /// Consume into the logical [`SkillStore`] — what read-only callers
    /// ([`SkillStore::load`]) hand back.
    pub fn into_logical(self) -> SkillStore {
        self.logical
    }

    /// Fold a task's worth of observations into head and logical alike
    /// (identical epoch stamps — the clocks are kept equal).
    pub fn merge(&mut self, obs: &[SkillObs]) {
        self.head.merge(obs);
        self.logical.merge(obs);
    }

    /// Advance the fold-epoch clock to `gen`, rotating the head into a
    /// fresh immutable segment first when it carries anything. Returns
    /// `Ok(true)` when a rotation happened — callers should
    /// [`SegmentedSkillStore::save`] promptly so the manifest references
    /// the new segment. `gen` at or below the current clock is a no-op
    /// (the resume path: the on-disk store already carries the bump).
    pub fn advance_to(&mut self, gen: u64) -> io::Result<bool> {
        if gen <= self.logical.generation {
            return Ok(false);
        }
        let mut rotated = false;
        if !self.head.is_empty() || self.head.observations > 0 {
            let file = self.next_segment_file()?;
            std::fs::create_dir_all(self.dir.join(SEGMENT_DIR))?;
            self.head.save(&self.dir.join(&file))?;
            self.segments.push(SegmentRef {
                file,
                generation: self.head.generation,
                observations: self.head.observations,
                cases: self.head.case_count() as u64,
            });
            let frozen_gen = self.head.generation;
            self.head = SkillStore::new();
            self.head.generation = frozen_gen;
            rotated = true;
        }
        self.head.generation = gen;
        self.logical.generation = gen;
        if rotated
            && self.auto_compact_segments != 0
            && self.segments.len() >= self.auto_compact_segments as usize
        {
            // The policy trigger rides the exact offline `skills compact`
            // code path (invariant 17 pins its fold), so a daemon's store
            // and an operator's cron job produce byte-identical layouts.
            self.compact().map_err(io::Error::other)?;
        }
        Ok(rotated)
    }

    /// The automatic-compaction threshold (0 = off).
    pub fn auto_compact_segments(&self) -> u64 {
        self.auto_compact_segments
    }

    /// Set the automatic-compaction policy (persisted by the next
    /// [`SegmentedSkillStore::save`]). `n` must be 0 (off) or >= 2 — a
    /// threshold of 1 would trigger folds that [`SegmentedSkillStore::compact`]
    /// no-ops on every epoch.
    pub fn set_auto_compact_segments(&mut self, n: u64) -> Result<(), String> {
        if n == 1 {
            return Err("--auto must be 0 (off) or >= 2 segments".to_string());
        }
        self.auto_compact_segments = n;
        Ok(())
    }

    /// Write the manifest atomically (staging file + rename), then drop any
    /// files superseded by gc/compaction — deletion strictly *after* the
    /// new manifest lands, so a reader holding the old manifest either
    /// resolves the old files or retries into the new manifest.
    pub fn save(&mut self) -> io::Result<()> {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let bytes = format!("{}\n", self.manifest_json());
        let tmp = self.path.with_extension("json.tmp");
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, &self.path)?;
        for stale in self.pending_delete.drain(..) {
            let _ = std::fs::remove_file(stale);
        }
        Ok(())
    }

    /// The manifest form: the head's flat serialization, with `learned`
    /// re-derived from the logical fold and the segment list spliced in.
    /// With no segments this is exactly the logical store's
    /// [`SkillStore::canonical_bytes`] — the v4 fixed point flat and
    /// migrated v1–v3 stores re-save as.
    fn manifest_json(&self) -> Json {
        let mut j = self.head.to_json();
        if let Json::Obj(map) = &mut j {
            if self.auto_compact_segments != 0 {
                map.insert(
                    "auto_compact_segments".to_string(),
                    json::num(self.auto_compact_segments as f64),
                );
            }
            map.insert("learned".to_string(), Json::Arr(self.logical.learned_json()));
            map.insert(
                "segments".to_string(),
                Json::Arr(
                    self.segments
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("cases", json::num(r.cases as f64)),
                                ("file", json::s(&r.file)),
                                ("generation", json::num(r.generation as f64)),
                                ("observations", json::num(r.observations as f64)),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        j
    }

    /// Offline compaction: fold every segment into one fresh immutable
    /// file and atomically swap the manifest to reference it. A no-op
    /// below 2 segments. Safe while readers hold older manifests: inputs
    /// are deleted only after the new manifest lands, and segment names
    /// are never reused.
    pub fn compact(&mut self) -> Result<CompactReport, String> {
        if self.segments.len() < 2 {
            return Ok(CompactReport::default());
        }
        let mut folded = SkillStore::new();
        for r in &self.segments {
            let seg_path = self.dir.join(&r.file);
            let bytes = std::fs::read(&seg_path)
                .map_err(|e| format!("reading segment {}: {e}", seg_path.display()))?;
            let seg = SkillStore::from_bytes(&bytes)
                .map_err(|e| format!("segment {}: {e}", seg_path.display()))?;
            folded.merge_store(&seg);
        }
        let file = self
            .next_segment_file()
            .map_err(|e| format!("scanning {SEGMENT_DIR}: {e}"))?;
        std::fs::create_dir_all(self.dir.join(SEGMENT_DIR))
            .map_err(|e| format!("creating {SEGMENT_DIR}: {e}"))?;
        folded
            .save(&self.dir.join(&file))
            .map_err(|e| format!("writing folded segment {file}: {e}"))?;
        let report = CompactReport {
            folded_segments: self.segments.len(),
            into: Some(file.clone()),
            observations: folded.observations,
        };
        for old in std::mem::take(&mut self.segments) {
            self.pending_delete.push(self.dir.join(&old.file));
        }
        self.segments.push(SegmentRef {
            file,
            generation: folded.generation,
            observations: folded.observations,
            cases: folded.case_count() as u64,
        });
        self.save()
            .map_err(|e| format!("writing manifest {}: {e}", self.path.display()))?;
        Ok(report)
    }

    /// Age stats out of the *logical* store (optionally scoped to one
    /// device partition), then collapse the layout: the surviving logical
    /// store becomes the new head and every segment is queued for deletion
    /// at the next [`SegmentedSkillStore::save`]. Historical
    /// `observations`/`generation` counters are untouched, exactly like
    /// [`SkillStore::gc`]. In-memory only — skipping `save` is a dry run.
    pub fn gc_device(&mut self, max_age: u64, device: Option<&str>) -> GcReport {
        let report = self.logical.gc_device(max_age, device);
        self.head = self.logical.clone();
        for r in std::mem::take(&mut self.segments) {
            self.pending_delete.push(self.dir.join(&r.file));
        }
        report
    }

    /// Render the physical layout (the `skills inspect --segments` view):
    /// one line per segment plus the head summary. The logical content is
    /// rendered separately via [`SkillStore::render_inspect`] on
    /// [`SegmentedSkillStore::logical`].
    pub fn render_layout(&self) -> String {
        let mut out = format!(
            "segment layout: {} segment(s) + head\n",
            self.segments.len()
        );
        for r in &self.segments {
            out.push_str(&format!(
                "  segment {:<40} generation {:>3}  observations {:>6}  cases {:>4}\n",
                r.file, r.generation, r.observations, r.cases
            ));
        }
        out.push_str(&format!(
            "  head    {:<40} generation {:>3}  observations {:>6}  cases {:>4}\n",
            "(manifest partitions)",
            self.head.generation,
            self.head.observations,
            self.head.case_count()
        ));
        if self.auto_compact_segments != 0 {
            out.push_str(&format!(
                "  policy  auto-compact at {} segment(s)\n",
                self.auto_compact_segments
            ));
        }
        out
    }

    /// First unused segment file name: one past the max counter seen in
    /// the manifest *and* on disk, zero-padded. Names are never reused, so
    /// files orphaned by a crash between rotation and manifest save can
    /// never be silently adopted by a later writer.
    fn next_segment_file(&self) -> io::Result<String> {
        let mut max = 0u64;
        for r in &self.segments {
            if let Some(n) = segment_counter(&r.file) {
                max = max.max(n);
            }
        }
        let seg_dir = self.dir.join(SEGMENT_DIR);
        if seg_dir.is_dir() {
            for entry in std::fs::read_dir(&seg_dir)? {
                let name = entry?.file_name();
                if let Some(n) = segment_counter(&name.to_string_lossy()) {
                    max = max.max(n);
                }
            }
        }
        Ok(format!("{SEGMENT_DIR}/seg-{:06}.json", max + 1))
    }
}

enum OpenError {
    /// A referenced segment file vanished mid-open (compaction race) —
    /// re-read the manifest.
    SegmentVanished(String),
    Fatal(String),
}

/// Counter embedded in a segment file name (`…seg-000042.json` -> 42).
fn segment_counter(file: &str) -> Option<u64> {
    let name = file.rsplit('/').next()?;
    name.strip_prefix("seg-")?.strip_suffix(".json")?.parse().ok()
}

/// Parse the manifest's `segments` list (absent or empty = flat store).
/// Relative traversal-free paths only: the manifest must not be able to
/// point readers outside its own directory.
fn parse_segment_refs(j: &Json) -> Result<Vec<SegmentRef>, String> {
    let Some(segs) = j.get("segments").and_then(|s| s.as_arr()) else {
        return Ok(Vec::new());
    };
    let mut out = Vec::with_capacity(segs.len());
    for s in segs {
        let file = s
            .get("file")
            .and_then(|f| f.as_str())
            .ok_or_else(|| "segment entry missing `file`".to_string())?
            .to_string();
        if file.starts_with('/') || file.split('/').any(|c| c == ".." || c.is_empty()) {
            return Err(format!("segment file {file:?}: not a clean relative path"));
        }
        let num = |k: &str| s.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        out.push(SegmentRef {
            file,
            generation: num("generation"),
            observations: num("observations"),
            cases: num("cases"),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::transforms::MethodId;

    fn obs_on(device: &str, case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: m,
            gain,
            device: device.to_string(),
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ks-seg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Three epochs of observations through the segmented writer must fold
    /// to byte-identical canonical bytes as one flat store fed the same
    /// multiset — the segment-fold-equivalence invariant.
    #[test]
    fn segmented_folds_byte_identical_to_flat() {
        let dir = tmp_dir("fold-eq");
        let mut flat = SkillStore::new();
        let epochs: Vec<Vec<SkillObs>> = (1..=3)
            .map(|e| {
                vec![
                    obs_on("a100-like", "gemm.naive_loop", MethodId::TileSmem, Some(e as f64)),
                    obs_on("tpu-like", "gemm.naive_loop", MethodId::SplitK, None),
                ]
            })
            .collect();
        for (i, batch) in epochs.iter().enumerate() {
            let mut seg = SegmentedSkillStore::open(&dir).unwrap();
            let rotated = seg.advance_to(seg.generation() + 1).unwrap();
            assert_eq!(rotated, i > 0, "every epoch after the first rotates");
            seg.merge(batch);
            seg.save().unwrap();

            flat.generation += 1;
            flat.merge(batch);
        }
        let reopened = SegmentedSkillStore::open(&dir).unwrap();
        assert_eq!(reopened.segments().len(), 2);
        assert_eq!(
            reopened.logical().canonical_bytes(),
            flat.canonical_bytes(),
            "segmented store folds to the flat store's bytes"
        );
        // The transparent reader path agrees.
        let loaded = SkillStore::load(&dir.join("skills.json")).unwrap();
        assert_eq!(loaded.canonical_bytes(), flat.canonical_bytes());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Compaction folds N segments into one and preserves the logical
    /// bytes; old files are gone, the new manifest references one segment.
    #[test]
    fn compaction_preserves_logical_bytes_and_swaps_atomically() {
        let dir = tmp_dir("compact");
        for e in 1..=3u64 {
            let mut seg = SegmentedSkillStore::open(&dir).unwrap();
            seg.advance_to(seg.generation() + 1).unwrap();
            seg.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(e as f64))]);
            seg.save().unwrap();
        }
        let before = SkillStore::load(&dir.join("skills.json")).unwrap();
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        let old_files: Vec<PathBuf> =
            seg.segments().iter().map(|r| dir.join(&r.file)).collect();
        let report = seg.compact().unwrap();
        assert_eq!(report.folded_segments, 2);
        assert!(report.render().starts_with("compacted 2 segment(s)"));
        for f in old_files {
            assert!(!f.exists(), "compaction input {f:?} deleted after swap");
        }
        let reopened = SegmentedSkillStore::open(&dir).unwrap();
        assert_eq!(reopened.segments().len(), 1);
        let after = SkillStore::load(&dir.join("skills.json")).unwrap();
        assert_eq!(after.canonical_bytes(), before.canonical_bytes());
        // Compacting again is a no-op.
        let mut again = SegmentedSkillStore::open(&dir).unwrap();
        assert_eq!(again.compact().unwrap(), CompactReport::default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A flat v4 (or migrated v1–v3) blob opens with no segments and
    /// re-saves byte-stable — the flat fixed point.
    #[test]
    fn flat_store_is_a_fixed_point() {
        let dir = tmp_dir("fixed-point");
        let mut flat = SkillStore::new();
        flat.advance_generation();
        flat.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(1.5))]);
        let path = dir.join("skills.json");
        flat.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        assert!(seg.segments().is_empty());
        seg.save().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes, "re-save is byte-stable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// gc collapses the layout: segments queued for deletion, survivors in
    /// the head, historical counters intact, manifest back to flat form.
    #[test]
    fn gc_collapses_segments_and_keeps_counters() {
        let dir = tmp_dir("gc");
        for e in 1..=3u64 {
            let mut seg = SegmentedSkillStore::open(&dir).unwrap();
            seg.advance_to(seg.generation() + 1).unwrap();
            let m = if e == 1 { MethodId::TileSmem } else { MethodId::SplitK };
            seg.merge(&[obs_on("a100-like", "c", m, Some(1.0))]);
            seg.save().unwrap();
        }
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        let total_obs = seg.logical().observations;
        seg.advance_to(20).unwrap();
        let report = seg.gc_device(8, None);
        assert_eq!(report.dropped_stats, 2, "epoch-1/2 stats age out at gen 20");
        seg.save().unwrap();
        let reopened = SegmentedSkillStore::open(&dir).unwrap();
        assert!(reopened.segments().is_empty(), "gc collapsed the layout");
        assert_eq!(reopened.logical().observations, total_obs, "historical counter kept");
        let leftover = std::fs::read_dir(dir.join(SEGMENT_DIR))
            .map(|d| d.filter_map(|e| e.ok()).count())
            .unwrap_or(0);
        assert_eq!(leftover, 0, "collapsed segment files deleted after save");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Segment names are never reused: a crash-orphaned file on disk bumps
    /// the counter past it.
    #[test]
    fn segment_names_skip_orphans() {
        let dir = tmp_dir("orphans");
        std::fs::create_dir_all(dir.join(SEGMENT_DIR)).unwrap();
        let orphan = dir.join(SEGMENT_DIR).join("seg-000007.json");
        SkillStore::new().save(&orphan).unwrap();
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        seg.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0))]);
        seg.save().unwrap();
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        seg.advance_to(seg.generation() + 1).unwrap();
        assert_eq!(
            seg.segments().last().unwrap().file,
            format!("{SEGMENT_DIR}/seg-000008.json"),
            "counter scans past the orphan"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The auto-compaction policy persists in the manifest, triggers at
    /// the fold boundary, and lands byte-identical to running the offline
    /// `compact()` path at the same boundary — then clearing the policy
    /// yields a manifest byte-identical to the offline store's.
    #[test]
    fn auto_compaction_matches_the_offline_path() {
        let auto_dir = tmp_dir("auto-compact");
        let offline_dir = tmp_dir("offline-compact");
        {
            let mut seg = SegmentedSkillStore::open(&auto_dir).unwrap();
            seg.set_auto_compact_segments(2).unwrap();
            seg.save().unwrap();
        }
        assert!(SegmentedSkillStore::open(&auto_dir)
            .unwrap()
            .render_layout()
            .contains("auto-compact at 2 segment(s)"));
        for e in 1..=4u64 {
            for dir in [&auto_dir, &offline_dir] {
                let mut seg = SegmentedSkillStore::open(dir).unwrap();
                let next = seg.generation() + 1;
                let rotated = seg.advance_to(next).unwrap();
                // Mirror the trigger by hand on the offline store: compact
                // whenever a rotation leaves >= 2 segments.
                if *dir == offline_dir && rotated && seg.segments().len() >= 2 {
                    seg.compact().unwrap();
                }
                seg.merge(&[obs_on("a100-like", "c", MethodId::TileSmem, Some(e as f64))]);
                seg.save().unwrap();
            }
        }
        let auto = SegmentedSkillStore::open(&auto_dir).unwrap();
        let offline = SegmentedSkillStore::open(&offline_dir).unwrap();
        assert_eq!(auto.auto_compact_segments(), 2, "policy survives reopen");
        assert_eq!(
            auto.segments().len(),
            offline.segments().len(),
            "auto and offline compaction leave the same layout"
        );
        assert_eq!(auto.logical().canonical_bytes(), offline.logical().canonical_bytes());
        for (a, b) in auto.segments().iter().zip(offline.segments()) {
            assert_eq!(a.file, b.file, "same segment names");
            assert_eq!(
                std::fs::read(auto_dir.join(&a.file)).unwrap(),
                std::fs::read(offline_dir.join(&b.file)).unwrap(),
                "segment {} byte-identical across paths",
                a.file
            );
        }
        // Clearing the policy removes the manifest key entirely: the two
        // manifests become byte-identical.
        let mut auto = auto;
        auto.set_auto_compact_segments(0).unwrap();
        auto.save().unwrap();
        assert_eq!(
            std::fs::read(auto_dir.join("skills.json")).unwrap(),
            std::fs::read(offline_dir.join("skills.json")).unwrap(),
        );
        let _ = std::fs::remove_dir_all(&auto_dir);
        let _ = std::fs::remove_dir_all(&offline_dir);
    }

    /// A threshold of 1 is refused (compact() no-ops below 2 segments, so
    /// it would be a busy-loop policy).
    #[test]
    fn auto_compact_threshold_of_one_is_refused() {
        let dir = tmp_dir("auto-one");
        let mut seg = SegmentedSkillStore::open(&dir).unwrap();
        assert!(seg.set_auto_compact_segments(1).is_err());
        assert!(seg.set_auto_compact_segments(0).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Manifests must not reference files outside their directory.
    #[test]
    fn traversal_paths_are_rejected() {
        for bad in ["/etc/passwd", "../x.json", "a//b.json"] {
            let text = format!(
                r#"{{"generation":1,"learned":[],"observations":0,"partitions":{{}},"segments":[{{"cases":0,"file":"{bad}","generation":1,"observations":0}}],"version":4}}"#
            );
            let j = Json::parse(&text).unwrap();
            assert!(parse_segment_refs(&j).is_err(), "{bad} must be rejected");
        }
    }
}
