//! Persistent long-term skill memory: the *learned* layer on top of the
//! curated knowledge base.
//!
//! The curated store (`kb_content`) is static expert knowledge; what the
//! paper's dual-level memory additionally needs is cross-task transfer —
//! outcomes observed while optimizing one task should inform method choice
//! on later tasks, seeds, and strategies. This module records, per
//! decision-table case, how every method actually performed
//! ([`MethodStat`]), serializes the store to disk after each task (the
//! suite orchestrator owns the write cycle), and warm-starts retrieval from
//! it: [`SkillStore::rerank`] reorders a case's `allowed_methods` by
//! observed mean gain, leaving unobserved methods in curated priority
//! order.
//!
//! Persistence uses the repo's own JSON layer (serde is not vendored
//! offline) and writes are atomic (tmp + rename) so a killed run never
//! leaves a torn store behind.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::kir::transforms::MethodId;
use crate::util::json::{self, Json};

/// One learned observation: applying `method` while the decision table had
/// matched `case_id` produced `gain` (speedup delta vs the base kernel), or
/// failed review (`None`).
#[derive(Debug, Clone, PartialEq)]
pub struct SkillObs {
    pub case_id: String,
    pub method: MethodId,
    pub gain: Option<f64>,
}

/// Aggregate outcome statistics for one (case, method) pair.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStat {
    pub attempts: u64,
    /// Attempts whose candidate compiled, verified, and was measured.
    pub wins: u64,
    /// Sum of speedup deltas over winning attempts.
    pub total_gain: f64,
}

impl MethodStat {
    pub fn mean_gain(&self) -> f64 {
        if self.wins == 0 {
            0.0
        } else {
            self.total_gain / self.wins as f64
        }
    }

    pub fn win_rate(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.wins as f64 / self.attempts as f64
        }
    }

    /// Ranking score: mean gain per attempt. Unobserved methods score 0, so
    /// known-good methods rise above them and known-bad ones sink below.
    fn score(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else if self.wins == 0 {
            -1.0
        } else {
            self.total_gain / self.attempts as f64
        }
    }
}

/// The persistent skill store: case id -> method -> stats.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SkillStore {
    pub cases: BTreeMap<String, BTreeMap<MethodId, MethodStat>>,
    /// Total observations folded in (for the audit trail).
    pub observations: u64,
}

impl SkillStore {
    pub fn new() -> SkillStore {
        SkillStore::default()
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    pub fn stat(&self, case_id: &str, method: MethodId) -> Option<&MethodStat> {
        self.cases.get(case_id).and_then(|m| m.get(&method))
    }

    /// Fold one observation in.
    pub fn observe(&mut self, obs: &SkillObs) {
        let stat = self
            .cases
            .entry(obs.case_id.clone())
            .or_default()
            .entry(obs.method)
            .or_default();
        stat.attempts += 1;
        if let Some(g) = obs.gain {
            stat.wins += 1;
            stat.total_gain += g;
        }
        self.observations += 1;
    }

    /// Fold a task's worth of observations in. Merging is additive, so the
    /// final store is independent of the order tasks complete in.
    pub fn merge(&mut self, obs: &[SkillObs]) {
        for o in obs {
            self.observe(o);
        }
    }

    /// Reorder a case's allowed methods by observed performance: stable
    /// sort, descending score. Methods never tried keep their curated
    /// position among themselves (score 0); methods that only ever failed
    /// sink below untried ones.
    pub fn rerank(&self, case_id: &str, methods: &mut [MethodId]) {
        let Some(stats) = self.cases.get(case_id) else {
            return;
        };
        methods.sort_by(|a, b| {
            let sa = stats.get(a).map(|s| s.score()).unwrap_or(0.0);
            let sb = stats.get(b).map(|s| s.score()).unwrap_or(0.0);
            sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    // ---- persistence ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let cases = self
            .cases
            .iter()
            .map(|(case, methods)| {
                let m = methods
                    .iter()
                    .map(|(method, s)| {
                        (
                            method.name().to_string(),
                            json::obj(vec![
                                ("attempts", json::num(s.attempts as f64)),
                                ("wins", json::num(s.wins as f64)),
                                ("total_gain", json::num(s.total_gain)),
                            ]),
                        )
                    })
                    .collect();
                (case.clone(), Json::Obj(m))
            })
            .collect();
        json::obj(vec![
            ("version", json::num(1.0)),
            ("observations", json::num(self.observations as f64)),
            ("cases", Json::Obj(cases)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SkillStore, String> {
        let mut store = SkillStore::new();
        store.observations = j
            .get("observations")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        let cases = j
            .get("cases")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| "skill store missing cases".to_string())?;
        for (case, methods) in cases {
            let methods = methods
                .as_obj()
                .ok_or_else(|| format!("case {case}: not an object"))?;
            let mut out = BTreeMap::new();
            for (mname, stat) in methods {
                let Some(method) = MethodId::from_name(mname) else {
                    // Unknown method (newer writer): skip, keep the rest.
                    continue;
                };
                let get = |k: &str| stat.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                out.insert(
                    method,
                    MethodStat {
                        attempts: get("attempts") as u64,
                        wins: get("wins") as u64,
                        total_gain: get("total_gain"),
                    },
                );
            }
            store.cases.insert(case.clone(), out);
        }
        Ok(store)
    }

    /// Atomic save: write a tmp file, then rename over the target.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", self.to_json()))?;
        std::fs::rename(&tmp, path)
    }

    /// Load a store; a missing file is an empty (cold) store, a corrupt
    /// file is an error.
    pub fn load(path: &Path) -> Result<SkillStore, String> {
        if !path.exists() {
            return Ok(SkillStore::new());
        }
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("parsing {path:?}: {e}"))?;
        SkillStore::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: m,
            gain,
        }
    }

    #[test]
    fn observe_accumulates() {
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(1.0)));
        s.observe(&obs("c", MethodId::TileSmem, Some(3.0)));
        s.observe(&obs("c", MethodId::TileSmem, None));
        let st = s.stat("c", MethodId::TileSmem).unwrap();
        assert_eq!(st.attempts, 3);
        assert_eq!(st.wins, 2);
        assert_eq!(st.mean_gain(), 2.0);
        assert_eq!(s.observations, 3);
    }

    #[test]
    fn merge_is_order_independent() {
        let a = vec![obs("c", MethodId::TileSmem, Some(1.0)), obs("d", MethodId::SplitK, None)];
        let b = vec![obs("c", MethodId::TileSmem, Some(0.5))];
        let mut s1 = SkillStore::new();
        s1.merge(&a);
        s1.merge(&b);
        let mut s2 = SkillStore::new();
        s2.merge(&b);
        s2.merge(&a);
        assert_eq!(s1, s2);
    }

    #[test]
    fn rerank_promotes_observed_winners_and_sinks_losers() {
        let mut s = SkillStore::new();
        // VectorizeLoads observed great, DoubleBuffer observed failing.
        s.observe(&obs("c", MethodId::VectorizeLoads, Some(2.0)));
        s.observe(&obs("c", MethodId::DoubleBuffer, None));
        let mut methods = vec![
            MethodId::DoubleBuffer,
            MethodId::TileSmem,
            MethodId::VectorizeLoads,
        ];
        s.rerank("c", &mut methods);
        assert_eq!(
            methods,
            vec![MethodId::VectorizeLoads, MethodId::TileSmem, MethodId::DoubleBuffer]
        );
    }

    #[test]
    fn rerank_unknown_case_is_noop() {
        let s = SkillStore::new();
        let mut methods = vec![MethodId::TileSmem, MethodId::SplitK];
        s.rerank("nope", &mut methods);
        assert_eq!(methods, vec![MethodId::TileSmem, MethodId::SplitK]);
    }

    #[test]
    fn json_roundtrip_exact() {
        let mut s = SkillStore::new();
        s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(1.2345678901234)));
        s.observe(&obs("gemm.naive_loop", MethodId::UseTensorCore, None));
        s.observe(&obs("fusion.elementwise_chain", MethodId::FuseElementwise, Some(0.25)));
        let j = s.to_json();
        let back = SkillStore::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ks-skills-{}", std::process::id()));
        let path = dir.join("skills.json");
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(0.5)));
        s.save(&path).unwrap();
        let back = SkillStore::load(&path).unwrap();
        assert_eq!(s, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_is_cold() {
        let s = SkillStore::load(Path::new("/nonexistent/skills.json")).unwrap();
        assert!(s.is_empty());
    }
}
