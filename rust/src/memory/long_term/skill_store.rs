//! Persistent long-term skill memory: the *learned* layer on top of the
//! curated knowledge base.
//!
//! The curated store (`kb_content`) is static expert knowledge; what the
//! paper's dual-level memory additionally needs is cross-task transfer —
//! outcomes observed while optimizing one task should inform method choice
//! on later tasks, seeds, and strategies. This module records, per
//! decision-table case, how every method actually performed
//! ([`MethodStat`]), serializes the store to disk after each task (the
//! suite orchestrator owns the write cycle), and warm-starts retrieval from
//! it: [`SkillStore::rerank`] reorders a case's `allowed_methods` by
//! observed mean gain, leaving unobserved methods in curated priority
//! order.
//!
//! Persistence uses the repo's own JSON layer (serde is not vendored
//! offline) and writes are atomic (tmp + rename) so a killed run never
//! leaves a torn store behind.
//!
//! Merging is exact: per-(case, method) gain totals accumulate through
//! [`ExactSum`], so folding observations — or whole stores, via
//! [`SkillStore::merge_store`] — is commutative and associative *at the bit
//! level*, with the empty store as identity. That is the property the
//! sharded suite relies on: N shards merged in any order serialize to the
//! same bytes a single process would have written.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::kir::transforms::MethodId;
use crate::util::fsum::ExactSum;
use crate::util::json::{self, Json};

/// One learned observation: applying `method` while the decision table had
/// matched `case_id` produced `gain` (speedup delta vs the base kernel), or
/// failed review (`None`).
#[derive(Debug, Clone, PartialEq)]
pub struct SkillObs {
    pub case_id: String,
    pub method: MethodId,
    pub gain: Option<f64>,
}

/// Aggregate outcome statistics for one (case, method) pair.
///
/// The gain total is an exact accumulator, not a plain f64, so stats from
/// different shards/orders combine to bit-identical results.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStat {
    pub attempts: u64,
    /// Attempts whose candidate compiled, verified, and was measured.
    pub wins: u64,
    /// Exact sum of speedup deltas over winning attempts.
    gain: ExactSum,
}

impl MethodStat {
    /// Sum of speedup deltas over winning attempts (correctly rounded).
    pub fn total_gain(&self) -> f64 {
        self.gain.value()
    }

    pub fn mean_gain(&self) -> f64 {
        if self.wins == 0 {
            0.0
        } else {
            self.total_gain() / self.wins as f64
        }
    }

    pub fn win_rate(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.wins as f64 / self.attempts as f64
        }
    }

    /// Ranking score: mean gain per attempt. Unobserved methods score 0, so
    /// known-good methods rise above them and known-bad ones sink below.
    fn score(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else if self.wins == 0 {
            -1.0
        } else {
            self.total_gain() / self.attempts as f64
        }
    }

    /// Add another stat's counts and exact gain total into this one.
    fn absorb(&mut self, other: &MethodStat) {
        self.attempts += other.attempts;
        self.wins += other.wins;
        self.gain.add_sum(&other.gain);
    }
}

/// The persistent skill store: case id -> method -> stats.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SkillStore {
    pub cases: BTreeMap<String, BTreeMap<MethodId, MethodStat>>,
    /// Total observations folded in (for the audit trail).
    pub observations: u64,
}

impl SkillStore {
    pub fn new() -> SkillStore {
        SkillStore::default()
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    pub fn stat(&self, case_id: &str, method: MethodId) -> Option<&MethodStat> {
        self.cases.get(case_id).and_then(|m| m.get(&method))
    }

    /// Fold one observation in.
    pub fn observe(&mut self, obs: &SkillObs) {
        let stat = self
            .cases
            .entry(obs.case_id.clone())
            .or_default()
            .entry(obs.method)
            .or_default();
        stat.attempts += 1;
        if let Some(g) = obs.gain {
            stat.wins += 1;
            stat.gain.add(g);
        }
        self.observations += 1;
    }

    /// Fold a task's worth of observations in. Merging is additive and gain
    /// totals accumulate exactly, so the final store is bit-identical
    /// regardless of the order tasks complete in.
    pub fn merge(&mut self, obs: &[SkillObs]) {
        for o in obs {
            self.observe(o);
        }
    }

    /// Fold an entire store into this one: per-(case, method) stats add,
    /// counts and exact gain totals alike. This fold is commutative and
    /// associative at the bit level, with the empty store as identity —
    /// the contract the sharded suite's `merge` subcommand depends on.
    pub fn merge_store(&mut self, other: &SkillStore) {
        for (case, methods) in &other.cases {
            let dst = self.cases.entry(case.clone()).or_default();
            for (method, stat) in methods {
                dst.entry(*method).or_default().absorb(stat);
            }
        }
        self.observations += other.observations;
    }

    /// Reorder a case's allowed methods by observed performance: stable
    /// sort, descending score. Methods never tried keep their curated
    /// position among themselves (score 0); methods that only ever failed
    /// sink below untried ones.
    pub fn rerank(&self, case_id: &str, methods: &mut [MethodId]) {
        let Some(stats) = self.cases.get(case_id) else {
            return;
        };
        methods.sort_by(|a, b| {
            let sa = stats.get(a).map(|s| s.score()).unwrap_or(0.0);
            let sb = stats.get(b).map(|s| s.score()).unwrap_or(0.0);
            sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    // ---- persistence ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let cases = self
            .cases
            .iter()
            .map(|(case, methods)| {
                let m = methods
                    .iter()
                    .map(|(method, s)| {
                        // `gain_parts` is the canonical exact decomposition
                        // (f64 Display round-trips exactly), `total_gain`
                        // the rounded convenience value. Canonical parts
                        // make equal stores serialize to equal bytes.
                        (
                            method.name().to_string(),
                            json::obj(vec![
                                ("attempts", json::num(s.attempts as f64)),
                                ("wins", json::num(s.wins as f64)),
                                ("total_gain", json::num(s.total_gain())),
                                (
                                    "gain_parts",
                                    json::arr(
                                        s.gain.canonical().iter().map(|&p| json::num(p)).collect(),
                                    ),
                                ),
                            ]),
                        )
                    })
                    .collect();
                (case.clone(), Json::Obj(m))
            })
            .collect();
        json::obj(vec![
            ("version", json::num(2.0)),
            ("observations", json::num(self.observations as f64)),
            ("cases", Json::Obj(cases)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SkillStore, String> {
        let mut store = SkillStore::new();
        store.observations = j
            .get("observations")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        let cases = j
            .get("cases")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| "skill store missing cases".to_string())?;
        for (case, methods) in cases {
            let methods = methods
                .as_obj()
                .ok_or_else(|| format!("case {case}: not an object"))?;
            let mut out = BTreeMap::new();
            for (mname, stat) in methods {
                let Some(method) = MethodId::from_name(mname) else {
                    // Unknown method (newer writer): skip, keep the rest.
                    continue;
                };
                let get = |k: &str| stat.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                // Exact parts when present; v1 stores (rounded total only)
                // load the rounded value as the single component.
                let gain = match stat.get("gain_parts").and_then(|v| v.as_arr()) {
                    Some(parts) => {
                        let vals: Vec<f64> = parts.iter().filter_map(|p| p.as_f64()).collect();
                        ExactSum::from_parts(&vals)
                    }
                    None => ExactSum::from_parts(&[get("total_gain")]),
                };
                out.insert(
                    method,
                    MethodStat {
                        attempts: get("attempts") as u64,
                        wins: get("wins") as u64,
                        gain,
                    },
                );
            }
            store.cases.insert(case.clone(), out);
        }
        Ok(store)
    }

    /// Atomic save: write a tmp file, then rename over the target.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", self.to_json()))?;
        std::fs::rename(&tmp, path)
    }

    /// Load a store; a missing file is an empty (cold) store, a corrupt
    /// file is an error.
    pub fn load(path: &Path) -> Result<SkillStore, String> {
        if !path.exists() {
            return Ok(SkillStore::new());
        }
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("parsing {path:?}: {e}"))?;
        SkillStore::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: m,
            gain,
        }
    }

    #[test]
    fn observe_accumulates() {
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(1.0)));
        s.observe(&obs("c", MethodId::TileSmem, Some(3.0)));
        s.observe(&obs("c", MethodId::TileSmem, None));
        let st = s.stat("c", MethodId::TileSmem).unwrap();
        assert_eq!(st.attempts, 3);
        assert_eq!(st.wins, 2);
        assert_eq!(st.mean_gain(), 2.0);
        assert_eq!(s.observations, 3);
    }

    #[test]
    fn merge_is_order_independent() {
        let a = vec![obs("c", MethodId::TileSmem, Some(1.0)), obs("d", MethodId::SplitK, None)];
        let b = vec![obs("c", MethodId::TileSmem, Some(0.5))];
        let mut s1 = SkillStore::new();
        s1.merge(&a);
        s1.merge(&b);
        let mut s2 = SkillStore::new();
        s2.merge(&b);
        s2.merge(&a);
        assert_eq!(s1, s2);
    }

    #[test]
    fn rerank_promotes_observed_winners_and_sinks_losers() {
        let mut s = SkillStore::new();
        // VectorizeLoads observed great, DoubleBuffer observed failing.
        s.observe(&obs("c", MethodId::VectorizeLoads, Some(2.0)));
        s.observe(&obs("c", MethodId::DoubleBuffer, None));
        let mut methods = vec![
            MethodId::DoubleBuffer,
            MethodId::TileSmem,
            MethodId::VectorizeLoads,
        ];
        s.rerank("c", &mut methods);
        assert_eq!(
            methods,
            vec![MethodId::VectorizeLoads, MethodId::TileSmem, MethodId::DoubleBuffer]
        );
    }

    #[test]
    fn rerank_unknown_case_is_noop() {
        let s = SkillStore::new();
        let mut methods = vec![MethodId::TileSmem, MethodId::SplitK];
        s.rerank("nope", &mut methods);
        assert_eq!(methods, vec![MethodId::TileSmem, MethodId::SplitK]);
    }

    #[test]
    fn json_roundtrip_exact() {
        let mut s = SkillStore::new();
        s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(1.2345678901234)));
        s.observe(&obs("gemm.naive_loop", MethodId::UseTensorCore, None));
        s.observe(&obs("fusion.elementwise_chain", MethodId::FuseElementwise, Some(0.25)));
        let j = s.to_json();
        let back = SkillStore::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ks-skills-{}", std::process::id()));
        let path = dir.join("skills.json");
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(0.5)));
        s.save(&path).unwrap();
        let back = SkillStore::load(&path).unwrap();
        assert_eq!(s, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_is_cold() {
        let s = SkillStore::load(Path::new("/nonexistent/skills.json")).unwrap();
        assert!(s.is_empty());
    }

    // ---- store-level merge: the sharding contract ----------------------

    /// Gains chosen so naive f64 summation is order-sensitive; exact
    /// accumulation must not be.
    fn shard_store(tag: u64) -> SkillStore {
        let mut s = SkillStore::new();
        let gains = [0.1, 0.2, 1e15, -1e15, 0.30000000000000004, 1e-9];
        for (i, g) in gains.iter().enumerate() {
            let gain = if i as u64 % 3 == tag % 3 { None } else { Some(g * (tag as f64 + 0.5)) };
            s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, gain));
            s.observe(&obs("fusion.elementwise_chain", MethodId::FuseElementwise, gain));
        }
        s
    }

    /// Serialized bytes, the strongest equality the merge promises.
    fn bytes(s: &SkillStore) -> String {
        s.to_json().to_string()
    }

    #[test]
    fn merge_store_is_commutative_at_byte_level() {
        let (a, b) = (shard_store(0), shard_store(1));
        let mut ab = a.clone();
        ab.merge_store(&b);
        let mut ba = b.clone();
        ba.merge_store(&a);
        assert_eq!(ab, ba);
        assert_eq!(bytes(&ab), bytes(&ba));
    }

    #[test]
    fn merge_store_is_associative_at_byte_level() {
        let (a, b, c) = (shard_store(0), shard_store(1), shard_store(2));
        let mut left = a.clone(); // (a + b) + c
        left.merge_store(&b);
        left.merge_store(&c);
        let mut bc = b.clone();
        bc.merge_store(&c);
        let mut right = a.clone(); // a + (b + c)
        right.merge_store(&bc);
        assert_eq!(left, right);
        assert_eq!(bytes(&left), bytes(&right));
    }

    #[test]
    fn merge_store_empty_is_identity() {
        let a = shard_store(1);
        let mut left = SkillStore::new();
        left.merge_store(&a);
        let mut right = a.clone();
        right.merge_store(&SkillStore::new());
        assert_eq!(left, a);
        assert_eq!(right, a);
        assert_eq!(bytes(&left), bytes(&a));
        assert_eq!(bytes(&right), bytes(&a));
    }

    #[test]
    fn store_fold_matches_observation_fold_in_any_order() {
        // Folding per-shard stores must equal folding the union of raw
        // observations, whatever the interleaving — the invariant `merge`
        // cross-checks between per-shard skills.json files and the
        // checkpointed cells.
        let all: Vec<SkillObs> = (0..3)
            .flat_map(|t| {
                [0.1, 0.7, 1e12, -1e12 + 3.0]
                    .iter()
                    .map(move |g| obs("reduction.rowwise", MethodId::VectorizeLoads, Some(g * (t + 1) as f64)))
                    .collect::<Vec<_>>()
            })
            .collect();
        let mut by_obs = SkillStore::new();
        for o in all.iter().rev() {
            by_obs.observe(o);
        }
        let mut by_stores = SkillStore::new();
        for chunk in all.chunks(4) {
            let mut shard = SkillStore::new();
            shard.merge(chunk);
            by_stores.merge_store(&shard);
        }
        assert_eq!(by_obs, by_stores);
        assert_eq!(bytes(&by_obs), bytes(&by_stores));
    }

    #[test]
    fn v1_store_without_gain_parts_still_loads() {
        let text = r#"{"version":1,"observations":2,"cases":{"c":{"tile_smem":{"attempts":2,"wins":1,"total_gain":0.75}}}}"#;
        let s = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap();
        let st = s.stat("c", MethodId::TileSmem).unwrap();
        assert_eq!(st.attempts, 2);
        assert_eq!(st.total_gain(), 0.75);
    }
}
