//! Persistent long-term skill memory v4: the *learned* layer on top of the
//! curated knowledge base.
//!
//! The curated store (`kb_content`) is static expert knowledge; what the
//! paper's dual-level memory additionally needs is cross-task transfer —
//! outcomes observed while optimizing one task should inform method choice
//! on later tasks, seeds, strategies, and processes. This module records,
//! per decision-table case, how every method actually performed
//! ([`MethodStat`]), serializes the store to disk after each task (the
//! suite orchestrator owns the write cycle), and warm-starts retrieval
//! from it.
//!
//! v3 adds three things on top of the v2 outcome ledger (the on-disk
//! contract is specified normatively in `docs/memory-formats.md`):
//!
//! * **Device partitions.** Stats are keyed by the device preset that
//!   produced them (`DeviceSpec::name`, e.g. `a100-like` vs `tpu-like`):
//!   what wins on a GPU-shaped machine is not evidence about a TPU-shaped
//!   one. Retrieval consults the matching partition first and falls back
//!   to the pooled cross-device view at a discount
//!   ([`CROSS_DEVICE_DISCOUNT`]).
//! * **Confidence-weighted, decaying scores.** Reranking no longer uses
//!   the raw mean gain: [`MethodStat::score`] shrinks the observed mean
//!   toward the curated prior by [`PRIOR_WEIGHT`] pseudo-attempts (small
//!   samples barely move the curated order; strong evidence dominates it)
//!   and down-weights stale stats by [`STALENESS_DECAY`] per generation of
//!   age. The generation counter is deterministic — bumped per completed
//!   fold epoch, never wall clock — so resume/merge determinism holds.
//! * **Learned decision cases.** When the evidence in one partition
//!   consistently contradicts or extends the curated decision table, the
//!   store synthesizes a [`LearnedCase`] (promotion / demotion /
//!   extension). Learned cases are *derived* deterministically from the
//!   stats — serialized for inspectability, recomputed on load — so they
//!   can never break the merge algebra. Retrieval surfaces them in
//!   [`RetrievalResult::audit`](super::retrieval::RetrievalResult::audit).
//!
//! Persistence uses the repo's own JSON layer (serde is not vendored
//! offline) and writes are atomic (tmp + rename) so a killed run never
//! leaves a torn store behind.
//!
//! v4 adds the **segmented on-disk layout** (see `segmented`): a live
//! memory-dir store may be persisted as a manifest whose `partitions` hold
//! only the active head, with the rest of the history in immutable folded
//! segment files under `skills.segments/`. [`SkillStore::load`] folds a
//! segmented manifest back into one logical store transparently, so every
//! reader sees the same bytes a monolithic store would have produced; the
//! flat serialization ([`SkillStore::to_json`]) carries an empty
//! `segments` list and stays the canonical one-blob form.
//!
//! Merging is exact: per-(partition, case, method) gain totals accumulate
//! through [`ExactSum`], counts add, and generation stamps combine through
//! `max`, so folding observations — or whole stores, via
//! [`SkillStore::merge_store`] — is commutative and associative *at the
//! bit level*, with the empty store as identity. That is the property the
//! sharded suite relies on: N shards merged in any order serialize to the
//! same bytes a single process would have written.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use super::kb_content::DECISION_TABLE;
use super::schema::{LearnedCase, LearnedOrigin};
use crate::kir::transforms::MethodId;
use crate::util::fsum::ExactSum;
use crate::util::json::{self, Json};

/// Partition key assigned to observations loaded from v1/v2 stores and
/// pre-v3 checkpoints, which carried no device field. Every pre-v3 run used
/// the default `LoopConfig` device, which is the A100-like preset.
pub const LEGACY_DEVICE: &str = "a100-like";

/// Pseudo-attempts of the curated prior a stat is shrunk toward: with `n`
/// real attempts, the observed mean gain is scaled by `n / (n + this)`, so
/// one lucky observation cannot overturn the curated order but sustained
/// evidence can.
pub const PRIOR_WEIGHT: f64 = 2.0;

/// Per-generation-of-age multiplier applied to a stat's score: a stat last
/// re-observed `d` fold epochs ago contributes `STALENESS_DECAY^d` of its
/// fresh weight, decaying toward the curated prior rather than below it.
pub const STALENESS_DECAY: f64 = 0.85;

/// Score multiplier applied when retrieval falls back from the requested
/// device partition to the pooled cross-device view: evidence gathered on
/// different hardware is suggestive, not conclusive.
pub const CROSS_DEVICE_DISCOUNT: f64 = 0.25;

/// Minimum attempts a (partition, case, method) stat needs before the store
/// will synthesize a [`LearnedCase`] from it.
pub const MIN_LEARN_EVIDENCE: u64 = 5;

/// Minimum Wilson-lower-bound confidence a stat needs before the store will
/// synthesize a [`LearnedCase`] from it.
pub const MIN_LEARN_CONFIDENCE: f64 = 0.5;

/// One learned observation: applying `method` on `device` while the
/// decision table had matched `case_id` produced `gain` (speedup delta vs
/// the base kernel), or failed review (`None`).
#[derive(Debug, Clone, PartialEq)]
pub struct SkillObs {
    /// Matched decision-table case id (e.g. `gemm.naive_loop`).
    pub case_id: String,
    /// Optimization method that was applied.
    pub method: MethodId,
    /// Measured speedup delta vs the base kernel; `None` = failed review.
    pub gain: Option<f64>,
    /// Device preset the observation was measured on (`DeviceSpec::name`);
    /// selects the store partition the stat lands in.
    pub device: String,
}

/// Wilson score-interval lower bound (z = 1, one-sided ~84%) on the success
/// probability after `successes` out of `trials`. Zero trials score 0.
pub fn wilson_lower_bound(successes: u64, trials: u64) -> f64 {
    if trials == 0 {
        return 0.0;
    }
    let n = trials as f64;
    let p = successes as f64 / n;
    // z = 1, so z^2 = 1 throughout.
    let centre = p + 1.0 / (2.0 * n);
    let margin = (p * (1.0 - p) / n + 1.0 / (4.0 * n * n)).sqrt();
    ((centre - margin) / (1.0 + 1.0 / n)).max(0.0)
}

/// Aggregate outcome statistics for one (partition, case, method) triple.
///
/// The gain total is an exact accumulator, not a plain f64, so stats from
/// different shards/orders combine to bit-identical results; the freshness
/// stamp (`last_gen`) combines through `max`, which is equally
/// order-independent.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStat {
    /// Times the method was tried while this case was matched.
    pub attempts: u64,
    /// Attempts whose candidate compiled, verified, and was measured.
    pub wins: u64,
    /// Exact sum of speedup deltas over winning attempts.
    gain: ExactSum,
    /// Fold epoch (store generation) at which this stat last absorbed an
    /// observation; drives the staleness decay.
    pub last_gen: u64,
}

impl MethodStat {
    /// Sum of speedup deltas over winning attempts (correctly rounded).
    pub fn total_gain(&self) -> f64 {
        self.gain.value()
    }

    /// Mean speedup delta over winning attempts (0 when nothing won).
    pub fn mean_gain(&self) -> f64 {
        if self.wins == 0 {
            0.0
        } else {
            self.total_gain() / self.wins as f64
        }
    }

    /// Fraction of attempts that survived review.
    pub fn win_rate(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.wins as f64 / self.attempts as f64
        }
    }

    /// Wilson lower bound on the win rate — the confidence weight the
    /// rerank and the learned-case synthesis both use.
    pub fn wilson_lower_bound(&self) -> f64 {
        wilson_lower_bound(self.wins, self.attempts)
    }

    /// Staleness multiplier relative to the store's current generation: 1.0
    /// when re-observed this epoch, decaying by [`STALENESS_DECAY`] per
    /// epoch of age (exponent capped so ancient stats cannot underflow).
    pub fn staleness_weight(&self, store_generation: u64) -> f64 {
        let d = store_generation.saturating_sub(self.last_gen).min(64);
        STALENESS_DECAY.powi(d as i32)
    }

    /// Confidence-weighted ranking score at the given store generation.
    ///
    /// The observed mean gain per attempt is shrunk toward the curated
    /// prior (score 0 — "keep the curated order") by [`PRIOR_WEIGHT`]
    /// pseudo-attempts, then staleness-decayed. Methods that only ever
    /// failed score negative (sinking below untried ones), with magnitude
    /// that also grows with evidence and decays with age. Unobserved
    /// methods score exactly 0, so they keep their curated position.
    pub fn score(&self, store_generation: u64) -> f64 {
        if self.attempts == 0 {
            return 0.0;
        }
        let n = self.attempts as f64;
        let shrunk = if self.wins == 0 {
            -(n / (n + PRIOR_WEIGHT))
        } else {
            self.total_gain() / (n + PRIOR_WEIGHT)
        };
        shrunk * self.staleness_weight(store_generation)
    }

    /// Add another stat's counts, exact gain total, and freshness stamp
    /// into this one. Counts add, gains add exactly, stamps take the max —
    /// all commutative and associative.
    fn absorb(&mut self, other: &MethodStat) {
        self.attempts += other.attempts;
        self.wins += other.wins;
        self.gain.add_sum(&other.gain);
        self.last_gen = self.last_gen.max(other.last_gen);
    }
}

/// Stats for one case: method -> outcome stats.
pub type CaseStats = BTreeMap<MethodId, MethodStat>;

/// One device partition: case id -> per-method stats.
pub type Partition = BTreeMap<String, CaseStats>;

/// What [`SkillStore::gc`] removed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GcReport {
    /// Age threshold the sweep ran with (generations since last observed).
    pub max_age: u64,
    /// Partition the sweep was scoped to (`None` = every partition).
    pub device: Option<String>,
    /// Individual (partition, case, method) stats dropped.
    pub dropped_stats: usize,
    /// Case entries left empty by the sweep and removed.
    pub dropped_cases: usize,
    /// Partitions left empty by the sweep and removed.
    pub dropped_partitions: usize,
}

impl GcReport {
    /// Human-readable one-line summary.
    pub fn render(&self) -> String {
        let scope = match &self.device {
            Some(d) => format!("partition {d}, "),
            None => String::new(),
        };
        format!(
            "gc ({scope}max age {} generation(s)): dropped {} stat(s), {} emptied case(s), {} emptied partition(s)",
            self.max_age, self.dropped_stats, self.dropped_cases, self.dropped_partitions
        )
    }
}

/// The persistent skill store: device partition -> case id -> method ->
/// stats, plus the deterministic generation clock.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SkillStore {
    /// Per-device-preset stat partitions. Keys are `DeviceSpec::name`
    /// values ([`LEGACY_DEVICE`] for data migrated from v1/v2 stores).
    pub partitions: BTreeMap<String, Partition>,
    /// Total observations folded in (for the audit trail). A historical
    /// counter: [`SkillStore::gc`] does not decrement it.
    pub observations: u64,
    /// Deterministic fold-epoch clock. Observations are stamped with the
    /// generation current at fold time; the suite orchestrator advances it
    /// once per fold epoch (one `run-task` invocation, one strategy-suite
    /// run), never per wall clock — see `coordinator::scheduler`.
    pub generation: u64,
}

impl SkillStore {
    /// An empty (cold) store at generation 0.
    pub fn new() -> SkillStore {
        SkillStore::default()
    }

    /// True when the store holds no stats at all.
    pub fn is_empty(&self) -> bool {
        self.partitions.is_empty()
    }

    /// Number of distinct case ids observed across all partitions.
    pub fn case_count(&self) -> usize {
        let mut ids: std::collections::BTreeSet<&str> = Default::default();
        for cases in self.partitions.values() {
            for case in cases.keys() {
                ids.insert(case);
            }
        }
        ids.len()
    }

    /// Advance the generation clock by one fold epoch and return the new
    /// generation. Standalone `run-task` invocations call this before
    /// folding a task's observations ("bumped per completed task"); the
    /// suite orchestrator instead derives the epoch from the warm-start
    /// snapshot so resumed runs reuse the interrupted run's epoch.
    pub fn advance_generation(&mut self) -> u64 {
        self.generation += 1;
        self.generation
    }

    /// Stat recorded for `method` under `case_id` in the `device`
    /// partition, if any.
    pub fn stat_in(&self, device: &str, case_id: &str, method: MethodId) -> Option<&MethodStat> {
        self.partitions
            .get(device)
            .and_then(|p| p.get(case_id))
            .and_then(|m| m.get(&method))
    }

    /// Pooled cross-device stat for (case, method): the fold of every
    /// partition's stat. `None` when no partition observed the pair.
    pub fn pooled_stat(&self, case_id: &str, method: MethodId) -> Option<MethodStat> {
        let mut out: Option<MethodStat> = None;
        for p in self.partitions.values() {
            if let Some(s) = p.get(case_id).and_then(|m| m.get(&method)) {
                out.get_or_insert_with(MethodStat::default).absorb(s);
            }
        }
        out
    }

    /// Fold one observation in, stamped with the current fold epoch.
    ///
    /// The stamp is `max(generation, 1)` — a cold store's first fold is
    /// epoch 1 — and folding never *advances* the clock, so folding a
    /// multiset of observations is order-independent at the bit level
    /// (which is what lets the work-stealing scheduler fold cells in
    /// completion order).
    pub fn observe(&mut self, obs: &SkillObs) {
        let epoch = self.generation.max(1);
        self.generation = epoch;
        let stat = self
            .partitions
            .entry(obs.device.clone())
            .or_default()
            .entry(obs.case_id.clone())
            .or_default()
            .entry(obs.method)
            .or_default();
        stat.attempts += 1;
        if let Some(g) = obs.gain {
            stat.wins += 1;
            stat.gain.add(g);
        }
        stat.last_gen = stat.last_gen.max(epoch);
        self.observations += 1;
    }

    /// Fold a task's worth of observations in (all at the current epoch).
    /// Merging is additive and gain totals accumulate exactly, so the
    /// final store is bit-identical regardless of the order tasks complete
    /// in.
    pub fn merge(&mut self, obs: &[SkillObs]) {
        for o in obs {
            self.observe(o);
        }
    }

    /// Cold fold of a batch of observations: an empty store with every stat
    /// stamped at epoch 1, exactly the shape run-dir stores and the live
    /// memory-exchange deltas (`exchange/<strategy>/epoch-K.shard-I.json`)
    /// use. Because the fold starts from the identity and stamps are fixed,
    /// the result is a pure function of the observation multiset — any
    /// partitioning of the same cells produces deltas whose
    /// [`SkillStore::merge_store`] union is bit-identical.
    pub fn from_observations<'a, I>(obs: I) -> SkillStore
    where
        I: IntoIterator<Item = &'a SkillObs>,
    {
        let mut store = SkillStore::new();
        for o in obs {
            store.observe(o);
        }
        store
    }

    /// Fold an entire store into this one: per-(partition, case, method)
    /// stats add (counts and exact gain totals alike), freshness stamps
    /// and the generation clock combine through `max`. This fold is
    /// commutative and associative at the bit level, with the empty store
    /// as identity — the contract the sharded suite's `merge` subcommand
    /// depends on.
    pub fn merge_store(&mut self, other: &SkillStore) {
        for (device, cases) in &other.partitions {
            for (case, methods) in cases {
                if methods.is_empty() {
                    continue;
                }
                let dst = self
                    .partitions
                    .entry(device.clone())
                    .or_default()
                    .entry(case.clone())
                    .or_default();
                for (method, stat) in methods {
                    dst.entry(*method).or_default().absorb(stat);
                }
            }
        }
        self.observations += other.observations;
        self.generation = self.generation.max(other.generation);
    }

    /// Reorder a case's allowed methods by learned performance on `device`:
    /// stable sort, descending confidence-weighted score. The matching
    /// device partition is consulted first; methods it never observed fall
    /// back to the pooled cross-device view at [`CROSS_DEVICE_DISCOUNT`].
    /// Methods never tried anywhere keep their curated position among
    /// themselves (score 0); methods that only ever failed sink below
    /// untried ones. An empty `device` skips the partition preference and
    /// ranks on the pooled view at full weight.
    pub fn rerank(&self, device: &str, case_id: &str, methods: &mut [MethodId]) {
        let scores: Vec<f64> = methods
            .iter()
            .map(|&m| self.rank_score(device, case_id, m))
            .collect();
        let mut order: Vec<usize> = (0..methods.len()).collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let reordered: Vec<MethodId> = order.iter().map(|&i| methods[i]).collect();
        methods.copy_from_slice(&reordered);
    }

    /// The score [`SkillStore::rerank`] sorts by: device partition first,
    /// pooled fallback at [`CROSS_DEVICE_DISCOUNT`], 0 when unobserved.
    pub fn rank_score(&self, device: &str, case_id: &str, method: MethodId) -> f64 {
        if !device.is_empty() {
            if let Some(s) = self.stat_in(device, case_id, method) {
                return s.score(self.generation);
            }
        }
        match self.pooled_stat(case_id, method) {
            Some(s) => {
                let x = s.score(self.generation);
                if device.is_empty() {
                    x
                } else {
                    x * CROSS_DEVICE_DISCOUNT
                }
            }
            None => 0.0,
        }
    }

    // ---- learned decision cases -----------------------------------------

    /// Synthesize learned decision cases from the recorded evidence.
    ///
    /// Derived — not stored — so two stores holding the same stats always
    /// agree on their learned cases, whatever order they were merged in.
    /// Per (partition, curated case), with at least [`MIN_LEARN_EVIDENCE`]
    /// attempts and [`MIN_LEARN_CONFIDENCE`] Wilson confidence:
    ///
    /// * **Promotion** — a method other than the curated first choice whose
    ///   confidence-weighted score beats the first choice's *observed*
    ///   score in the same partition (the first choice must have been
    ///   tried there — an unmeasured comparison is not a contradiction).
    /// * **Demotion** — the curated first choice failed every attempt: the
    ///   evidence contradicts the curated recommendation outright.
    /// * **Extension** — a winning method outside the case's curated
    ///   `allowed_methods` (free-choice strategies can discover these): the
    ///   evidence extends the curated method set.
    pub fn learned_cases(&self) -> Vec<LearnedCase> {
        let mut out = Vec::new();
        for (device, cases) in &self.partitions {
            for (case_id, methods) in cases {
                self.synthesize_case(device, case_id, methods, &mut out);
            }
        }
        out
    }

    /// Learned cases for one (device, case) pair — what retrieval surfaces
    /// in the audit trail. An empty `device` matches every partition.
    /// Synthesis runs only over the requested slice of the store (this
    /// sits in the per-round retrieval hot path).
    pub fn learned_for(&self, device: &str, case_id: &str) -> Vec<LearnedCase> {
        let mut out = Vec::new();
        for (dev, cases) in &self.partitions {
            if !device.is_empty() && dev.as_str() != device {
                continue;
            }
            if let Some(methods) = cases.get(case_id) {
                self.synthesize_case(dev, case_id, methods, &mut out);
            }
        }
        out
    }

    /// Synthesis core for one (partition, case): see [`SkillStore::learned_cases`].
    fn synthesize_case(
        &self,
        device: &str,
        case_id: &str,
        methods: &CaseStats,
        out: &mut Vec<LearnedCase>,
    ) {
        let curated = DECISION_TABLE.iter().find(|c| c.id == case_id);
        let curated_first = curated.and_then(|c| c.allowed_methods.first().copied());
        let first_stat = curated_first.and_then(|m| methods.get(&m));
        let first_observed = first_stat.map(|s| s.attempts > 0).unwrap_or(false);
        let first_score = first_stat.map(|s| s.score(self.generation)).unwrap_or(0.0);
        for (&method, stat) in methods {
            if stat.attempts < MIN_LEARN_EVIDENCE {
                continue;
            }
            if Some(method) == curated_first {
                // Contradiction of the curated recommendation itself: it
                // consistently fails here. The evidence floor above is the
                // whole gate — at MIN_LEARN_EVIDENCE all-failed attempts,
                // the Wilson bound on the failure rate (recorded as the
                // case's confidence) already clears any sane threshold.
                if stat.wins == 0 {
                    out.push(self.learned_case(
                        device,
                        case_id,
                        method,
                        stat,
                        LearnedOrigin::Demotion,
                    ));
                }
                continue;
            }
            let confidence = stat.wilson_lower_bound();
            if confidence < MIN_LEARN_CONFIDENCE || stat.score(self.generation) <= 0.0 {
                continue;
            }
            let in_curated = curated
                .map(|c| c.allowed_methods.contains(&method))
                .unwrap_or(true);
            if !in_curated {
                out.push(self.learned_case(
                    device,
                    case_id,
                    method,
                    stat,
                    LearnedOrigin::Extension,
                ));
            } else if first_observed && stat.score(self.generation) > first_score {
                // A promotion is only a *contradiction* when the curated
                // first choice was actually measured in this partition.
                out.push(self.learned_case(
                    device,
                    case_id,
                    method,
                    stat,
                    LearnedOrigin::Promotion,
                ));
            }
        }
    }

    fn learned_case(
        &self,
        device: &str,
        case_id: &str,
        method: MethodId,
        stat: &MethodStat,
        origin: LearnedOrigin,
    ) -> LearnedCase {
        let why = match origin {
            LearnedOrigin::Promotion => format!(
                "{} outperforms the curated first choice on {device} \
                 ({}/{} wins, mean gain {:+.3})",
                method.name(),
                stat.wins,
                stat.attempts,
                stat.mean_gain()
            ),
            LearnedOrigin::Demotion => format!(
                "curated first choice {} failed all {} attempt(s) on {device}",
                method.name(),
                stat.attempts
            ),
            LearnedOrigin::Extension => format!(
                "{} wins outside the curated method set on {device} \
                 ({}/{} wins, mean gain {:+.3})",
                method.name(),
                stat.wins,
                stat.attempts,
                stat.mean_gain()
            ),
        };
        let confidence = match origin {
            LearnedOrigin::Demotion => wilson_lower_bound(stat.attempts, stat.attempts),
            _ => stat.wilson_lower_bound(),
        };
        LearnedCase {
            device: device.to_string(),
            base_case: case_id.to_string(),
            method,
            origin,
            attempts: stat.attempts,
            wins: stat.wins,
            mean_gain: stat.mean_gain(),
            confidence,
            why,
        }
    }

    // ---- maintenance ----------------------------------------------------

    /// Drop stats that have not been re-observed for more than `max_age`
    /// generations (then prune emptied cases/partitions). The
    /// `observations` and `generation` counters are historical and remain
    /// untouched. This is the `skills gc` CLI surface; run-dir stores are
    /// derived from checkpoints and never need it.
    pub fn gc(&mut self, max_age: u64) -> GcReport {
        self.gc_device(max_age, None)
    }

    /// [`SkillStore::gc`] scoped to one device partition: only stats under
    /// `device` are aged, every other partition is left byte-untouched —
    /// the `skills gc --device` per-partition retention policy. `None`
    /// sweeps everything.
    pub fn gc_device(&mut self, max_age: u64, device: Option<&str>) -> GcReport {
        let mut report = GcReport {
            max_age,
            device: device.map(|d| d.to_string()),
            ..GcReport::default()
        };
        let gen = self.generation;
        self.partitions.retain(|dev, cases| {
            if device.is_some_and(|d| d != dev.as_str()) {
                return true;
            }
            cases.retain(|_, methods| {
                let before = methods.len();
                methods.retain(|_, stat| gen.saturating_sub(stat.last_gen) <= max_age);
                report.dropped_stats += before - methods.len();
                if methods.is_empty() {
                    report.dropped_cases += 1;
                    false
                } else {
                    true
                }
            });
            if cases.is_empty() {
                report.dropped_partitions += 1;
                false
            } else {
                true
            }
        });
        report
    }

    /// Render the store for the `skills inspect` CLI: header, per-partition
    /// stat tables (optionally filtered by partition key / case-id
    /// substring), and the synthesized learned cases.
    pub fn render_inspect(&self, device: Option<&str>, case: Option<&str>) -> String {
        let mut out = format!(
            "skill store v4: generation {}, {} observation(s), {} partition(s), {} case(s)\n",
            self.generation,
            self.observations,
            self.partitions.len(),
            self.case_count()
        );
        if self.is_empty() {
            out.push_str("(no recorded stats)\n");
            return out;
        }
        if let Some(d) = device {
            if !self.partitions.contains_key(d) {
                out.push_str(&format!(
                    "(no partition {d:?}; known: {:?})\n",
                    self.partition_names()
                ));
                return out;
            }
        }
        for (dev, cases) in &self.partitions {
            if device.map(|d| d != dev.as_str()).unwrap_or(false) {
                continue;
            }
            out.push_str(&format!("partition {dev}:\n"));
            for (case_id, methods) in cases {
                if case.map(|c| !case_id.contains(c)).unwrap_or(false) {
                    continue;
                }
                out.push_str(&format!("  case {case_id}:\n"));
                for (method, s) in methods {
                    out.push_str(&format!(
                        "    {:<24} attempts {:>4}  wins {:>4}  win% {:>5.1}  conf {:.2}  \
                         mean gain {:+.3}  last_gen {:>3}  staleness x{:.2}  score {:+.4}\n",
                        method.name(),
                        s.attempts,
                        s.wins,
                        100.0 * s.win_rate(),
                        s.wilson_lower_bound(),
                        s.mean_gain(),
                        s.last_gen,
                        s.staleness_weight(self.generation),
                        s.score(self.generation)
                    ));
                }
            }
        }
        let learned = self.learned_cases();
        if !learned.is_empty() {
            out.push_str("learned decision cases:\n");
            for lc in learned {
                if device.map(|d| d != lc.device).unwrap_or(false) {
                    continue;
                }
                if case.map(|c| !lc.base_case.contains(c)).unwrap_or(false) {
                    continue;
                }
                out.push_str(&format!("  {}\n", lc.render()));
            }
        }
        out
    }

    fn partition_names(&self) -> Vec<&str> {
        self.partitions.keys().map(|k| k.as_str()).collect()
    }

    // ---- persistence ----------------------------------------------------

    /// Serialize to the canonical v4 one-blob JSON form (see
    /// `docs/memory-formats.md`). Equal stores serialize to equal bytes:
    /// maps are sorted, gain totals use the canonical exact decomposition,
    /// the `learned` section is derived deterministically from the stats,
    /// and the `segments` list is always empty — a flat store *is* its own
    /// head. Segmented manifests are written only by
    /// [`segmented::SegmentedSkillStore`](super::segmented::SegmentedSkillStore).
    pub fn to_json(&self) -> Json {
        let partitions = self
            .partitions
            .iter()
            .map(|(device, cases)| {
                let cs = cases
                    .iter()
                    .map(|(case, methods)| {
                        let m = methods
                            .iter()
                            .map(|(method, s)| {
                                // `gain_parts` is the canonical exact
                                // decomposition (f64 Display round-trips
                                // exactly), `total_gain` the rounded
                                // convenience value. Canonical parts make
                                // equal stores serialize to equal bytes.
                                (
                                    method.name().to_string(),
                                    json::obj(vec![
                                        ("attempts", json::num(s.attempts as f64)),
                                        ("wins", json::num(s.wins as f64)),
                                        ("total_gain", json::num(s.total_gain())),
                                        (
                                            "gain_parts",
                                            json::arr(
                                                s.gain
                                                    .canonical()
                                                    .iter()
                                                    .map(|&p| json::num(p))
                                                    .collect(),
                                            ),
                                        ),
                                        ("last_gen", json::num(s.last_gen as f64)),
                                    ]),
                                )
                            })
                            .collect();
                        (case.clone(), Json::Obj(m))
                    })
                    .collect();
                (device.clone(), Json::Obj(cs))
            })
            .collect();
        json::obj(vec![
            ("version", json::num(4.0)),
            ("generation", json::num(self.generation as f64)),
            ("observations", json::num(self.observations as f64)),
            ("partitions", Json::Obj(partitions)),
            ("learned", Json::Arr(self.learned_json())),
            ("segments", json::arr(vec![])),
        ])
    }

    /// The serialized `learned` section: derived learned cases in canonical
    /// order. Factored out so the segmented manifest writer can derive the
    /// section from the *logical* fold while its `partitions` hold only the
    /// active head.
    pub(crate) fn learned_json(&self) -> Vec<Json> {
        self.learned_cases()
            .iter()
            .map(|lc| {
                json::obj(vec![
                    ("id", json::s(&lc.id())),
                    ("origin", json::s(lc.origin.name())),
                    ("device", json::s(&lc.device)),
                    ("case", json::s(&lc.base_case)),
                    ("method", json::s(lc.method.name())),
                    ("attempts", json::num(lc.attempts as f64)),
                    ("wins", json::num(lc.wins as f64)),
                    ("mean_gain", json::num(lc.mean_gain)),
                    ("confidence", json::num(lc.confidence)),
                    ("why", json::s(&lc.why)),
                ])
            })
            .collect()
    }

    /// Parse any *flat* store version. v3/v4 read the partitioned form
    /// (the `learned` section is derived data and ignored); v1/v2 stores —
    /// a flat top-level `cases` map, with (`v2`) or without (`v1`) exact
    /// `gain_parts` — load into the [`LEGACY_DEVICE`] partition at
    /// generation 1 and re-save canonically as v4. A v4 manifest with a
    /// non-empty `segments` list is rejected here: its partitions are only
    /// the active head, so parsing it flat would silently drop history —
    /// go through [`SkillStore::load`], which folds the segments back in.
    pub fn from_json(j: &Json) -> Result<SkillStore, String> {
        if j.get("segments")
            .and_then(|s| s.as_arr())
            .is_some_and(|segs| !segs.is_empty())
        {
            return Err(
                "segmented v4 manifest (non-empty `segments`); load via SkillStore::load so \
                 segment files fold back into the logical store"
                    .to_string(),
            );
        }
        let mut store = SkillStore::new();
        store.observations = j
            .get("observations")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        if let Some(partitions) = j.get("partitions").and_then(|p| p.as_obj()) {
            // v3 form.
            store.generation = j.get("generation").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            for (device, cases) in partitions {
                let cases = cases
                    .as_obj()
                    .ok_or_else(|| format!("partition {device}: not an object"))?;
                for (case, methods) in cases {
                    let parsed = parse_case(case, methods, None)?;
                    if !parsed.is_empty() {
                        store
                            .partitions
                            .entry(device.clone())
                            .or_default()
                            .insert(case.clone(), parsed);
                    }
                }
            }
            return Ok(store);
        }
        // v1/v2 form: flat cases, no device partitions, no generation.
        let cases = j
            .get("cases")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| "skill store missing cases/partitions".to_string())?;
        for (case, methods) in cases {
            let parsed = parse_case(case, methods, Some(1))?;
            if !parsed.is_empty() {
                store
                    .partitions
                    .entry(LEGACY_DEVICE.to_string())
                    .or_default()
                    .insert(case.clone(), parsed);
            }
        }
        if !store.partitions.is_empty() || store.observations > 0 {
            store.generation = 1;
        }
        Ok(store)
    }

    /// The exact bytes [`SkillStore::save`] writes: the canonical v4 JSON
    /// form plus a trailing newline. Equal stores produce equal bytes, which
    /// is what lets transports and tests compare stores without touching
    /// disk.
    pub fn canonical_bytes(&self) -> Vec<u8> {
        format!("{}\n", self.to_json()).into_bytes()
    }

    /// Parse a store from raw bytes (any accepted version) — the in-memory
    /// twin of [`SkillStore::load`]. Run-dir transports use it to validate a
    /// pulled exchange delta *before* installing it where a waiting shard
    /// would fold it.
    pub fn from_bytes(bytes: &[u8]) -> Result<SkillStore, String> {
        let text =
            std::str::from_utf8(bytes).map_err(|e| format!("skill store is not UTF-8: {e}"))?;
        let j = Json::parse(text).map_err(|e| format!("parsing skill store: {e}"))?;
        SkillStore::from_json(&j)
    }

    /// Atomic save: write a tmp file, then rename over the target.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.canonical_bytes())?;
        std::fs::rename(&tmp, path)
    }

    /// Load a store; a missing file is an empty (cold) store, a corrupt
    /// file is an error. A segmented v4 manifest is folded back into one
    /// logical store transparently (head + every segment, via the same
    /// commutative [`SkillStore::merge_store`] algebra), so callers that
    /// only *read* memory never need to know about segments.
    pub fn load(path: &Path) -> Result<SkillStore, String> {
        if !path.exists() {
            return Ok(SkillStore::new());
        }
        let bytes = std::fs::read(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| format!("{}: skill store is not UTF-8: {e}", path.display()))?;
        let j = Json::parse(text).map_err(|e| format!("{}: parsing skill store: {e}", path.display()))?;
        if j.get("segments")
            .and_then(|s| s.as_arr())
            .is_some_and(|segs| !segs.is_empty())
        {
            return super::segmented::SegmentedSkillStore::open_path(path)
                .map(|seg| seg.into_logical());
        }
        SkillStore::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// Parse one case's method map. `legacy_gen` forces the freshness stamp
/// (v1/v2 stores recorded none); v3 reads the stored `last_gen`.
fn parse_case(case: &str, methods: &Json, legacy_gen: Option<u64>) -> Result<CaseStats, String> {
    let methods = methods
        .as_obj()
        .ok_or_else(|| format!("case {case}: not an object"))?;
    let mut out = CaseStats::new();
    for (mname, stat) in methods {
        let Some(method) = MethodId::from_name(mname) else {
            // Unknown method (newer writer): skip, keep the rest.
            continue;
        };
        let get = |k: &str| stat.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        // Exact parts when present; v1 stores (rounded total only) load
        // the rounded value as the single component.
        let gain = match stat.get("gain_parts").and_then(|v| v.as_arr()) {
            Some(parts) => {
                let vals: Vec<f64> = parts.iter().filter_map(|p| p.as_f64()).collect();
                ExactSum::from_parts(&vals)
            }
            None => ExactSum::from_parts(&[get("total_gain")]),
        };
        out.insert(
            method,
            MethodStat {
                attempts: get("attempts") as u64,
                wins: get("wins") as u64,
                gain,
                last_gen: legacy_gen.unwrap_or_else(|| get("last_gen").max(1.0) as u64),
            },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        obs_on(LEGACY_DEVICE, case, m, gain)
    }

    fn obs_on(device: &str, case: &str, m: MethodId, gain: Option<f64>) -> SkillObs {
        SkillObs {
            case_id: case.to_string(),
            method: m,
            gain,
            device: device.to_string(),
        }
    }

    #[test]
    fn observe_accumulates() {
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(1.0)));
        s.observe(&obs("c", MethodId::TileSmem, Some(3.0)));
        s.observe(&obs("c", MethodId::TileSmem, None));
        let st = s.stat_in(LEGACY_DEVICE, "c", MethodId::TileSmem).unwrap();
        assert_eq!(st.attempts, 3);
        assert_eq!(st.wins, 2);
        assert_eq!(st.mean_gain(), 2.0);
        assert_eq!(st.last_gen, 1);
        assert_eq!(s.observations, 3);
        assert_eq!(s.generation, 1, "cold folds land in epoch 1");
    }

    #[test]
    fn from_observations_is_partition_independent() {
        // Any split of one observation multiset into cold deltas must union
        // (in any order) to the same bytes as the one-shot cold fold — the
        // exchange protocol's core invariant.
        let all: Vec<SkillObs> = (0..6)
            .map(|i| {
                obs_on(
                    if i % 2 == 0 { "a100-like" } else { "tpu-like" },
                    "gemm.naive_loop",
                    MethodId::TileSmem,
                    if i % 3 == 0 { None } else { Some(0.1 * i as f64 + 1e15) },
                )
            })
            .collect();
        let whole = SkillStore::from_observations(&all);
        let mut pieced = SkillStore::new();
        for chunk in all.chunks(2).rev().collect::<Vec<_>>() {
            pieced.merge_store(&SkillStore::from_observations(chunk.iter()));
        }
        assert_eq!(whole, pieced);
        assert_eq!(whole.to_json().to_string(), pieced.to_json().to_string());
        assert_eq!(whole.generation, 1, "cold deltas live at epoch 1");
    }

    #[test]
    fn merge_is_order_independent() {
        let a = vec![obs("c", MethodId::TileSmem, Some(1.0)), obs("d", MethodId::SplitK, None)];
        let b = vec![obs("c", MethodId::TileSmem, Some(0.5))];
        let mut s1 = SkillStore::new();
        s1.merge(&a);
        s1.merge(&b);
        let mut s2 = SkillStore::new();
        s2.merge(&b);
        s2.merge(&a);
        assert_eq!(s1, s2);
        assert_eq!(s1.to_json().to_string(), s2.to_json().to_string());
    }

    #[test]
    fn partitions_isolate_devices() {
        let mut s = SkillStore::new();
        s.observe(&obs_on("a100-like", "c", MethodId::TileSmem, Some(2.0)));
        s.observe(&obs_on("tpu-like", "c", MethodId::TileSmem, None));
        let a = s.stat_in("a100-like", "c", MethodId::TileSmem).unwrap();
        let t = s.stat_in("tpu-like", "c", MethodId::TileSmem).unwrap();
        assert_eq!((a.attempts, a.wins), (1, 1));
        assert_eq!((t.attempts, t.wins), (1, 0));
        let pooled = s.pooled_stat("c", MethodId::TileSmem).unwrap();
        assert_eq!((pooled.attempts, pooled.wins), (2, 1));
        assert_eq!(s.case_count(), 1);
    }

    #[test]
    fn rerank_promotes_observed_winners_and_sinks_losers() {
        let mut s = SkillStore::new();
        // VectorizeLoads observed great, DoubleBuffer observed failing.
        s.observe(&obs("c", MethodId::VectorizeLoads, Some(2.0)));
        s.observe(&obs("c", MethodId::DoubleBuffer, None));
        let mut methods = vec![
            MethodId::DoubleBuffer,
            MethodId::TileSmem,
            MethodId::VectorizeLoads,
        ];
        s.rerank(LEGACY_DEVICE, "c", &mut methods);
        assert_eq!(
            methods,
            vec![MethodId::VectorizeLoads, MethodId::TileSmem, MethodId::DoubleBuffer]
        );
    }

    #[test]
    fn rerank_unknown_case_is_noop() {
        let s = SkillStore::new();
        let mut methods = vec![MethodId::TileSmem, MethodId::SplitK];
        s.rerank(LEGACY_DEVICE, "nope", &mut methods);
        assert_eq!(methods, vec![MethodId::TileSmem, MethodId::SplitK]);
    }

    #[test]
    fn rerank_prefers_matching_partition_over_pooled() {
        // On the TPU partition SplitK failed; on the A100 partition it won
        // big. TPU retrieval must rank on its own partition's evidence, and
        // a device with no evidence of its own sees the pooled view at a
        // discount (still positive, so the method rises above untried).
        let mut s = SkillStore::new();
        s.observe(&obs_on("tpu-like", "c", MethodId::SplitK, None));
        for _ in 0..3 {
            s.observe(&obs_on("a100-like", "c", MethodId::SplitK, Some(3.0)));
        }
        assert!(s.rank_score("tpu-like", "c", MethodId::SplitK) < 0.0);
        assert!(s.rank_score("a100-like", "c", MethodId::SplitK) > 0.0);
        // A third device has no partition: pooled fallback, discounted.
        let pooled = s.rank_score("", "c", MethodId::SplitK);
        let other = s.rank_score("h100-like", "c", MethodId::SplitK);
        assert!(other > 0.0 && other < pooled);
        assert_eq!(other, pooled * CROSS_DEVICE_DISCOUNT);
    }

    #[test]
    fn small_samples_shrink_toward_curated_prior() {
        // One observation moves the score far less than its raw mean.
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(3.0)));
        let one = s.rank_score(LEGACY_DEVICE, "c", MethodId::TileSmem);
        assert!(one < 3.0 / 1.0, "shrinkage must pull below the raw mean");
        for _ in 0..9 {
            s.observe(&obs("c", MethodId::TileSmem, Some(3.0)));
        }
        let ten = s.rank_score(LEGACY_DEVICE, "c", MethodId::TileSmem);
        assert!(ten > one, "more evidence must increase the score");
    }

    #[test]
    fn stale_stats_decay_toward_the_prior() {
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(2.0)));
        let fresh = s.rank_score(LEGACY_DEVICE, "c", MethodId::TileSmem);
        for _ in 0..10 {
            s.advance_generation();
        }
        let stale = s.rank_score(LEGACY_DEVICE, "c", MethodId::TileSmem);
        assert!(stale > 0.0 && stale < fresh, "fresh {fresh} stale {stale}");
        let st = s.stat_in(LEGACY_DEVICE, "c", MethodId::TileSmem).unwrap();
        assert!(st.staleness_weight(s.generation) < 1.0);
        assert_eq!(st.staleness_weight(st.last_gen), 1.0);
    }

    #[test]
    fn wilson_bound_is_sane() {
        assert_eq!(wilson_lower_bound(0, 0), 0.0);
        let one = wilson_lower_bound(1, 1);
        let ten = wilson_lower_bound(10, 10);
        assert!(one > 0.0 && one < ten && ten < 1.0);
        assert!(wilson_lower_bound(0, 10) < wilson_lower_bound(5, 10));
    }

    #[test]
    fn json_roundtrip_exact() {
        let mut s = SkillStore::new();
        s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(1.2345678901234)));
        s.observe(&obs("gemm.naive_loop", MethodId::UseTensorCore, None));
        s.observe(&obs_on(
            "tpu-like",
            "fusion.elementwise_chain",
            MethodId::FuseElementwise,
            Some(0.25),
        ));
        s.advance_generation();
        s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(0.5)));
        let j = s.to_json();
        let back = SkillStore::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(s, back);
        assert_eq!(j.to_string(), back.to_json().to_string());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ks-skills-{}", std::process::id()));
        let path = dir.join("skills.json");
        let mut s = SkillStore::new();
        s.observe(&obs("c", MethodId::TileSmem, Some(0.5)));
        s.save(&path).unwrap();
        let back = SkillStore::load(&path).unwrap();
        assert_eq!(s, back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_is_cold() {
        let s = SkillStore::load(Path::new("/nonexistent/skills.json")).unwrap();
        assert!(s.is_empty());
        assert_eq!(s.generation, 0);
    }

    // ---- store-level merge: the sharding contract ----------------------

    /// Gains chosen so naive f64 summation is order-sensitive; exact
    /// accumulation must not be. Spreads observations across two device
    /// partitions so the partitioned merge algebra is exercised too.
    fn shard_store(tag: u64) -> SkillStore {
        let mut s = SkillStore::new();
        let gains = [0.1, 0.2, 1e15, -1e15, 0.30000000000000004, 1e-9];
        for (i, g) in gains.iter().enumerate() {
            let gain = if i as u64 % 3 == tag % 3 { None } else { Some(g * (tag as f64 + 0.5)) };
            let device = if i % 2 == 0 { "a100-like" } else { "tpu-like" };
            s.observe(&obs_on(device, "gemm.naive_loop", MethodId::TileSmem, gain));
            s.observe(&obs_on(device, "fusion.elementwise_chain", MethodId::FuseElementwise, gain));
        }
        s
    }

    /// Serialized bytes, the strongest equality the merge promises.
    fn bytes(s: &SkillStore) -> String {
        s.to_json().to_string()
    }

    #[test]
    fn merge_store_is_commutative_at_byte_level() {
        let (a, b) = (shard_store(0), shard_store(1));
        let mut ab = a.clone();
        ab.merge_store(&b);
        let mut ba = b.clone();
        ba.merge_store(&a);
        assert_eq!(ab, ba);
        assert_eq!(bytes(&ab), bytes(&ba));
    }

    #[test]
    fn merge_store_is_associative_at_byte_level() {
        let (a, b, c) = (shard_store(0), shard_store(1), shard_store(2));
        let mut left = a.clone(); // (a + b) + c
        left.merge_store(&b);
        left.merge_store(&c);
        let mut bc = b.clone();
        bc.merge_store(&c);
        let mut right = a.clone(); // a + (b + c)
        right.merge_store(&bc);
        assert_eq!(left, right);
        assert_eq!(bytes(&left), bytes(&right));
    }

    #[test]
    fn merge_store_empty_is_identity() {
        let a = shard_store(1);
        let mut left = SkillStore::new();
        left.merge_store(&a);
        let mut right = a.clone();
        right.merge_store(&SkillStore::new());
        assert_eq!(left, a);
        assert_eq!(right, a);
        assert_eq!(bytes(&left), bytes(&a));
        assert_eq!(bytes(&right), bytes(&a));
    }

    #[test]
    fn store_fold_matches_observation_fold_in_any_order() {
        // Folding per-shard stores must equal folding the union of raw
        // observations, whatever the interleaving — the invariant `merge`
        // cross-checks between per-shard skills.json files and the
        // checkpointed cells.
        let all: Vec<SkillObs> = (0..3)
            .flat_map(|t| {
                [0.1, 0.7, 1e12, -1e12 + 3.0]
                    .iter()
                    .map(move |g| {
                        obs("reduction.rowwise", MethodId::VectorizeLoads, Some(g * (t + 1) as f64))
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        let mut by_obs = SkillStore::new();
        for o in all.iter().rev() {
            by_obs.observe(o);
        }
        let mut by_stores = SkillStore::new();
        for chunk in all.chunks(4) {
            let mut shard = SkillStore::new();
            shard.merge(chunk);
            by_stores.merge_store(&shard);
        }
        assert_eq!(by_obs, by_stores);
        assert_eq!(bytes(&by_obs), bytes(&by_stores));
    }

    #[test]
    fn generation_merges_by_max_and_stamps_survive() {
        let mut old = SkillStore::new();
        old.observe(&obs("c", MethodId::TileSmem, Some(1.0))); // gen 1
        let mut new = SkillStore::new();
        new.generation = 4;
        new.observe(&obs("c", MethodId::SplitK, Some(1.0))); // stamped 4
        let mut ab = old.clone();
        ab.merge_store(&new);
        let mut ba = new.clone();
        ba.merge_store(&old);
        assert_eq!(ab, ba);
        assert_eq!(ab.generation, 4);
        assert_eq!(ab.stat_in(LEGACY_DEVICE, "c", MethodId::TileSmem).unwrap().last_gen, 1);
        assert_eq!(ab.stat_in(LEGACY_DEVICE, "c", MethodId::SplitK).unwrap().last_gen, 4);
    }

    #[test]
    fn v1_store_without_gain_parts_still_loads() {
        let text = r#"{"version":1,"observations":2,"cases":{"c":{"tile_smem":{"attempts":2,"wins":1,"total_gain":0.75}}}}"#;
        let s = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap();
        let st = s.stat_in(LEGACY_DEVICE, "c", MethodId::TileSmem).unwrap();
        assert_eq!(st.attempts, 2);
        assert_eq!(st.total_gain(), 0.75);
        assert_eq!(st.last_gen, 1, "legacy stats load at generation 1");
        assert_eq!(s.generation, 1);
    }

    #[test]
    fn v2_store_loads_into_legacy_partition() {
        let text = r#"{"version":2,"observations":3,"cases":{"c":{"tile_smem":{"attempts":3,"wins":2,"gain_parts":[1.75],"total_gain":1.75}}}}"#;
        let s = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap();
        let st = s.stat_in(LEGACY_DEVICE, "c", MethodId::TileSmem).unwrap();
        assert_eq!((st.attempts, st.wins), (3, 2));
        assert_eq!(st.total_gain(), 1.75);
        let v4 = s.to_json().to_string();
        assert!(v4.contains("\"version\":4") && v4.contains("\"partitions\""));
        assert!(v4.contains("\"segments\":[]"), "flat form carries an empty segment list");
    }

    #[test]
    fn nonempty_segment_manifest_is_rejected_by_from_json() {
        let text = r#"{"generation":2,"learned":[],"observations":1,"partitions":{},"segments":[{"cases":1,"file":"skills.segments/seg-000001.json","generation":1,"observations":1}],"version":4}"#;
        let err = SkillStore::from_json(&Json::parse(text).unwrap()).unwrap_err();
        assert!(err.contains("SkillStore::load"), "points at the folding loader: {err}");
    }

    #[test]
    fn gc_device_scopes_the_sweep_to_one_partition() {
        let mut s = SkillStore::new();
        s.observe(&obs_on("a100-like", "c", MethodId::TileSmem, Some(1.0)));
        s.observe(&obs_on("tpu-like", "c", MethodId::SplitK, Some(1.0)));
        s.generation = 50;
        let report = s.gc_device(8, Some("tpu-like"));
        assert_eq!(report.dropped_stats, 1);
        assert_eq!(report.dropped_partitions, 1);
        assert!(report.render().contains("partition tpu-like"));
        assert!(
            s.stat_in("a100-like", "c", MethodId::TileSmem).is_some(),
            "other partitions stay byte-untouched"
        );
        assert!(s.stat_in("tpu-like", "c", MethodId::SplitK).is_none());
    }

    // ---- learned decision cases ----------------------------------------

    #[test]
    fn consistent_contradiction_synthesizes_a_promotion() {
        // gemm.exposed_pipeline's curated priority is [DoubleBuffer,
        // VectorizeLoads]; feed the store evidence that VectorizeLoads
        // consistently beats the curated first choice.
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs("gemm.exposed_pipeline", MethodId::VectorizeLoads, Some(2.0)));
            s.observe(&obs("gemm.exposed_pipeline", MethodId::DoubleBuffer, Some(0.05)));
        }
        let learned = s.learned_for(LEGACY_DEVICE, "gemm.exposed_pipeline");
        assert!(
            learned
                .iter()
                .any(|c| c.method == MethodId::VectorizeLoads
                    && c.origin == LearnedOrigin::Promotion),
            "{learned:?}"
        );
    }

    #[test]
    fn promotion_requires_the_first_choice_to_have_been_observed() {
        // VectorizeLoads wins big, but the curated first choice
        // (DoubleBuffer) was never tried in this partition: there is no
        // measured comparison, so no promotion may be synthesized.
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs("gemm.exposed_pipeline", MethodId::VectorizeLoads, Some(2.0)));
        }
        assert!(
            s.learned_for(LEGACY_DEVICE, "gemm.exposed_pipeline").is_empty(),
            "unmeasured first choice must not be 'contradicted'"
        );
    }

    #[test]
    fn learned_for_matches_the_full_synthesis() {
        // The hot-path slice synthesis must agree with the full scan.
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs("gemm.exposed_pipeline", MethodId::VectorizeLoads, Some(2.0)));
            s.observe(&obs("gemm.exposed_pipeline", MethodId::DoubleBuffer, Some(0.05)));
            s.observe(&obs_on("tpu-like", "gemm.naive_loop", MethodId::TileSmem, None));
        }
        let full = s.learned_cases();
        for lc in &full {
            let sliced = s.learned_for(&lc.device, &lc.base_case);
            assert!(sliced.contains(lc), "{lc:?} missing from sliced synthesis");
        }
        let n_sliced: usize = [
            s.learned_for(LEGACY_DEVICE, "gemm.exposed_pipeline").len(),
            s.learned_for("tpu-like", "gemm.naive_loop").len(),
        ]
        .iter()
        .sum();
        assert_eq!(n_sliced, full.len());
    }

    #[test]
    fn consistent_failure_of_first_choice_synthesizes_a_demotion() {
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, None));
        }
        let learned = s.learned_for(LEGACY_DEVICE, "gemm.naive_loop");
        assert!(
            learned
                .iter()
                .any(|c| c.method == MethodId::TileSmem && c.origin == LearnedOrigin::Demotion),
            "{learned:?}"
        );
    }

    #[test]
    fn off_table_winner_synthesizes_an_extension() {
        // KernelFission is not in gemm.naive_loop's curated method set.
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs("gemm.naive_loop", MethodId::KernelFission, Some(1.0)));
        }
        let learned = s.learned_for(LEGACY_DEVICE, "gemm.naive_loop");
        assert!(
            learned
                .iter()
                .any(|c| c.method == MethodId::KernelFission
                    && c.origin == LearnedOrigin::Extension),
            "{learned:?}"
        );
    }

    #[test]
    fn thin_evidence_synthesizes_nothing() {
        let mut s = SkillStore::new();
        s.observe(&obs("gemm.naive_loop", MethodId::VectorizeLoads, Some(10.0)));
        assert!(s.learned_cases().is_empty(), "one lucky obs is not a skill");
    }

    #[test]
    fn learned_cases_are_partition_scoped() {
        let mut s = SkillStore::new();
        for _ in 0..8 {
            s.observe(&obs_on("tpu-like", "gemm.naive_loop", MethodId::TileSmem, None));
        }
        assert!(!s.learned_for("tpu-like", "gemm.naive_loop").is_empty());
        assert!(s.learned_for("a100-like", "gemm.naive_loop").is_empty());
        // Empty device filter sees every partition's learned cases.
        assert!(!s.learned_for("", "gemm.naive_loop").is_empty());
    }

    // ---- gc + inspect ---------------------------------------------------

    #[test]
    fn gc_drops_only_stale_stats() {
        let mut s = SkillStore::new();
        s.observe(&obs("old", MethodId::TileSmem, Some(1.0))); // gen 1
        for _ in 0..5 {
            s.advance_generation();
        }
        s.observe(&obs("fresh", MethodId::SplitK, Some(1.0))); // gen 6
        let report = s.gc(3);
        assert_eq!(report.dropped_stats, 1);
        assert_eq!(report.dropped_cases, 1);
        assert!(s.stat_in(LEGACY_DEVICE, "old", MethodId::TileSmem).is_none());
        assert!(s.stat_in(LEGACY_DEVICE, "fresh", MethodId::SplitK).is_some());
        assert_eq!(s.generation, 6, "gc never rewinds the clock");
        assert!(report.render().contains("dropped 1 stat"));
    }

    #[test]
    fn gc_prunes_emptied_partitions() {
        let mut s = SkillStore::new();
        s.observe(&obs_on("tpu-like", "c", MethodId::TileSmem, Some(1.0)));
        for _ in 0..10 {
            s.advance_generation();
        }
        let report = s.gc(2);
        assert_eq!(report.dropped_partitions, 1);
        assert!(s.is_empty());
    }

    #[test]
    fn inspect_renders_partitions_and_filters() {
        let mut s = SkillStore::new();
        s.observe(&obs("gemm.naive_loop", MethodId::TileSmem, Some(1.0)));
        s.observe(&obs_on("tpu-like", "fusion.elementwise_chain", MethodId::FuseElementwise, None));
        let all = s.render_inspect(None, None);
        assert!(all.contains("partition a100-like"));
        assert!(all.contains("partition tpu-like"));
        assert!(all.contains("tile_smem"));
        let filtered = s.render_inspect(Some("tpu-like"), None);
        assert!(!filtered.contains("tile_smem"));
        assert!(filtered.contains("fuse_elementwise"));
        let missing = s.render_inspect(Some("h100-like"), None);
        assert!(missing.contains("no partition"));
        assert!(SkillStore::new().render_inspect(None, None).contains("no recorded stats"));
    }
}
