//! Short-term *optimization* memory (§4.2.2, Figure 3).
//!
//! Tracks every optimization method applied to the **current base kernel**
//! with its observed outcome, and implements the base-promotion policy of
//! Algorithm 1: a new kernel becomes the base only on >= rt relative or
//! >= at absolute speedup gain. The Planner is conditioned on this record,
//! so unproductive methods are not re-attempted against the same base.

use crate::kir::transforms::MethodId;

/// Outcome of one optimization round against a base kernel.
#[derive(Debug, Clone)]
pub struct OptAttempt {
    /// Method the Planner selected for the round.
    pub method: MethodId,
    /// Speedup (vs eager) the resulting kernel achieved; None = the round
    /// ended in an unrepaired failure.
    pub speedup: Option<f64>,
    /// Did this attempt get promoted to the new base?
    pub promoted: bool,
    /// Round number the attempt happened in.
    pub round: u32,
}

/// Per-task optimization memory.
#[derive(Debug, Clone)]
pub struct OptMemory {
    /// Relative promotion threshold (paper: rt = 0.3).
    pub rt: f64,
    /// Absolute promotion threshold (paper: at = 0.3).
    pub at: f64,
    /// Version of the current base kernel.
    pub base_version: u32,
    /// Speedup of the current base kernel.
    pub base_speedup: f64,
    /// Attempts made against the current base (cleared on promotion).
    pub attempts_on_base: Vec<OptAttempt>,
    /// Full history across bases (for trace rendering / Figure 3).
    pub history: Vec<OptAttempt>,
    /// Promotion events: (round, old base version, new base version).
    pub promotions: Vec<(u32, u32, u32)>,
}

impl OptMemory {
    /// Fresh per-task memory with the selected seed as base kernel #0.
    pub fn new(rt: f64, at: f64, seed_speedup: f64) -> Self {
        OptMemory {
            rt,
            at,
            base_version: 0,
            base_speedup: seed_speedup,
            attempts_on_base: Vec::new(),
            history: Vec::new(),
            promotions: Vec::new(),
        }
    }

    /// Algorithm 1's promotion test.
    pub fn should_promote(&self, speedup: f64) -> bool {
        speedup / self.base_speedup > 1.0 + self.rt || speedup - self.base_speedup > self.at
    }

    /// Record a completed round; promotes the base when thresholds pass.
    /// Returns whether promotion happened.
    pub fn record(
        &mut self,
        method: MethodId,
        speedup: Option<f64>,
        round: u32,
        kernel_version: u32,
    ) -> bool {
        let promoted = speedup.map(|s| self.should_promote(s)).unwrap_or(false);
        let attempt = OptAttempt {
            method,
            speedup,
            promoted,
            round,
        };
        self.history.push(attempt.clone());
        if promoted {
            self.promotions
                .push((round, self.base_version, kernel_version));
            self.base_version = kernel_version;
            self.base_speedup = speedup.unwrap();
            self.attempts_on_base.clear();
        } else {
            self.attempts_on_base.push(attempt);
        }
        promoted
    }

    /// Methods already tried on the current base that did NOT promote —
    /// what the Planner must deprioritize (Figure 3's conditioning).
    pub fn unproductive_on_base(&self) -> Vec<MethodId> {
        self.attempts_on_base.iter().map(|a| a.method).collect()
    }

    /// Has `method` failed on the current base already?
    pub fn tried_on_base(&self, method: MethodId) -> bool {
        self.attempts_on_base.iter().any(|a| a.method == method)
    }

    /// Render the Figure-3 style state.
    pub fn render(&self) -> String {
        let mut s = format!(
            "base #{} at {:.3}x; tried on base: [{}]",
            self.base_version,
            self.base_speedup,
            self.attempts_on_base
                .iter()
                .map(|a| format!(
                    "{}:{}",
                    a.method.name(),
                    a.speedup.map(|x| format!("{x:.2}x")).unwrap_or("fail".into())
                ))
                .collect::<Vec<_>>()
                .join(", ")
        );
        if !self.promotions.is_empty() {
            s.push_str(&format!("; promotions: {:?}", self.promotions));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_threshold_promotes() {
        let mut m = OptMemory::new(0.3, 0.3, 1.0);
        assert!(!m.record(MethodId::UnrollInner, Some(1.1), 1, 5)); // +10% < 30%
        assert!(m.record(MethodId::TileSmem, Some(1.5), 2, 6)); // +50%
        assert_eq!(m.base_version, 6);
        assert_eq!(m.base_speedup, 1.5);
        assert!(m.attempts_on_base.is_empty(), "promotion clears base attempts");
    }

    #[test]
    fn absolute_threshold_promotes() {
        // 0.1x -> 0.45x is only +0.35 absolute but 4.5x relative;
        // 2.0 -> 2.35 is +0.35 absolute (> at) though only +17.5% relative.
        let mut m = OptMemory::new(0.3, 0.3, 2.0);
        assert!(m.record(MethodId::DoubleBuffer, Some(2.35), 1, 3));
    }

    #[test]
    fn small_fluctuations_do_not_move_base() {
        let mut m = OptMemory::new(0.3, 0.3, 2.0);
        assert!(!m.record(MethodId::LaunchTune, Some(2.1), 1, 3));
        assert_eq!(m.base_version, 0);
        assert_eq!(m.unproductive_on_base(), vec![MethodId::LaunchTune]);
        assert!(m.tried_on_base(MethodId::LaunchTune));
        assert!(!m.tried_on_base(MethodId::TileSmem));
    }

    #[test]
    fn failures_recorded_as_unproductive() {
        let mut m = OptMemory::new(0.3, 0.3, 1.0);
        assert!(!m.record(MethodId::SplitK, None, 1, 2));
        assert!(m.tried_on_base(MethodId::SplitK));
        assert_eq!(m.history.len(), 1);
    }

    #[test]
    fn render_mentions_base_and_attempts() {
        let mut m = OptMemory::new(0.3, 0.3, 1.0);
        m.record(MethodId::UnrollInner, Some(1.05), 1, 2);
        let s = m.render();
        assert!(s.contains("base #0"));
        assert!(s.contains("unroll_inner:1.05x"));
    }
}
