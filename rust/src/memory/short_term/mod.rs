//! Short-term memory: per-task trajectory state (§4.2.2) — repair chains
//! (Figure 2) and optimization rounds with base-kernel promotion (Figure 3).

pub mod opt_memory;
pub mod repair_memory;

pub use opt_memory::OptMemory;
pub use repair_memory::{RepairAttempt, RepairChain, RepairMemory};
