//! Short-term *repair* memory (§4.2.2, Figure 2).
//!
//! Each chain starts at the first kernel version that fails compilation or
//! verification and accumulates every repair attempt with its outcome. The
//! Diagnoser is conditioned on the whole chain, so it never re-proposes a
//! fix already observed to fail on the same error signature — the mechanism
//! that breaks the cyclic-repair oscillation.

/// One recorded repair attempt.
#[derive(Debug, Clone)]
pub struct RepairAttempt {
    /// Error signature the attempt was responding to.
    pub error_signature: String,
    /// Candidate-fix index the Diagnoser proposed.
    pub fix_idx: u8,
    /// Did the fix clear the fault?
    pub fixed: bool,
    /// Kernel version the Repairer produced.
    pub kernel_version: u32,
    /// Round number (for trace rendering).
    pub round: u32,
}

/// A chain of repair attempts on one broken lineage (Figure 2).
#[derive(Debug, Clone, Default)]
pub struct RepairChain {
    /// Attempts in chain order, outcomes included.
    pub attempts: Vec<RepairAttempt>,
    /// Version of the kernel that first broke (chain root).
    pub root_version: u32,
}

/// The per-task repair memory: the active chain plus closed history.
#[derive(Debug, Clone, Default)]
pub struct RepairMemory {
    /// Chain currently being repaired, if any.
    pub active: Option<RepairChain>,
    /// Chains that ended (repair succeeded or the lineage was abandoned).
    pub closed: Vec<RepairChain>,
}

impl RepairMemory {
    /// Fresh per-task memory with no chains.
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a chain at the first failure of a lineage (no-op if one is open).
    pub fn open_chain(&mut self, root_version: u32) {
        if self.active.is_none() {
            self.active = Some(RepairChain {
                attempts: Vec::new(),
                root_version,
            });
        }
    }

    /// Record an attempt into the active chain.
    pub fn record(&mut self, attempt: RepairAttempt) {
        if self.active.is_none() {
            self.open_chain(attempt.kernel_version);
        }
        self.active.as_mut().unwrap().attempts.push(attempt);
    }

    /// Close the active chain (repair succeeded or budget exhausted).
    pub fn close_chain(&mut self) {
        if let Some(chain) = self.active.take() {
            self.closed.push(chain);
        }
    }

    /// Fix indices already tried *and failed* for this error signature in
    /// the active chain — what the Diagnoser must not repeat.
    pub fn failed_fixes_for(&self, error_signature: &str) -> Vec<u8> {
        self.active
            .as_ref()
            .map(|c| {
                c.attempts
                    .iter()
                    .filter(|a| !a.fixed && a.error_signature == error_signature)
                    .map(|a| a.fix_idx)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Total repair attempts across all chains (trace statistic).
    pub fn total_attempts(&self) -> usize {
        self.closed.iter().map(|c| c.attempts.len()).sum::<usize>()
            + self.active.as_ref().map(|c| c.attempts.len()).unwrap_or(0)
    }

    /// Length of the longest chain (Figure-2 style statistic).
    pub fn longest_chain(&self) -> usize {
        self.closed
            .iter()
            .chain(self.active.iter())
            .map(|c| c.attempts.len())
            .max()
            .unwrap_or(0)
    }

    /// Render the active chain like Figure 2 (kernel #2 -> #3 -> ...).
    pub fn render_active(&self) -> String {
        match &self.active {
            None => "<no active repair chain>".to_string(),
            Some(c) => {
                let mut s = format!("chain from kernel #{}:", c.root_version);
                for a in &c.attempts {
                    s.push_str(&format!(
                        " -> #{} (fix {} on '{}': {})",
                        a.kernel_version,
                        a.fix_idx,
                        truncate(&a.error_signature, 28),
                        if a.fixed { "fixed" } else { "still broken" }
                    ));
                }
                s
            }
        }
    }
}

fn truncate(s: &str, n: usize) -> &str {
    if s.len() <= n {
        s
    } else {
        &s[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attempt(sig: &str, fix: u8, fixed: bool, v: u32) -> RepairAttempt {
        RepairAttempt {
            error_signature: sig.to_string(),
            fix_idx: fix,
            fixed,
            kernel_version: v,
            round: v,
        }
    }

    #[test]
    fn failed_fixes_accumulate_per_signature() {
        let mut m = RepairMemory::new();
        m.open_chain(2);
        m.record(attempt("sync missing", 0, false, 3));
        m.record(attempt("sync missing", 2, false, 4));
        m.record(attempt("other error", 1, false, 5));
        assert_eq!(m.failed_fixes_for("sync missing"), vec![0, 2]);
        assert_eq!(m.failed_fixes_for("other error"), vec![1]);
        assert!(m.failed_fixes_for("fresh").is_empty());
    }

    #[test]
    fn closing_resets_the_no_repeat_set() {
        let mut m = RepairMemory::new();
        m.open_chain(1);
        m.record(attempt("e", 0, false, 2));
        m.close_chain();
        assert!(m.failed_fixes_for("e").is_empty());
        assert_eq!(m.closed.len(), 1);
        assert_eq!(m.total_attempts(), 1);
    }

    #[test]
    fn successful_fix_recorded_but_not_blocked() {
        let mut m = RepairMemory::new();
        m.record(attempt("e", 1, true, 3));
        assert!(m.failed_fixes_for("e").is_empty());
        assert_eq!(m.total_attempts(), 1);
    }

    #[test]
    fn figure2_render() {
        let mut m = RepairMemory::new();
        m.open_chain(2);
        m.record(attempt("ptxas error: too much shared data", 0, false, 3));
        m.record(attempt("ptxas error: too much shared data", 1, true, 4));
        let s = m.render_active();
        assert!(s.contains("chain from kernel #2"));
        assert!(s.contains("fixed"));
    }

    #[test]
    fn longest_chain_tracks_max() {
        let mut m = RepairMemory::new();
        m.open_chain(1);
        for i in 0..4 {
            m.record(attempt("e", i, false, i as u32 + 2));
        }
        m.close_chain();
        m.open_chain(9);
        m.record(attempt("e2", 0, true, 10));
        m.close_chain();
        assert_eq!(m.longest_chain(), 4);
    }
}
