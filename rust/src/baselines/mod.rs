//! Strategy definitions: KernelSkill, its three ablations (Table 2), and the
//! six published baselines (Table 1/3), all expressed over the same loop
//! substrate (DESIGN.md §Baselines).
//!
//! A [`Strategy`] bundles: the selection mode (where the systems genuinely
//! differ), which memories are enabled, the refinement budget, and the
//! surrogate policy profile. `run_task` (coordinator) interprets it.

use crate::agents::policy::{PolicyProfile, SelectionMode};
use crate::kir::transforms::MethodId;

#[derive(Debug, Clone)]
pub struct Strategy {
    pub name: &'static str,
    /// Max refinement rounds N (paper: 15; STARK: 30).
    pub rounds: u32,
    /// Seed kernels sampled by the Generator (paper: 3).
    pub n_seeds: usize,
    pub use_long_term: bool,
    pub use_short_term_opt: bool,
    pub use_short_term_repair: bool,
    pub policy: PolicyProfile,
    pub selection: SelectionMode,
}

/// KernelSkill as configured in §5.3: ChatGPT-5.1, 3 seeds, 15 rounds,
/// rt = at = 0.3, both memories.
pub fn kernelskill() -> Strategy {
    Strategy {
        name: "KernelSkill",
        rounds: 15,
        n_seeds: 3,
        use_long_term: true,
        use_short_term_opt: true,
        use_short_term_repair: true,
        policy: PolicyProfile::chatgpt51(),
        selection: SelectionMode::DecisionPolicy,
    }
}

/// Table-2 ablation: no memory at all (free choice, no trajectory state).
pub fn wo_memory() -> Strategy {
    Strategy {
        name: "w/o memory",
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        selection: SelectionMode::FreeChoice,
        ..kernelskill()
    }
}

/// Table-2 ablation: long-term memory only.
pub fn wo_short_term() -> Strategy {
    Strategy {
        name: "w/o Short_term memory",
        use_short_term_opt: false,
        use_short_term_repair: false,
        ..kernelskill()
    }
}

/// Table-2 ablation: short-term memory only.
pub fn wo_long_term() -> Strategy {
    Strategy {
        name: "w/o Long_term memory",
        use_long_term: false,
        selection: SelectionMode::FreeChoice,
        ..kernelskill()
    }
}

/// Kevin-32B: multi-turn-RL-trained model. Selection is a learned, fixed
/// preference ordering (no profiling conditioning); weaker coding/repair;
/// shorter effective budget (the trained policy plateaus).
pub fn kevin() -> Strategy {
    Strategy {
        name: "Kevin-32B",
        rounds: 12,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        policy: PolicyProfile::trained_32b(),
        selection: SelectionMode::FixedOrdering(vec![
            MethodId::FuseElementwise,
            MethodId::TileSmem,
            MethodId::VectorizeLoads,
            MethodId::CoalesceAccesses,
            MethodId::FuseEpilogueReduction,
            MethodId::UnrollInner,
            MethodId::DoubleBuffer,
            MethodId::LaunchTune,
            MethodId::HorizontalFuse,
        ]),
    }
}

/// QiMeng: macro-thinking / micro-coding. A static macro plan per task
/// category, executed stepwise; competent coder.
pub fn qimeng() -> Strategy {
    Strategy {
        name: "QiMeng",
        rounds: 15,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        policy: PolicyProfile {
            coding_skill: 0.78,
            repair_skill: 0.7,
            feature_accuracy: 0.85,
            fusion_bias: 0.3,
            hint_following: 0.1,
            planning_skill: 0.4,
        },
        selection: SelectionMode::MacroPlan,
    }
}

/// CudaForge: training-free Coder-Judge with NCU/GPU-spec feedback.
pub fn cudaforge() -> Strategy {
    Strategy {
        name: "CudaForge",
        rounds: 15,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        policy: PolicyProfile {
            hint_following: 0.45,
            ..PolicyProfile::chatgpt51()
        },
        selection: SelectionMode::JudgeHints,
    }
}

/// Astra: multi-agent roles, implicit method selection, no memory.
pub fn astra() -> Strategy {
    Strategy {
        name: "Astra",
        rounds: 15,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        policy: PolicyProfile {
            fusion_bias: 0.55,
            hint_following: 0.4,
            planning_skill: 0.12,
            ..PolicyProfile::chatgpt51()
        },
        selection: SelectionMode::FreeChoice,
    }
}

/// PRAGMA: profiling-reasoned bottleneck->action mapping, flat rules.
pub fn pragma() -> Strategy {
    Strategy {
        name: "PRAGMA",
        rounds: 15,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: false,
        use_short_term_repair: false,
        policy: PolicyProfile::chatgpt51(),
        selection: SelectionMode::FlatRules,
    }
}

/// STARK: strategic search + grounded instruction + within-task memory,
/// 30 refinement rounds (its published budget).
pub fn stark() -> Strategy {
    Strategy {
        name: "STARK",
        rounds: 30,
        n_seeds: 3,
        use_long_term: false,
        use_short_term_opt: true,
        use_short_term_repair: true,
        policy: PolicyProfile {
            planning_skill: 0.45,
            fusion_bias: 0.2,
            hint_following: 0.15,
            ..PolicyProfile::chatgpt51()
        },
        selection: SelectionMode::StrategicSearch,
    }
}

/// The Table-1/3 roster, paper order.
pub fn table1_roster() -> Vec<Strategy> {
    vec![
        kevin(),
        astra(),
        pragma(),
        cudaforge(),
        qimeng(),
        stark(),
        kernelskill(),
    ]
}

/// The Table-2 roster.
pub fn table2_roster() -> Vec<Strategy> {
    vec![wo_memory(), wo_short_term(), wo_long_term(), kernelskill()]
}

/// Resolve any roster strategy by (case-insensitive) name — shared by the
/// CLI and by checkpoint readers rebuilding tables from streamed results.
pub fn by_name(name: &str) -> Option<Strategy> {
    table1_roster()
        .into_iter()
        .chain(table2_roster())
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rosters_have_unique_names() {
        let mut names: Vec<&str> = table1_roster()
            .iter()
            .chain(table2_roster().iter())
            .map(|s| s.name)
            .collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before - 1, "only KernelSkill appears twice");
    }

    #[test]
    fn only_stark_gets_30_rounds() {
        for s in table1_roster() {
            if s.name == "STARK" {
                assert_eq!(s.rounds, 30);
            } else {
                assert!(s.rounds <= 15);
            }
        }
    }

    #[test]
    fn ablations_toggle_exactly_the_memories() {
        let full = kernelskill();
        let wo_st = wo_short_term();
        assert_eq!(wo_st.use_long_term, true);
        assert_eq!(wo_st.use_short_term_opt, false);
        let wo_lt = wo_long_term();
        assert_eq!(wo_lt.use_long_term, false);
        assert_eq!(wo_lt.use_short_term_opt, true);
        assert_eq!(full.use_long_term && full.use_short_term_opt, true);
    }
}
