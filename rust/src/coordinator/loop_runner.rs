//! Algorithm 1: the closed-loop multi-agent refinement for one task.
//!
//! Two-branch control flow per round: a broken kernel goes to the
//! Diagnoser/Repairer (conditioned on short-term repair memory); a healthy
//! one goes through Feature Extractor -> Retrieval -> Planner -> Optimizer
//! (conditioned on long-term memory + short-term optimization memory).
//! Base-kernel promotion follows the rt/at thresholds.

use crate::agents::{
    diagnoser, feature_extractor, generator, optimizer, planner, repairer, reviewer, KernelState,
};
use crate::baselines::Strategy;
use crate::bench_suite::Task;
use crate::device::faults::{ChaosConfig, Fault};
use crate::device::machine::DeviceSpec;
use crate::device::metrics::ToolVersion;
use crate::kir::schedule::Schedule;
use crate::kir::transforms::{self, MethodId, ALL_METHODS};
use crate::memory::long_term::retrieval;
use crate::memory::long_term::{SkillObs, SkillStore};
use crate::memory::short_term::{OptMemory, RepairAttempt, RepairMemory};
use crate::util::rng::{derive_seed, label, Rng};

/// Which branch a round took.
#[derive(Debug, Clone, PartialEq)]
pub enum Branch {
    /// Optimization round with the method chosen.
    Optimize(MethodId),
    /// Repair round with the candidate-fix index.
    Repair(u8),
    /// The optimizer produced a structurally illegal schedule and the agent
    /// reverted the edit.
    Revert,
    /// No plan available (converged / nothing applicable).
    Converged,
}

/// Per-round trace record (feeds Figures 2-3 and the trajectory bench).
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    /// Round number (1-based).
    pub round: u32,
    /// Which branch the round took and with what choice.
    pub branch: Branch,
    /// Did the round's candidate compile?
    pub compiled: bool,
    /// Did the round's candidate verify?
    pub correct: bool,
    /// Measured speedup of the candidate, when it ran.
    pub speedup: Option<f64>,
    /// Kernel version the round produced (or re-reported).
    pub version: u32,
}

/// Outcome of one task run.
#[derive(Debug, Clone)]
pub struct TaskResult {
    /// Task the run was about.
    pub task_id: String,
    /// KernelBenchSim level of the task.
    pub level: u8,
    /// Strategy name the run used.
    pub strategy: &'static str,
    /// A compiling + verifying kernel was produced within budget.
    pub success: bool,
    /// Best speedup over Torch Eager (0.0 on failure, per the paper's
    /// aggregate accounting).
    pub best_speedup: f64,
    /// Speedup of the selected seed (None if no seed verified).
    pub seed_speedup: Option<f64>,
    /// Rounds actually consumed (<= the strategy budget).
    pub rounds_used: u32,
    /// Full per-round trace.
    pub rounds: Vec<RoundRecord>,
    /// Base-kernel promotions that happened.
    pub promotions: u32,
    /// Total repair attempts across all chains.
    pub repair_attempts: usize,
    /// Length of the longest repair chain (Figure-2 statistic).
    pub longest_repair_chain: usize,
    /// The winning schedule (artifact verification / e2e replay).
    pub best_sched: Schedule,
    /// Skill observations harvested this run (matched decision-table case,
    /// method tried, measured gain). The suite orchestrator folds these
    /// into the persistent long-term skill store.
    pub skill_obs: Vec<SkillObs>,
}

/// Loop configuration shared across a suite run.
#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Relative base-promotion threshold (paper: 0.3).
    pub rt: f64,
    /// Absolute base-promotion threshold (paper: 0.3).
    pub at: f64,
    /// Device preset priced by the cost model; its `name` also keys the
    /// skill-store partition observations land in.
    pub dev: DeviceSpec,
    /// Profiling-tool naming era the synthesized profiles emulate.
    pub tool: ToolVersion,
    /// Experiment-level seed; per-task streams derive from it.
    pub run_seed: u64,
    /// Warm-start snapshot of the persistent long-term skill store. When
    /// set, retrieval reranks allowed methods by persisted observations.
    /// The snapshot is read-only for the whole run, which keeps task runs
    /// order-independent (parallel == serial, resume == uninterrupted).
    pub skills: Option<std::sync::Arc<SkillStore>>,
    /// Directory holding the live skill store (`skills.json`). `run_task`
    /// loads a snapshot from here when `skills` is unset; *writing* the
    /// store back is the suite orchestrator's job (see
    /// `coordinator::scheduler`).
    pub memory_dir: Option<std::path::PathBuf>,
    /// Memoize skill-layer retrieval lookups across the rounds of one task
    /// run (see [`retrieval::RetrievalCache`]). Byte-identical output
    /// either way — the cache exists purely to keep repeat store walks out
    /// of the per-round hot path; `--no-retrieval-cache` turns it off for
    /// A/B runs.
    pub retrieval_cache: bool,
    /// Environment-fault chaos layer (`--chaos`). When set, a *separate*
    /// deterministic RNG stream — derived per (chaos seed, run seed,
    /// strategy, task) — injects transient compile failures into fresh
    /// candidates and corrupts what the Reviewer measures (see
    /// [`reviewer::review_chaotic`]). The cell's own stream is untouched,
    /// so a chaos config with every knob at zero is byte-identical to no
    /// chaos, and chaotic runs shard/merge/resume exactly like clean ones.
    pub chaos: Option<ChaosConfig>,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            rt: 0.3,
            at: 0.3,
            dev: DeviceSpec::a100_like(),
            tool: ToolVersion::Ncu2023,
            run_seed: 0,
            skills: None,
            memory_dir: None,
            retrieval_cache: true,
            chaos: None,
        }
    }
}

/// Run Algorithm 1 on one task under one strategy.
pub fn run_task(task: &Task, strategy: &Strategy, cfg: &LoopConfig) -> TaskResult {
    let mut rng = Rng::new(derive_seed(
        cfg.run_seed,
        &[label(strategy.name), label(&task.id)],
    ));
    // Chaos stream: derived per (chaos seed, run seed, strategy, task) and
    // kept entirely separate from the cell stream above. Per-cell derivation
    // means sharding and resume never change which chaos draws a cell sees.
    let mut chaos_rng = cfg.chaos.as_ref().map(|c| {
        Rng::new(derive_seed(
            c.seed,
            &[label("chaos"), cfg.run_seed, label(strategy.name), label(&task.id)],
        ))
    });

    // Whether this run's agent stack *notices* exploitable operand
    // structure at all. Noticing is a property of the whole run (a blind
    // model stays blind across rounds): KernelSkill is prompted to look by
    // the long-term memory's feature definition (feature 19); strategies
    // with strategic grounding or macro planning sometimes see it; plain
    // free choice rarely; fixed/judge/rule pipelines never — structure is
    // simply not in their repertoire.
    let notices_structure = task.graph.structured_operands && {
        use crate::agents::policy::SelectionMode::*;
        let p = match strategy.selection {
            DecisionPolicy => {
                if strategy.use_long_term {
                    strategy.policy.feature_accuracy
                } else {
                    strategy.policy.planning_skill * 0.6
                }
            }
            StrategicSearch => 0.42,
            MacroPlan => 0.35,
            FreeChoice => strategy.policy.planning_skill * 0.6,
            FixedOrdering(_) | JudgeHints | FlatRules => 0.0,
        };
        rng.chance(p)
    };
    // Per-run judgment draw (see PlanContext::insightful).
    let insightful = rng.chance(strategy.policy.planning_skill);
    // §Perf opts 3-4: eager latency and the custom floor are task
    // constants; price them once.
    let consts = Some((
        crate::bench_suite::eager::eager_time_s(task, &cfg.dev),
        crate::bench_suite::eager::custom_floor_s(task, &cfg.dev),
    ));

    // Warm-start snapshot of the persistent skill store (long-term-memory
    // strategies only). The snapshot is immutable for the whole run, which
    // keeps task runs order-independent: parallel == serial and a resumed
    // suite reproduces an uninterrupted one.
    let skills: Option<std::sync::Arc<SkillStore>> = if strategy.use_long_term {
        cfg.skills.clone().or_else(|| {
            cfg.memory_dir.as_ref().map(|d| {
                std::sync::Arc::new(SkillStore::load(&d.join("skills.json")).unwrap_or_default())
            })
        })
    } else {
        None
    };
    let mut skill_obs: Vec<SkillObs> = Vec::new();

    // ---- Seed generation + selection (Generator + Reviewer) ----
    let mut seeds = generator::generate_seeds(task, strategy.n_seeds, &strategy.policy, &mut rng);
    // Chaos: a transient toolchain failure can hit any fresh candidate —
    // same injection idiom as the Generator's own seed faults, but
    // single-fix and retry-clearable, so the repair branch shrugs it off.
    if let (Some(c), Some(crng)) = (cfg.chaos.as_ref(), chaos_rng.as_mut()) {
        if c.transient_compile_p > 0.0 {
            for seed in seeds.iter_mut() {
                if crng.chance(c.transient_compile_p) {
                    seed.faults.push(Fault::transient(MethodId::LaunchTune));
                }
            }
        }
    }
    let mut version_counter = seeds.len() as u32;
    let mut best: Option<(f64, Schedule)> = None;
    let mut base: Option<(KernelState, reviewer::Review)> = None;
    let mut current: Option<KernelState> = None;
    let mut seed_speedup = None;

    for seed in &seeds {
        let review = reviewer::review_chaotic(
            task,
            seed,
            &cfg.dev,
            cfg.tool,
            &mut rng,
            consts,
            cfg.chaos.as_ref().zip(chaos_rng.as_mut()),
        );
        if review.ok() {
            let sp = review.speedup.unwrap();
            if seed_speedup.map(|s| sp > s).unwrap_or(true) {
                seed_speedup = Some(sp);
                best = Some((sp, seed.sched.clone()));
                base = Some((seed.clone(), review));
            }
        } else if current.is_none() {
            current = Some(seed.clone());
        }
    }
    // Healthy seed wins the "current" slot; else start broken.
    if base.is_some() {
        current = None;
    }

    // Without short-term memory there is no reliable record of which
    // version was best: the pipeline delivers its LATEST working kernel.
    // Only memory-less strategies ever read it, so only they pay the
    // per-round schedule clone that keeps it current.
    let track_latest = !strategy.use_short_term_opt;
    let mut latest_valid: Option<(f64, Schedule)> = if track_latest { best.clone() } else { None };
    let mut opt_mem = OptMemory::new(cfg.rt, cfg.at, seed_speedup.unwrap_or(0.0));
    let mut repair_mem = RepairMemory::new();
    let mut rounds = Vec::new();
    let mut promotions = 0u32;
    // Method that produced the currently-broken candidate (for post-repair
    // bookkeeping in the optimization memory).
    let mut pending_method: Option<MethodId> = None;
    let mut last_method: Option<MethodId> = None;
    let mut rounds_used = 0;
    // The strategy-adjusted repair policy is round-invariant; built on the
    // first repair round actually taken, reused afterwards.
    let mut repair_policy: Option<crate::agents::policy::PolicyProfile> = None;
    // Skill-layer retrieval memo, valid for this run's immutable store
    // snapshot (one per task run; see `RetrievalCache`).
    let mut retrieval_cache = cfg.retrieval_cache.then(retrieval::RetrievalCache::new);
    // The per-round child-stream label is a compile-time constant; hash it
    // once instead of re-running FNV over "round" every round.
    let round_label = label("round");

    for round in 1..=strategy.rounds {
        rounds_used = round;
        let mut round_rng = rng.child_with(round_label);

        if let Some(broken) = current.take() {
            // ---------------- Repair branch ----------------
            if strategy.use_short_term_repair {
                repair_mem.open_chain(broken.version);
            }
            let fault = broken
                .compile_fault()
                .or_else(|| broken.runtime_fault())
                .cloned();

            let (state, record) = match fault {
                Some(fault) => {
                    let mem = strategy.use_short_term_repair.then_some(&repair_mem);
                    let plan =
                        diagnoser::diagnose(&fault, mem, &strategy.policy, &mut round_rng);
                    version_counter += 1;
                    // A history-conditioned repair plan avoids re-breaking
                    // what previous fixes touched (fewer regressions).
                    let repair_policy = repair_policy.get_or_insert_with(|| {
                        let mut p = strategy.policy.clone();
                        if strategy.use_short_term_repair {
                            p.repair_skill = (p.repair_skill + 0.25).min(1.0);
                        }
                        p
                    });
                    let result = repairer::execute(
                        &broken,
                        &plan,
                        repair_policy,
                        version_counter,
                        &mut round_rng,
                    );
                    let fix_idx = plan.fix_idx;
                    repair_mem.record(RepairAttempt {
                        error_signature: plan.error_signature,
                        fix_idx,
                        fixed: result.fixed,
                        kernel_version: version_counter,
                        round,
                    });
                    (result.state, Branch::Repair(fix_idx))
                }
                None => {
                    // Structural legality failure without an injected fault:
                    // the agent reverts the offending edit (back to base or
                    // the seed schedule).
                    version_counter += 1;
                    let sched = base
                        .as_ref()
                        .map(|(b, _)| b.sched.clone())
                        .unwrap_or_else(|| Schedule::per_op_naive(&task.graph));
                    (KernelState::new(sched, version_counter), Branch::Revert)
                }
            };

            let review = reviewer::review_chaotic(
                task,
                &state,
                &cfg.dev,
                cfg.tool,
                &mut round_rng,
                consts,
                cfg.chaos.as_ref().zip(chaos_rng.as_mut()),
            );
            rounds.push(RoundRecord {
                round,
                branch: record,
                compiled: review.compiles,
                correct: review.correct,
                speedup: review.speedup,
                version: state.version,
            });
            if review.ok() {
                repair_mem.close_chain();
                let sp = review.speedup.unwrap();
                if track_latest {
                    latest_valid = Some((sp, state.sched.clone()));
                }
                if best.as_ref().map(|(b, _)| sp > *b).unwrap_or(true) {
                    best = Some((sp, state.sched.clone()));
                }
                // The repaired kernel is this lineage's measurement; apply
                // the promotion rule for the method that spawned it.
                let method = pending_method.take().unwrap_or(MethodId::LaunchTune);
                if strategy.use_short_term_opt {
                    let promoted = opt_mem.record(method, Some(sp), round, state.version);
                    if promoted || base.is_none() {
                        if promoted {
                            promotions += 1;
                        }
                        base = Some((state, review));
                    }
                } else {
                    // No trajectory memory: the agent iterates on its
                    // latest working kernel, wherever that drifted (§4.2's
                    // oscillation failure mode). Best-so-far is still
                    // reported, but refinement builds on `state`.
                    opt_mem.base_speedup = sp;
                    promotions += 1;
                    base = Some((state, review));
                }
                // current stays None: next round optimizes from base.
            } else {
                current = Some(state);
            }
            continue;
        }

        // ---------------- Optimization branch ----------------
        let Some((base_state, base_review)) = base.as_ref() else {
            // No healthy kernel and nothing to repair: cannot proceed.
            rounds.push(RoundRecord {
                round,
                branch: Branch::Converged,
                compiled: false,
                correct: false,
                speedup: None,
                version: version_counter,
            });
            break;
        };

        let hot_group = base_review.hot_group.min(base_state.sched.num_kernels() - 1);
        let applicable: Vec<MethodId> = ALL_METHODS
            .iter()
            .copied()
            .filter(|m| {
                (notices_structure || *m != MethodId::SpecializeStructure)
                    && transforms::applicable_at(*m, &task.graph, &base_state.sched, hot_group)
                        .is_ok()
            })
            .collect();

        let mut features = feature_extractor::extract(
            &task.graph,
            &base_state.sched,
            hot_group,
            &strategy.policy,
            &mut round_rng,
        );
        if !notices_structure {
            features.structured_operand = false;
        }
        // A healthy base review carries a profile by construction, but a
        // panic here would take down every cell of a launched shard with
        // it; degrade to convergence instead of aborting the fleet.
        let Some(profile) = base_review.profile.as_ref() else {
            crate::log_warn!(
                "task {}: healthy base kernel has no profile; stopping refinement",
                task.id
            );
            rounds.push(RoundRecord {
                round,
                branch: Branch::Converged,
                compiled: true,
                correct: true,
                speedup: base_review.speedup,
                version: base_state.version,
            });
            break;
        };
        let retrieval_result = strategy.use_long_term.then(|| {
            retrieval::retrieve_for_with_cache(
                task,
                &features,
                profile,
                skills.as_deref(),
                cfg.dev.name,
                retrieval_cache.as_mut(),
            )
        });

        let ctx = planner::PlanContext {
            applicable: &applicable,
            retrieval: retrieval_result.as_ref(),
            opt_memory: strategy.use_short_term_opt.then_some(&opt_mem),
            features: &features,
            profile,
            last_method,
            rounds_done: round - 1,
            insightful,
        };
        let Some(plan) = planner::plan(&strategy.selection, &ctx, &strategy.policy, &mut round_rng)
        else {
            rounds.push(RoundRecord {
                round,
                branch: Branch::Converged,
                compiled: true,
                correct: true,
                speedup: base_review.speedup,
                version: base_state.version,
            });
            // Deterministic selectors that found nothing will find nothing
            // next round either; chance-based ones may (different draw).
            if matches!(
                strategy.selection,
                crate::agents::policy::SelectionMode::DecisionPolicy
                    | crate::agents::policy::SelectionMode::FixedOrdering(_)
            ) {
                break;
            }
            last_method = None;
            continue;
        };
        last_method = Some(plan.method);

        version_counter += 1;
        let mut candidate = optimizer::execute(
            task,
            base_state,
            &plan,
            hot_group,
            &strategy.policy,
            version_counter,
            &mut round_rng,
        );
        if let (Some(c), Some(crng)) = (cfg.chaos.as_ref(), chaos_rng.as_mut()) {
            if c.transient_compile_p > 0.0 && crng.chance(c.transient_compile_p) {
                candidate.faults.push(Fault::transient(plan.method));
            }
        }
        let transient_hit = candidate.faults.iter().any(|f| f.kind.is_transient());
        let review = reviewer::review_chaotic(
            task,
            &candidate,
            &cfg.dev,
            cfg.tool,
            &mut round_rng,
            consts,
            cfg.chaos.as_ref().zip(chaos_rng.as_mut()),
        );
        rounds.push(RoundRecord {
            round,
            branch: Branch::Optimize(plan.method),
            compiled: review.compiles,
            correct: review.correct,
            speedup: review.speedup,
            version: candidate.version,
        });

        // Harvest the (case, method, outcome) observation for the
        // persistent skill store; gain is measured against the base kernel
        // the method was applied to, and the device preset keys the store
        // partition the stat lands in. A transient toolchain failure says
        // nothing about the method — recording it as a failed try would let
        // chaos silently corrupt the learned stats, so it is skipped.
        if transient_hit {
            // skip harvest
        } else if let Some(case) = retrieval_result.as_ref().and_then(|r| r.matched_case) {
            skill_obs.push(SkillObs {
                case_id: case.to_string(),
                method: plan.method,
                gain: review
                    .speedup
                    .filter(|_| review.ok())
                    .map(|sp| sp - base_review.speedup.unwrap_or(0.0)),
                device: cfg.dev.name.to_string(),
            });
        }

        if review.ok() {
            let sp = review.speedup.unwrap();
            if track_latest {
                latest_valid = Some((sp, candidate.sched.clone()));
            }
            if best.as_ref().map(|(b, _)| sp > *b).unwrap_or(true) {
                best = Some((sp, candidate.sched.clone()));
            }
            if strategy.use_short_term_opt {
                if opt_mem.record(plan.method, Some(sp), round, candidate.version) {
                    promotions += 1;
                    base = Some((candidate, review));
                }
            } else {
                // Memory-less drift: always iterate on the latest kernel.
                opt_mem.base_speedup = sp;
                promotions += 1;
                base = Some((candidate, review));
            }
        } else {
            // Same protection for the short-term trajectory memory: a
            // transient toolchain failure is not evidence against the
            // method, so the failed-try record is withheld; the retry's
            // outcome lands through the post-repair bookkeeping instead.
            if strategy.use_short_term_opt && !transient_hit {
                opt_mem.record(plan.method, None, round, candidate.version);
            }
            pending_method = Some(plan.method);
            current = Some(candidate);
        }
    }

    let success = best.is_some();
    // Deliverable kernel: best-version tracking requires the short-term
    // memory's plan->result record; without it the final (latest) working
    // kernel is what ships — possibly a late regression.
    let delivered = if strategy.use_short_term_opt { best } else { latest_valid };
    let (best_speedup, best_sched) = delivered
        .map(|(s, sched)| (s, sched))
        .unwrap_or_else(|| (0.0, Schedule::per_op_naive(&task.graph)));

    TaskResult {
        task_id: task.id.clone(),
        level: task.level,
        strategy: strategy.name,
        success,
        best_speedup,
        seed_speedup,
        rounds_used,
        rounds,
        promotions,
        repair_attempts: repair_mem.total_attempts(),
        longest_repair_chain: repair_mem.longest_chain(),
        best_sched,
        skill_obs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    fn cfg() -> LoopConfig {
        LoopConfig::default()
    }

    #[test]
    fn kernelskill_succeeds_on_the_motivating_example() {
        let tasks = bench_suite::level_suite(42, 2);
        let task = tasks.iter().find(|t| t.id.contains("fused_epilogue")).unwrap();
        let r = run_task(task, &baselines::kernelskill(), &cfg());
        assert!(r.success);
        // The Appendix-D instance is physics-capped (the 1024x8192x8192 GEMM
        // dominates both eager and custom); what matters is the trajectory:
        // a large climb from the ~0.06x naive seed, driven by GEMM work first.
        assert!(
            r.best_speedup > 0.6 && r.best_speedup > r.seed_speedup.unwrap_or(0.0) * 5.0,
            "KernelSkill should climb far above the naive seed, got {} from {:?}",
            r.best_speedup,
            r.seed_speedup
        );
        // The first optimization round must target the GEMM (TileSmem), not
        // fusion — the motivating example's point.
        let first_opt = r
            .rounds
            .iter()
            .find_map(|rec| match rec.branch {
                Branch::Optimize(m) => Some(m),
                _ => None,
            })
            .unwrap();
        assert_eq!(first_opt, MethodId::TileSmem);
    }

    #[test]
    fn runs_are_deterministic() {
        let tasks = bench_suite::level_suite(42, 1);
        let a = run_task(&tasks[5], &baselines::kernelskill(), &cfg());
        let b = run_task(&tasks[5], &baselines::kernelskill(), &cfg());
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.rounds.len(), b.rounds.len());
    }

    #[test]
    fn different_run_seeds_differ() {
        let tasks = bench_suite::level_suite(42, 2);
        let mut c2 = cfg();
        c2.run_seed = 99;
        let a = run_task(&tasks[3], &baselines::kernelskill(), &cfg());
        let b = run_task(&tasks[3], &baselines::kernelskill(), &c2);
        // Trajectories diverge (round count or speedup).
        assert!(a.best_speedup != b.best_speedup || a.rounds.len() != b.rounds.len());
    }

    #[test]
    fn best_never_below_seed() {
        let tasks = bench_suite::level_suite(42, 1);
        for t in tasks.iter().take(20) {
            let r = run_task(t, &baselines::kernelskill(), &cfg());
            if let Some(seed) = r.seed_speedup {
                assert!(r.best_speedup >= seed * 0.999, "{}", t.id);
            }
        }
    }

    #[test]
    fn rounds_respect_budget() {
        let tasks = bench_suite::level_suite(42, 3);
        for t in tasks.iter().take(6) {
            let r = run_task(t, &baselines::stark(), &cfg());
            assert!(r.rounds.len() <= 30);
            let r2 = run_task(t, &baselines::kernelskill(), &cfg());
            assert!(r2.rounds.len() <= 15);
        }
    }

    #[test]
    fn chaos_with_zero_knobs_matches_a_clean_run() {
        let tasks = bench_suite::level_suite(42, 1);
        let mut c = cfg();
        c.chaos = Some(ChaosConfig::parse("seed=7").unwrap());
        let a = run_task(&tasks[5], &baselines::kernelskill(), &cfg());
        let b = run_task(&tasks[5], &baselines::kernelskill(), &c);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.rounds.len(), b.rounds.len());
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn transient_compile_chaos_repairs_and_still_converges() {
        let tasks = bench_suite::level_suite(42, 1);
        let mut c = cfg();
        c.chaos = Some(ChaosConfig::parse("tc=0.5,seed=11").unwrap());
        let mut chaotic_repairs = 0usize;
        let mut clean_repairs = 0usize;
        for t in tasks.iter().take(10) {
            let chaotic = run_task(t, &baselines::kernelskill(), &c);
            assert!(chaotic.success, "{}: transient chaos must not kill the cell", t.id);
            assert!(chaotic.best_speedup > 0.0, "{}", t.id);
            chaotic_repairs += chaotic.repair_attempts;
            clean_repairs += run_task(t, &baselines::kernelskill(), &cfg()).repair_attempts;
        }
        assert!(
            chaotic_repairs > clean_repairs,
            "transient faults must route through the repair branch ({chaotic_repairs} vs {clean_repairs})"
        );
    }

    #[test]
    fn transient_chaos_never_pollutes_skill_observations() {
        // At p=1 every fresh candidate hits a transient toolchain failure,
        // so every optimize round is a transient round: the harvest must
        // withhold all of them rather than record bogus failed tries.
        let tasks = bench_suite::level_suite(42, 1);
        let mut c = cfg();
        c.chaos = Some(ChaosConfig::parse("tc=1,seed=5").unwrap());
        for t in tasks.iter().take(5) {
            let r = run_task(t, &baselines::kernelskill(), &c);
            assert!(r.skill_obs.is_empty(), "{}: {:?}", t.id, r.skill_obs);
        }
    }

    #[test]
    fn failure_reports_zero_speedup() {
        // A hostile strategy: terrible coder, no repair memory, tiny budget.
        let mut s = baselines::kevin();
        s.rounds = 2;
        s.policy.coding_skill = 0.0;
        s.policy.repair_skill = 0.0;
        let tasks = bench_suite::level_suite(42, 3);
        let mut failures = 0;
        for t in tasks.iter().take(15) {
            let r = run_task(t, &s, &cfg());
            if !r.success {
                failures += 1;
                assert_eq!(r.best_speedup, 0.0);
            }
        }
        assert!(failures > 0, "expected some failures under a 2-round budget");
    }
}
